"""§Roofline table assembler: reads experiments/dryrun/*.json and prints
the per-(arch x shape x mesh) three-term roofline table (markdown)."""
from __future__ import annotations

import argparse
import json
import pathlib


def fmt_s(v):
    if v >= 1:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v*1e3:.1f}ms"
    return f"{v*1e6:.0f}us"


def load_rows(dirpath="experiments/dryrun", pod="pod1", tag=None):
    suffix = f"__{pod}.{tag}.json" if tag else f"__{pod}.json"
    rows = []
    for f in sorted(pathlib.Path(dirpath).glob("*.json")):
        if not f.name.endswith(suffix):
            continue
        rows.append(json.load(open(f)))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--pod", default="pod1")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args(argv)
    rows = load_rows(args.dir, args.pod, args.tag)
    print("| arch | shape | compute | memory | collective | dominant |"
          " useful | MFU-bound |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("status") == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                  f" — | — |")
            continue
        if r.get("status") != "ok":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — |")
            continue
        t = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{t['dominant']} | {t['useful_ratio']:.2f} | "
            f"{t['mfu_bound']*100:.2f}% |"
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
