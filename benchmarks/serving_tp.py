"""Tensor-parallel serving scaling benchmark (serve/distributed.py).

    PYTHONPATH=src python benchmarks/serving_tp.py --smoke

Forces a multi-device CPU host (XLA_FLAGS, set before jax imports), then
serves the same paged-decode workload through the engine at each model-
axis width in ``--mp-list``: mp=1 is the single-device baseline, wider
meshes shard the packed quantized weights (column/row-parallel), the KV
page pool (over KV heads), and the paged-attention dispatch (shard_map).
Per config it reports throughput, per-device vs total page-pool bytes
(the pool memory win: device_bytes ≈ total/mp), and token parity with
the mp=1 baseline — the record lands in ``BENCH_tp.json`` so the
distributed path's correctness AND its memory scaling are visible
PR-over-PR.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# must precede any jax import: fake a multi-device host for the mesh
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.core.quantizer import QuipConfig  # noqa: E402
from repro.data import make_calibration  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve import (  # noqa: E402
    CachedDecoder,
    DistributedCachedDecoder,
    Engine,
    EngineConfig,
    make_serving_mesh,
)


def run_workload(adapter, prompts, args):
    engine = Engine(adapter, EngineConfig(
        max_seq_len=args.prompt_len + args.gen,
        n_slots=args.slots,
        page_size=args.page_size,
        token_budget=args.token_budget,
        prefill_chunk=args.prefill_chunk,
        paged_decode=True,
        kv_int8=args.kv_int8,
    ))
    # warm the jit caches; compile time stays out of the measured run
    warm = engine.submit(np.asarray(prompts[0]), max_new=2)
    engine.run()
    assert warm.done
    for i in range(args.requests):
        engine.submit(np.asarray(prompts[i]), max_new=args.gen)
    engine.reset_clock()
    engine.reset_stats()
    t0 = time.time()
    done = engine.run()
    wall = time.time() - t0
    toks = [
        np.asarray(r.out_tokens, np.int32)
        for r in sorted(done, key=lambda r: r.rid)
    ]
    total = sum(len(t) for t in toks)
    return {
        "wall_s": round(wall, 3),
        "tok_s": round(total / wall, 2),
        "pool_total_bytes": engine.pool.total_bytes(),
        "pool_device_bytes": engine.pool.device_bytes(),
    }, toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mp-list", default="1,2",
                    help="model-axis widths to sweep (comma-separated); "
                         "widths the arch's KV heads cannot divide fall "
                         "back to a replicated pool")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--fp", action="store_true",
                    help="serve fp weights instead of QuIP-quantized")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--token-budget", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_tp.json")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if not args.smoke:
        print("[serving_tp] full-scale arch on CPU is impractical; "
              "using the smoke config (pass --smoke to silence this)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.fp:
        qm, label = None, "fp"
    else:
        from repro.launch.quantize import quantize_dense_model

        calib = make_calibration(cfg.vocab, n_segments=8, seg_len=64,
                                 seed=args.seed + 7)
        qm = quantize_dense_model(
            params, cfg,
            QuipConfig(bits=args.bits, method="ldlq", use_kernel=False),
            calib.tokens, seed=args.seed, verbose=False,
        )
        label = f"quip-{args.bits}b"
    prompts = make_calibration(
        cfg.vocab, n_segments=args.requests, seg_len=args.prompt_len,
        seed=args.seed + 3,
    ).tokens

    mp_list = [int(x) for x in args.mp_list.split(",")]
    if 1 not in mp_list:
        # parity is defined against the single-device engine; always
        # measure that baseline even if the sweep didn't ask for it
        mp_list = [1] + mp_list
    configs = []
    base_toks = None
    for mp in sorted(set(mp_list)):
        if mp > jax.device_count():
            print(f"[serving_tp] skip mp={mp}: only "
                  f"{jax.device_count()} devices")
            continue
        if mp == 1:
            adapter = (CachedDecoder.from_model(model, params) if args.fp
                       else CachedDecoder.from_quantized(qm))
        else:
            mesh = make_serving_mesh(1, mp)
            adapter = (
                DistributedCachedDecoder.from_model(model, params, mesh=mesh)
                if args.fp
                else DistributedCachedDecoder.from_quantized(qm, mesh=mesh)
            )
        rec, toks = run_workload(adapter, prompts, args)
        if mp == 1:  # the single-device baseline every width compares to
            base_toks = toks
        match = all(
            np.array_equal(a, b) for a, b in zip(base_toks, toks)
        )
        rec.update(
            mp=mp,
            pool_device_frac=round(
                rec["pool_device_bytes"] / rec["pool_total_bytes"], 4
            ),
            tokens_match_mp1=bool(match),
        )
        configs.append(rec)
        print(f"[serving_tp] mp={mp}: {rec['tok_s']} tok/s, pool "
              f"{rec['pool_device_bytes']}/{rec['pool_total_bytes']} B/device "
              f"({rec['pool_device_frac']:.0%}), parity={match}")

    record = {
        "label": label,
        "arch": cfg.name,
        "kv_pages": "int8" if args.kv_int8 else "fp",
        "requests": args.requests,
        "prompt_len": args.prompt_len,
        "gen": args.gen,
        "devices": jax.device_count(),
        "configs": configs,
    }
    print(json.dumps(record, indent=1))
    if not configs:
        print("[serving_tp] FAIL: no config ran (every --mp-list width "
              "was skipped) — nothing measured, not writing a record")
        return 1
    if args.out:
        with open(args.out, "w") as f:
            json.dump(record, f)
    if not all(c["tokens_match_mp1"] for c in configs):
        print("[serving_tp] FAIL: TP token stream diverged from mp=1")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
