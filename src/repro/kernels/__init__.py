"""Pallas TPU kernels for QuIP's compute hot spots.

quant_matmul/     packed 2/3/4-bit weight x activation matmul (W2A16 serving)
kron_mul/         fused (A ⊗ B) x incoherence transform (two MXU dots)
hadamard/         randomized Hadamard transform as kron-decomposed MXU dots
ldlq/             in-block sequential LDLQ rounding, gridded over row blocks
paged_attention/  GQA decode attention in place over the paged KV pool
                  (scalar-prefetch block tables, online softmax, int8 pages)

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper w/ padding + CPU fallback), ref.py (pure-jnp oracle used by
the allclose test sweeps).
"""
