"""RWKV6 LM and Zamba2-style hybrid (Mamba2 backbone + shared attn block).

Both families are sub-quadratic: decode state is O(1) in context length, so
they run the ``long_500k`` shape (DESIGN.md §5).

Hybrid layout: ``n_shared = n_layers // shared_attn_period`` invocations of a
single SHARED transformer block (one weight copy, distinct KV caches per
invocation), interleaved every (period-1) Mamba2 layers; leftover Mamba2
layers form the tail.  E.g. zamba2-7b: 81 = 13·(5 mamba + 1 shared) + 3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models.transformer import _stack, _stack_axes, remat_wrap

__all__ = [
    "init_rwkv_lm", "rwkv_lm_axes", "rwkv_forward", "rwkv_prefill",
    "rwkv_decode_step", "init_rwkv_cache", "rwkv_cache_axes",
    "init_hybrid", "hybrid_axes", "hybrid_forward", "hybrid_prefill",
    "hybrid_decode_step", "init_hybrid_cache", "hybrid_cache_axes",
]


# ===========================================================================
# RWKV6
# ===========================================================================


def _init_rwkv_block(key, cfg: ArchConfig) -> dict:
    return {
        "ln1": L.init_norm(cfg, cfg.d_model, "ln"),
        "time_mix": ssm.init_rwkv6(key, cfg),
        "ln2": L.init_norm(cfg, cfg.d_model, "ln"),
        "channel_mix": ssm.init_channel_mix(L._key(key, "cm"), cfg),
    }


def _rwkv_block_axes(cfg) -> dict:
    return {
        "ln1": L.norm_axes("ln"),
        "time_mix": ssm.rwkv6_axes(),
        "ln2": L.norm_axes("ln"),
        "channel_mix": ssm.channel_mix_axes(),
    }


def init_rwkv_lm(key, cfg: ArchConfig) -> dict:
    return {
        "embed": L.init_embedding(L._key(key, "embed"), cfg),
        "ln0": L.init_norm(cfg, cfg.d_model, "ln"),
        "layers": _stack(
            L._key(key, "layers"), cfg.n_layers,
            lambda k: _init_rwkv_block(k, cfg),
        ),
        "final_norm": L.init_norm(cfg, cfg.d_model, "ln"),
    }


def rwkv_lm_axes(cfg: ArchConfig) -> dict:
    return {
        "embed": L.embedding_axes(cfg),
        "ln0": L.norm_axes("ln"),
        "layers": _stack_axes(_rwkv_block_axes(cfg)),
        "final_norm": L.norm_axes("ln"),
    }


def rwkv_forward(params, tokens: jax.Array, cfg: ArchConfig):
    x = L.embed(params["embed"], tokens)
    x = L.norm_apply(params["ln0"], x, cfg)

    def body(x, lp):
        h = L.norm_apply(lp["ln1"], x, cfg)
        x = x + ssm.rwkv6_time_mix(lp["time_mix"], h, cfg)
        h = L.norm_apply(lp["ln2"], x, cfg)
        x = x + ssm.channel_mix(lp["channel_mix"], h)
        return x, None

    body = remat_wrap(body, cfg)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, jnp.float32(0.0)


def init_rwkv_cache(cfg: ArchConfig, batch: int, max_len: int = 0, kv_dtype=None):
    """Recurrent state (context length enters only through its *contents*)."""
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    dt = jnp.dtype(cfg.dtype)
    one = {
        "tm_shift": jnp.zeros((batch, 1, d), dt),
        "wkv": jnp.zeros((batch, H, hs, hs), jnp.float32),
        "cm_shift": jnp.zeros((batch, 1, d), dt),
    }
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one
    )


def rwkv_cache_axes(cfg: ArchConfig, int8: bool = False) -> dict:
    return {
        "tm_shift": ("layers", "batch", None, None),
        "wkv": ("layers", "batch", None, None, None),
        "cm_shift": ("layers", "batch", None, None),
    }


def rwkv_prefill(
    params, tokens: jax.Array, cfg: ArchConfig, kv_dtype=None, max_len=None
):
    x = L.embed(params["embed"], tokens)
    x = L.norm_apply(params["ln0"], x, cfg)

    def body(x, lp):
        h = L.norm_apply(lp["ln1"], x, cfg)
        tm, tm_shift, wkv = ssm.rwkv6_time_mix(
            lp["time_mix"], h, cfg, return_state=True
        )
        x = x + tm
        h = L.norm_apply(lp["ln2"], x, cfg)
        cm, cm_shift = ssm.channel_mix(lp["channel_mix"], h, return_state=True)
        x = x + cm
        return x, {"tm_shift": tm_shift, "wkv": wkv, "cm_shift": cm_shift}

    x, states = jax.lax.scan(body, x, params["layers"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x[:, -1:, :])[:, 0]
    return logits, states


def rwkv_decode_step(params, tokens, cfg: ArchConfig, cache, pos):
    x = L.embed(params["embed"], tokens)
    x = L.norm_apply(params["ln0"], x, cfg)

    def body(x, xs):
        lp, st = xs
        h = L.norm_apply(lp["ln1"], x, cfg)
        tm, tm_shift, wkv = ssm.rwkv6_time_mix_step(
            lp["time_mix"], h, cfg, st["tm_shift"], st["wkv"]
        )
        x = x + tm
        h = L.norm_apply(lp["ln2"], x, cfg)
        cm, cm_shift = ssm.channel_mix_step(lp["channel_mix"], h, st["cm_shift"])
        x = x + cm
        return x, {"tm_shift": tm_shift, "wkv": wkv, "cm_shift": cm_shift}

    x, states = jax.lax.scan(body, x, (params["layers"], cache))
    x = L.norm_apply(params["final_norm"], x, cfg)
    return L.lm_logits(params["embed"], x)[:, 0], states


# ===========================================================================
# Hybrid (zamba2-style)
# ===========================================================================


def _hybrid_counts(cfg: ArchConfig):
    per = cfg.shared_attn_period
    n_shared = cfg.n_layers // per
    n_mamba = cfg.n_layers - n_shared
    main_mamba = n_shared * (per - 1)
    tail = n_mamba - main_mamba
    return n_shared, per - 1, n_mamba, tail


def _init_mamba_layer(key, cfg):
    return {
        "norm": L.init_norm(cfg, cfg.d_model),
        "mamba": ssm.init_mamba2(key, cfg),
    }


def _mamba_layer_axes(cfg):
    return {"norm": L.norm_axes(), "mamba": ssm.mamba2_axes(cfg)}


def init_hybrid(key, cfg: ArchConfig) -> dict:
    n_shared, per_m, n_mamba, tail = _hybrid_counts(cfg)
    return {
        "embed": L.init_embedding(L._key(key, "embed"), cfg),
        "mamba_layers": _stack(
            L._key(key, "mamba"), n_mamba, lambda k: _init_mamba_layer(k, cfg)
        ),
        "shared": {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "attn": L.init_attention(L._key(key, "shared_attn"), cfg),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(L._key(key, "shared_mlp"), cfg),
        },
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def hybrid_axes(cfg: ArchConfig) -> dict:
    return {
        "embed": L.embedding_axes(cfg),
        "mamba_layers": _stack_axes(_mamba_layer_axes(cfg)),
        "shared": {
            "ln1": L.norm_axes(),
            "attn": L.attention_axes(cfg),
            "ln2": L.norm_axes(),
            "mlp": L.mlp_axes(cfg),
        },
        "final_norm": L.norm_axes(),
    }


def _shared_block(sp, x, cfg, positions, return_kv=False):
    h = L.norm_apply(sp["ln1"], x, cfg)
    if return_kv:
        a, kv = L.attention_full(
            sp["attn"], h, cfg, positions=positions, causal=True, return_kv=True
        )
    else:
        a = L.attention_full(sp["attn"], h, cfg, positions=positions, causal=True)
        kv = None
    x = x + a
    h = L.norm_apply(sp["ln2"], x, cfg)
    return x + L.mlp_apply(sp["mlp"], h, cfg), kv


def _split_main_tail(tree, n_super, per):
    main = jax.tree.map(
        lambda a: a[: n_super * per].reshape(n_super, per, *a.shape[1:]), tree
    )
    tail = jax.tree.map(lambda a: a[n_super * per :], tree)
    return main, tail


def hybrid_forward(params, tokens: jax.Array, cfg: ArchConfig):
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    n_shared, per_m, n_mamba, tail = _hybrid_counts(cfg)
    x = L.embed(params["embed"], tokens)
    main, tail_layers = _split_main_tail(params["mamba_layers"], n_shared, per_m)

    def mamba_one(x, lp):
        h = L.norm_apply(lp["norm"], x, cfg)
        return x + ssm.mamba2_forward(lp["mamba"], h, cfg), None

    mamba_one_r = remat_wrap(mamba_one, cfg)

    def superblock(x, lps):
        x, _ = jax.lax.scan(mamba_one_r, x, lps)
        x, _ = _shared_block(params["shared"], x, cfg, positions)
        return x, None

    superblock = remat_wrap(superblock, cfg)
    x, _ = jax.lax.scan(superblock, x, main)
    if tail:
        x, _ = jax.lax.scan(mamba_one_r, x, tail_layers)
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, jnp.float32(0.0)


def init_hybrid_cache(cfg: ArchConfig, batch: int, max_len: int, kv_dtype=None):
    n_shared, per_m, n_mamba, tail = _hybrid_counts(cfg)
    mamba_state = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_mamba, *a.shape)),
        ssm.init_mamba2_state(cfg, batch),
    )
    kv = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_shared, *a.shape)),
        L.init_kv_cache(cfg, batch, max_len, kv_dtype),
    )
    return {"mamba": mamba_state, "kv": kv}


def hybrid_cache_axes(cfg: ArchConfig, int8: bool = False) -> dict:
    return {
        "mamba": _stack_axes(ssm.mamba2_state_axes()),
        "kv": _stack_axes(L.kv_cache_axes(int8)),
    }


def hybrid_prefill(
    params, tokens: jax.Array, cfg: ArchConfig, kv_dtype=None, max_len=None
):
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    n_shared, per_m, n_mamba, tail = _hybrid_counts(cfg)
    x = L.embed(params["embed"], tokens)
    main, tail_layers = _split_main_tail(params["mamba_layers"], n_shared, per_m)
    kv0 = L.init_kv_cache(cfg, B, max_len or S, kv_dtype)

    def mamba_one(x, lp):
        h = L.norm_apply(lp["norm"], x, cfg)
        y, st = ssm.mamba2_forward(lp["mamba"], h, cfg, return_state=True)
        return x + y, st

    def superblock(x, lps):
        x, states = jax.lax.scan(mamba_one, x, lps)
        x, (k, v) = _shared_block(
            params["shared"], x, cfg, positions, return_kv=True
        )
        return x, (states, L.cache_store(kv0, k, v, 0))

    x, (main_states, kv_caches) = jax.lax.scan(superblock, x, main)
    main_states = jax.tree.map(
        lambda a: a.reshape(n_shared * per_m, *a.shape[2:]), main_states
    )
    if tail:
        x, tail_states = jax.lax.scan(mamba_one, x, tail_layers)
        main_states = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), main_states, tail_states
        )
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x[:, -1:, :])[:, 0]
    return logits, {"mamba": main_states, "kv": kv_caches}


def hybrid_decode_step(params, tokens, cfg: ArchConfig, cache, pos):
    x = L.embed(params["embed"], tokens)
    n_shared, per_m, n_mamba, tail = _hybrid_counts(cfg)
    main, tail_layers = _split_main_tail(params["mamba_layers"], n_shared, per_m)
    main_st, tail_st = _split_main_tail(cache["mamba"], n_shared, per_m)

    def mamba_one(x, xs):
        lp, st = xs
        h = L.norm_apply(lp["norm"], x, cfg)
        y, st2 = ssm.mamba2_decode_step(lp["mamba"], h, cfg, st)
        return x + y, st2

    def superblock(x, xs):
        lps, sts, kv_c = xs
        x, new_sts = jax.lax.scan(mamba_one, x, (lps, sts))
        h = L.norm_apply(params["shared"]["ln1"], x, cfg)
        a, new_kv = L.attention_decode(
            params["shared"]["attn"], h, cfg, kv_c, pos
        )
        x = x + a
        h = L.norm_apply(params["shared"]["ln2"], x, cfg)
        x = x + L.mlp_apply(params["shared"]["mlp"], h, cfg)
        return x, (new_sts, new_kv)

    x, (new_main, new_kv) = jax.lax.scan(superblock, x, (main, main_st, cache["kv"]))
    new_main = jax.tree.map(
        lambda a: a.reshape(n_shared * per_m, *a.shape[2:]), new_main
    )
    if tail:
        x, new_tail = jax.lax.scan(mamba_one, x, (tail_layers, tail_st))
        new_main = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), new_main, new_tail
        )
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x)[:, 0]
    return logits, {"mamba": new_main, "kv": new_kv}
