"""Unit tests for the loop-weighted HLO analyzer (§Roofline engine)."""
from __future__ import annotations

import textwrap

from repro.runtime.hlo_analysis import analyze_hlo, parse_collectives

HLO_SIMPLE = textwrap.dedent("""\
    HloModule test, is_scheduled=true

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %w = f32[8,8] constant({...})
      %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[4,4]<=[16], to_apply=%add_comp
      ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
    }

    %cond (p: (s32[], f32[8,8])) -> pred[] {
      %p = (s32[], f32[8,8]) parameter(0)
      ROOT %lt = pred[] constant(true)
    }

    %add_comp (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x: f32[8,8]) -> f32[8,8] {
      %x = f32[8,8]{1,0} parameter(0)
      %i0 = s32[] constant(0)
      %t0 = (s32[], f32[8,8]) tuple(%i0, %x)
      %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_loop_weighted_dot_flops():
    st = analyze_hlo(HLO_SIMPLE, 16)
    # dot: 2 * 8*8 * 8 = 1024 flops, x10 trips
    assert st.flops == 1024 * 10


def test_loop_weighted_collective_bytes():
    st = analyze_hlo(HLO_SIMPLE, 16)
    # all-reduce of f32[8,8] = 256 B, group size 4: 2*256*(3/4) = 384/iter
    assert abs(st.collectives.bytes_by_kind["all-reduce"] - 384 * 10) < 1e-6
    assert st.collectives.count_by_kind["all-reduce"] == 1


HLO_DUS = textwrap.dedent("""\
    HloModule test2

    ENTRY %main (buf: f32[100,64], upd: f32[1,64]) -> f32[100,64] {
      %buf = f32[100,64]{1,0} parameter(0)
      %upd = f32[1,64]{1,0} parameter(1)
      %i = s32[] constant(3)
      %z = s32[] constant(0)
      ROOT %d = f32[100,64]{1,0} dynamic-update-slice(%buf, %upd, %i, %z)
    }
""")


def test_inplace_dus_costs_update_only():
    st = analyze_hlo(HLO_DUS, 1)
    # aliased buffer free; the 1x64 f32 update (256 B) + 2 s32 indices
    assert st.bytes_accessed == 256.0 + 8.0


HLO_DSLICE = textwrap.dedent("""\
    HloModule test3

    ENTRY %main (stack: f32[40,64,64]) -> f32[1,64,64] {
      %stack = f32[40,64,64]{2,1,0} parameter(0)
      %i = s32[] constant(7)
      %z = s32[] constant(0)
      ROOT %s = f32[1,64,64]{2,1,0} dynamic-slice(%stack, %i, %z, %z), dynamic_slice_sizes={1,64,64}
    }
""")


def test_dynamic_slice_reads_slice_not_stack():
    st = analyze_hlo(HLO_DSLICE, 1)
    # one 64x64 f32 slice result (big operand read through the slice) +
    # 3 s32 indices
    assert st.bytes_accessed == 16384.0 + 12.0


HLO_CONVERT = textwrap.dedent("""\
    HloModule test4

    ENTRY %main (x: bf16[128,128]) -> f32[128,128] {
      %x = bf16[128,128]{1,0} parameter(0)
      ROOT %c = f32[128,128]{1,0} convert(%x)
    }
""")


def test_convert_counted_at_narrow_dtype():
    st = analyze_hlo(HLO_CONVERT, 1)
    # 2 x bf16 side = 2 * 128*128*2 = 65536 B (not bf16+f32 = 98304)
    assert st.bytes_accessed == 65536.0


HLO_TUPLE_A2A = textwrap.dedent("""\
    HloModule test5

    ENTRY %main (a: s8[16,64], b: s8[16,64]) -> (s8[16,64], s8[16,64]) {
      %a = s8[16,64]{1,0} parameter(0)
      %b = s8[16,64]{1,0} parameter(1)
      ROOT %x = (s8[16,64], s8[16,64]) all-to-all(%a, %b), replica_groups=[1,16]<=[16]
    }
""")


def test_tuple_all_to_all_sums_operands():
    st = parse_collectives(HLO_TUPLE_A2A, 16)
    # 2 operands x 1024 B x 15/16
    assert abs(st.bytes_by_kind["all-to-all"] - 2 * 1024 * 15 / 16) < 1e-6
