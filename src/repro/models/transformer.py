"""Decoder-only transformer stack (dense + MoE families).

Layer weights are stacked along a leading ``layers`` axis and the stack is
applied with ``lax.scan`` (compact HLO at 35–100 layers, fast compiles).
Remat policy per :class:`ArchConfig.remat` wraps the scanned block body.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.runtime.sharding import constrain

__all__ = [
    "init_decoder",
    "decoder_axes",
    "decoder_forward",
    "decoder_prefill",
    "decoder_decode_step",
    "init_decoder_cache",
    "decoder_cache_axes",
    "remat_wrap",
    "unstack_layers",
]


def remat_wrap(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _stack(key, n: int, init_one):
    """Initialize ``n`` layers and stack each leaf along axis 0."""
    ps = [init_one(jax.random.fold_in(key, i)) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def unstack_layers(params: dict) -> list[dict]:
    """Split the scanned ``layers`` stack into per-layer param dicts (the
    layout block-by-block consumers — quantization, the serving adapter —
    operate on)."""
    n = jax.tree.leaves(params["layers"])[0].shape[0]
    return [jax.tree.map(lambda a: a[i], params["layers"]) for i in range(n)]


def _stack_axes(axes: dict) -> dict:
    return jax.tree.map(
        lambda ax: ("layers", *ax),
        axes,
        is_leaf=lambda v: isinstance(v, tuple)
        and all(isinstance(e, (str, type(None))) for e in v),
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig) -> dict:
    p = {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(key, cfg),
        "ln2": L.init_norm(cfg, cfg.d_model),
    }
    if cfg.n_experts:
        p["moe"] = L.init_moe(L._key(key, "moe"), cfg)
    else:
        p["mlp"] = L.init_mlp(L._key(key, "mlp"), cfg)
    return p


def _block_axes(cfg: ArchConfig) -> dict:
    ax = {
        "ln1": L.norm_axes(),
        "attn": L.attention_axes(cfg),
        "ln2": L.norm_axes(),
    }
    if cfg.n_experts:
        ax["moe"] = L.moe_axes(cfg)
    else:
        ax["mlp"] = L.mlp_axes(cfg)
    return ax


def init_decoder(key, cfg: ArchConfig) -> dict:
    return {
        "embed": L.init_embedding(L._key(key, "embed"), cfg),
        "layers": _stack(
            L._key(key, "layers"), cfg.n_layers, lambda k: _init_block(k, cfg)
        ),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def decoder_axes(cfg: ArchConfig) -> dict:
    return {
        "embed": L.embedding_axes(cfg),
        "layers": _stack_axes(_block_axes(cfg)),
        "final_norm": L.norm_axes(),
    }


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block_apply(lp, x, cfg: ArchConfig, positions, return_kv=False):
    h = L.norm_apply(lp["ln1"], x, cfg)
    if return_kv:
        a, kv = L.attention_full(
            lp["attn"], h, cfg, positions=positions, causal=cfg.causal,
            return_kv=True,
        )
    else:
        a = L.attention_full(
            lp["attn"], h, cfg, positions=positions, causal=cfg.causal
        )
        kv = None
    x = x + a
    h = L.norm_apply(lp["ln2"], x, cfg)
    if cfg.n_experts:
        f, aux = L.moe_apply(lp["moe"], h, cfg)
    else:
        f, aux = L.mlp_apply(lp["mlp"], h, cfg), jnp.float32(0.0)
    return x + f, aux, kv


def decoder_forward(params, tokens: jax.Array, cfg: ArchConfig):
    """tokens (B, S) -> (hidden (B, S, D), aux_loss)."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, lp):
        x, aux = carry
        x2, a, _ = _block_apply(lp, x, cfg, positions)
        return (x2, aux + a), None

    body = remat_wrap(body, cfg)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, aux / cfg.n_layers


# ---------------------------------------------------------------------------
# serving: prefill + cached decode
# ---------------------------------------------------------------------------


def init_decoder_cache(cfg: ArchConfig, batch: int, max_len: int, kv_dtype=None):
    one = L.init_kv_cache(cfg, batch, max_len, kv_dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one
    )


def decoder_cache_axes(cfg: ArchConfig, int8: bool = False) -> dict:
    return _stack_axes(L.kv_cache_axes(int8))


def decoder_prefill(
    params, tokens: jax.Array, cfg: ArchConfig, kv_dtype=None, max_len=None
):
    """Forward full prompt, building the layer-stacked KV cache.

    ``max_len`` reserves cache room beyond the prompt (decode budget).
    Returns (last-token logits (B, V), cache).
    """
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(S, dtype=jnp.int32)
    cache0 = L.init_kv_cache(cfg, B, max_len or S, kv_dtype)

    def body(carry, lp):
        x, aux = carry
        x2, a, (k, v) = _block_apply(lp, x, cfg, positions, return_kv=True)
        cache = L.cache_store(cache0, k, v, 0)
        return (x2, aux + a), cache

    (x, _), caches = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x[:, -1:, :])[:, 0]
    return logits, caches


def decoder_decode_step(params, tokens, cfg: ArchConfig, cache, pos):
    """One decode step.  tokens (B, 1); pos scalar int32.

    Returns (logits (B, V), new_cache)."""
    x = L.embed(params["embed"], tokens)

    def body(x, xs):
        lp, cache_l = xs
        h = L.norm_apply(lp["ln1"], x, cfg)
        a, new_cache = L.attention_decode(lp["attn"], h, cfg, cache_l, pos)
        x = x + a
        h = L.norm_apply(lp["ln2"], x, cfg)
        if cfg.n_experts:
            f, _ = L.moe_apply(lp["moe"], h, cfg)
        else:
            f = L.mlp_apply(lp["mlp"], h, cfg)
        return x + f, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], cache))
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x)[:, 0]
    return logits, new_caches
