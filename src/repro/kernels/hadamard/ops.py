"""Public wrapper: randomized Hadamard transform with factor caching."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hadamard.kernel import hadamard_kernel, sylvester
from repro.kernels.hadamard.ref import hadamard_ref


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=32)
def _factors(n: int) -> tuple[int, int]:
    """Split n = a*b (powers of two) with b <= 128 lane-aligned."""
    if n < 2 or n & (n - 1):
        raise ValueError(
            f"Hadamard transform dim must be a power of two >= 2, got {n}"
        )
    b = min(n, 128)
    return n // b, b


@functools.partial(jax.jit, static_argnames=("interpret", "force_kernel"))
def hadamard_transform(
    x: jax.Array,
    signs: jax.Array,
    *,
    interpret: bool = False,
    force_kernel: bool = False,
) -> jax.Array:
    """y = H (signs ⊙ x) along the last axis (power-of-two dim)."""
    if not (on_tpu() or interpret or force_kernel):
        return hadamard_ref(x, signs)
    n = x.shape[-1]
    a, b = _factors(n)
    Ha = jnp.asarray(sylvester(a))
    Hb = jnp.asarray(sylvester(b))
    lead = x.shape[:-1]
    N = 1
    for d in lead:
        N *= d
    x2 = x.reshape(N, n)
    bB = min(256, _ceil_to(N, 8))
    Np = _ceil_to(N, bB)
    if Np != N:
        x2 = jnp.pad(x2, ((0, Np - N), (0, 0)))
    y = hadamard_kernel(
        x2, signs.astype(x.dtype), Ha, Hb, a=a, b=b, bB=bB, interpret=interpret
    )
    return y[:N].reshape(*lead, n)
