"""Shared benchmark plumbing: a small trained LM + timing helpers.

The paper's tables compare quantization methods on a TRAINED model (a
random-init model has no signal to destroy).  ``trained_lm`` trains a small
dense LM on the deterministic synthetic stream (data/synthetic.py) and
caches the params to experiments/cache/ so the grid benches reuse it.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data import make_calibration, token_batches
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw, cosine_schedule

CACHE = pathlib.Path("experiments/cache")


def bench_lm_config(vocab: int = 512, d: int = 128, layers: int = 4) -> ArchConfig:
    return ArchConfig(
        name=f"bench-lm-{d}x{layers}",
        family="dense",
        n_layers=layers,
        d_model=d,
        n_heads=4,
        n_kv_heads=2,
        d_ff=4 * d,
        vocab=vocab,
        mlp="swiglu",
        dtype="float32",
        microbatch=8,
        remat="none",
    )


def trained_lm(steps: int = 150, seed: int = 0, cfg: ArchConfig | None = None):
    """Returns (cfg, model, trained params); cached on disk."""
    cfg = cfg or bench_lm_config()
    model = build_model(cfg)
    tag = f"{cfg.name}_s{steps}_seed{seed}"
    CACHE.mkdir(parents=True, exist_ok=True)
    cache_file = CACHE / f"{tag}.npz"
    params0 = model.init(jax.random.PRNGKey(seed))
    if cache_file.exists():
        flat, treedef = jax.tree.flatten(params0)
        with np.load(cache_file) as z:
            leaves = [jnp.asarray(z[f"a{i}"]) for i in range(len(flat))]
        return cfg, model, jax.tree.unflatten(treedef, leaves)
    opt = adamw(cosine_schedule(1e-3, steps, 20))
    step_fn = jax.jit(make_train_step(model, opt, n_micro=1))
    params, opt_state = params0, opt.init(params0)
    stream = token_batches(cfg.vocab, 8, 128, seed=seed)
    t0 = time.time()
    for s in range(steps):
        batch = next(stream)
        params, opt_state, metrics = step_fn(params, opt_state, batch, jnp.int32(s))
        if s % 50 == 0:
            print(f"[bench-lm] step {s} loss={float(metrics['loss']):.3f}")
    print(f"[bench-lm] trained {steps} steps in {time.time()-t0:.0f}s, "
          f"final loss {float(metrics['loss']):.3f}")
    flat, _ = jax.tree.flatten(params)
    np.savez(cache_file, **{f"a{i}": np.asarray(x) for i, x in enumerate(flat)})
    return cfg, model, params


def eval_ppl(model, params, cfg, seed: int = 99, n_seg: int = 8, seg_len: int = 128):
    toks = make_calibration(cfg.vocab, n_segments=n_seg, seg_len=seg_len,
                            seed=seed).tokens
    logits = model.logits(params, model.forward(params, {"tokens": toks[:, :-1]})[0])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, toks[:, 1:, None], -1)[..., 0]
    return float(jnp.exp(jnp.mean(nll)))


def timeit(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
