from repro.kernels.hadamard.ops import hadamard_transform

__all__ = ["hadamard_transform"]
