"""Blocked LDLQ driver with the Pallas in-block kernel.

Outer schedule identical to ``core.ldlq.ldlq_blocked``: the trailing
feedback `Err @ U_panel` is one MXU matmul per block; the sequential
in-block recurrence runs in the Pallas kernel, parallel over row blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.ldlq import ldlq_blocked
from repro.kernels.ldlq.kernel import ldlq_block_kernel


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("maxq", "block", "interpret", "force_kernel")
)
def ldlq_pallas(
    W: jax.Array,
    Udot: jax.Array,
    maxq: int,
    *,
    block: int = 128,
    interpret: bool = False,
    force_kernel: bool = False,
) -> jax.Array:
    """LDLQ via the Pallas in-block kernel; falls back to XLA off-TPU."""
    if not (on_tpu() or interpret or force_kernel):
        return ldlq_blocked(W, Udot, maxq, block=min(block, W.shape[1]))
    m, n = W.shape
    if n % block:
        raise ValueError(
            f"W column count n={n} must be a multiple of the LDLQ block "
            f"size {block}"
        )
    nb = n // block
    bM = min(256, _ceil_to(m, 8))
    Mp = _ceil_to(m, bM)
    Wp = jnp.pad(W.astype(jnp.float32), ((0, Mp - m), (0, 0)))

    def outer(carry, i):
        What, Err = carry
        Wblk = jax.lax.dynamic_slice(Wp, (0, i * block), (Mp, block))
        Upanel = jax.lax.dynamic_slice(Udot, (0, i * block), (n, block))
        Ublk = jax.lax.dynamic_slice(
            Udot, (i * block, i * block), (block, block)
        )
        base = Err @ Upanel  # cross-block feedback: one MXU matmul
        Q, E = ldlq_block_kernel(
            Wblk, base, Ublk, nb=block, bM=bM, maxq=maxq,
            interpret=interpret,
        )
        What = jax.lax.dynamic_update_slice(What, Q, (0, i * block))
        Err = jax.lax.dynamic_update_slice(Err, E, (0, i * block))
        return (What, Err), None

    (What, _), _ = jax.lax.scan(
        outer, (jnp.zeros_like(Wp), jnp.zeros_like(Wp)), jnp.arange(nb)
    )
    return What[:m]
