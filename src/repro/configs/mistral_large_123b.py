"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    mlp="swiglu",
    rope_theta=1e6,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        mlp="swiglu",
        dtype="float32",
        microbatch=2,
        remat="none",
    )
