"""Driver integration tests: train (fault-tolerant), quantize, serve."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import quantize as qz
from repro.launch import serve as sv
from repro.launch import train as tr


@pytest.mark.slow
def test_train_driver_failure_recovery(tmp_path):
    """Injected failure -> restore from checkpoint -> identical replay."""
    rc = tr.main([
        "--arch", "qwen3-14b", "--smoke", "--steps", "8",
        "--global-batch", "2", "--seq-len", "16",
        "--save-every", "3", "--fail-at", "5",
        "--ckpt-dir", str(tmp_path), "--log-every", "2",
    ])
    assert rc == 0
    from repro.checkpoint import latest_step

    assert latest_step(tmp_path) == 8


@pytest.mark.slow
def test_train_driver_resume(tmp_path):
    """A second invocation resumes from the final checkpoint."""
    args = [
        "--arch", "qwen3-14b", "--smoke", "--steps", "4",
        "--global-batch", "2", "--seq-len", "16",
        "--save-every", "2", "--ckpt-dir", str(tmp_path),
    ]
    assert tr.main(args) == 0
    # extend to 6 steps: resumes at 4, not 0
    args[args.index("--steps") + 1] = "6"
    assert tr.main(args) == 0
    from repro.checkpoint import latest_step

    assert latest_step(tmp_path) == 6


@pytest.mark.slow
def test_quantize_driver_2bit_close_to_fp(tmp_path):
    out = tmp_path / "q.json"
    rc = qz.main([
        "--arch", "mistral-large-123b", "--smoke", "--bits", "2",
        "--calib-segments", "8", "--calib-len", "64",
        "--out", str(out),
    ])
    assert rc == 0
    import json

    rec = json.loads(out.read_text())
    # 2-bit with IncP stays within 25% relative ppl of fp on the smoke model
    assert rec["ppl_quant"] < rec["ppl_fp16"] * 1.25


@pytest.mark.slow
def test_serve_driver_quantized_generation():
    """In-process quantize -> engine serve; --check verifies the cached
    decode against the recompute oracle (rc != 0 on divergence)."""
    rc = sv.main([
        "--arch", "qwen3-14b", "--smoke", "--requests", "2",
        "--prompt-len", "16", "--gen", "4", "--quantize", "--bits", "4",
        "--check",
    ])
    assert rc == 0


@pytest.mark.slow
def test_quantize_artifact_then_serve(tmp_path):
    """quantize --out-dir -> serve --load-quantized, no re-quantization."""
    rc = qz.main([
        "--arch", "qwen3-14b", "--smoke", "--bits", "2",
        "--calib-segments", "4", "--calib-len", "32",
        "--out-dir", str(tmp_path / "art"),
    ])
    assert rc == 0
    rc = sv.main([
        "--arch", "qwen3-14b", "--smoke", "--requests", "4",
        "--prompt-len", "16", "--gen", "4",
        "--load-quantized", str(tmp_path / "art"), "--check",
    ])
    assert rc == 0
