"""Tables 3 & 5 analogue: IncP sub-step ablation + random-permutation ablation.

Table 3: rescale / incoherence / quant-range sub-steps each contribute.
Table 5: the random permutation inside the fast orthogonal multiply helps.
Metric: held-out perplexity of the quantized bench LM (2 and 3 bits).
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.quantizer import QuipConfig
from repro.data import make_calibration
from repro.launch.quantize import perplexity, quantize_dense_model

from benchmarks.common import emit, trained_lm

VARIANTS = {
    # (incoherence, rescale, spectrum_range, permute)
    "rescale_only":        dict(incoherence=False, rescale=True,  spectrum_range=False, permute=False),
    "incoherence_only":    dict(incoherence=True,  rescale=False, spectrum_range=False, permute=True),
    "rescale+incoherence": dict(incoherence=True,  rescale=True,  spectrum_range=False, permute=True),
    "full_incp":           dict(incoherence=True,  rescale=True,  spectrum_range=True,  permute=True),
    "full_no_permute":     dict(incoherence=True,  rescale=True,  spectrum_range=True,  permute=False),
}


def run(args) -> dict:
    cfg, model, params = trained_lm(steps=args.train_steps)
    calib = make_calibration(cfg.vocab, n_segments=16, seg_len=128, seed=7)
    eval_toks = make_calibration(cfg.vocab, n_segments=8, seg_len=128,
                                 seed=99).tokens
    results = {}
    bits_list = [2] if args.quick else [3, 2]
    for bits in bits_list:
        for name, kw in VARIANTS.items():
            t0 = time.time()
            qcfg = QuipConfig(bits=bits, method="ldlq", use_kernel=False, **kw)
            qm = quantize_dense_model(params, cfg, qcfg, calib.tokens,
                                      verbose=False)
            ppl = perplexity(qm.logits, eval_toks)
            results[f"{name}@{bits}b"] = ppl
            emit(f"ablation_incp/{name}@{bits}b", (time.time() - t0) * 1e6,
                 f"ppl={ppl:.2f}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/ablation_incoherence.json")
    args = ap.parse_args(argv)
    results = run(args)
    print(json.dumps(results, indent=1))
    if args.out:
        import pathlib

        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
