"""starcoder2-15b [dense] — GQA kv=4, RoPE, GeLU MLP w/ bias — arXiv:2402.19173."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    mlp="gelu",
    mlp_bias=True,
    qkv_bias=True,
    rope_theta=1e5,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        mlp="gelu",
        mlp_bias=True,
        qkv_bias=True,
        dtype="float32",
        microbatch=2,
        remat="none",
    )
