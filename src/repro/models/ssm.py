"""Attention-free sequence mixers: Mamba2 (SSD) and RWKV6 ("Finch").

Both are linear-attention-family recurrences

    h_t = diag(d_t) h_{t-1} + k_t^T v_t,      y_t = q_t h_t (+ bonus)

trained with a *chunked* algorithm: intra-chunk terms are attention-like
matmuls with decay masks, inter-chunk state is carried by a `lax.scan` over
chunks — O(T·c) work, compact HLO, no T-length sequential scan in the
forward graph.  A naive per-step scan is kept as the test oracle
(`*_scan_ref`).  Decode is the O(1) recurrence on an explicit state.

Numerical safety: all decay factors are applied as exp(Δlog) with Δlog ≤ 0
wherever possible.  RWKV6's per-channel decay requires the factored form
exp(+cum)·exp(−cum); we clamp log-decay to ≥ −4 and use chunk length 16 so
the factored exponentials stay well inside fp32 range (DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import _init_dense, _key, rms_norm
from repro.runtime.sharding import constrain

# ===========================================================================
# Mamba2
# ===========================================================================


def init_mamba2(key, cfg: ArchConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N  # conv over [x, B, C]
    return {
        # in_proj -> [z (di), x (di), B (N), C (N), dt (H)]
        "in_proj": _init_dense(
            _key(key, "in"), (d, 2 * di + 2 * N + H), dt
        ),
        "conv_w": _init_dense(_key(key, "conv"), (cfg.ssm_conv, conv_dim), dt, 0.2),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),  # A = -exp(A_log), per head
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((di,), dt),  # gated RMSNorm scale
        "out_proj": _init_dense(
            _key(key, "out"), (di, d), dt,
            scale=(di**-0.5) / math.sqrt(2 * max(cfg.n_layers, 1)),
        ),
    }


def mamba2_axes(cfg: ArchConfig) -> dict:
    return {
        "in_proj": ("embed", "ff"),
        "conv_w": ("conv", None),
        "conv_b": (None,),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": ("norm",),
        "out_proj": ("ff", "embed"),
    }


def _mamba2_split(p, cfg: ArchConfig, u: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = u @ p["in_proj"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N :]  # (.., H)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array):
    """Depthwise causal conv along seq.  xBC: (B, T, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    return jax.nn.silu(out + b)


def mamba2_forward(
    p: dict,
    u: jax.Array,
    cfg: ArchConfig,
    *,
    chunk: int = 128,
    return_state: bool = False,
):
    """Chunked SSD forward.  u: (B, T, D) -> (B, T, D)."""
    B, T, _ = u.shape
    di, N, H, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dtr = _mamba2_split(p, cfg, u)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    x = xBC[..., :di].reshape(B, T, H, hd)
    Bm = xBC[..., di : di + N]  # (B, T, N) shared across heads (G=1)
    Cm = xBC[..., di + N :]  # (B, T, N)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B, T, H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    log_a = dt * A  # (B, T, H) <= 0: per-step log decay

    c = min(chunk, T)
    while T % c:
        c -= 1
    nc = T // c
    xc = x.reshape(B, nc, c, H, hd)
    Bc = Bm.reshape(B, nc, c, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, c, N).astype(jnp.float32)
    dtc = dt.reshape(B, nc, c, H)
    lac = log_a.reshape(B, nc, c, H)
    cum = jnp.cumsum(lac, axis=2)  # inclusive (B, nc, c, H)

    mask = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(h, inp):
        """h: (B, H, hd, N) carried state (fp32)."""
        xq, Bq, Cq, dtq, cumq = inp  # leading B axis
        # intra-chunk: M[t,s] = CB[t,s] * exp(cum_t - cum_s) * dt_s  (t >= s)
        CB = jnp.einsum("btn,bsn->bts", Cq, Bq)  # (B, c, c)
        dlt = cumq[:, :, None, :] - cumq[:, None, :, :]  # (B, c, c, H) t,s
        dec = jnp.exp(jnp.where(mask[None, :, :, None], dlt, -jnp.inf))
        M = CB[..., None] * dec * dtq[:, None, :, :]  # (B, c, c, H)
        y_intra = jnp.einsum("btsh,bshd->bthd", M, xq.astype(jnp.float32))
        # inter-chunk: y_t += exp(cum_t) * C_t . h_prev
        y_inter = jnp.einsum(
            "btn,bhdn->bthd", Cq, h
        ) * jnp.exp(cumq)[..., None]
        # state update: h' = exp(cum_last)*h + sum_s exp(cum_last - cum_s) dt_s x_s B_s^T
        cum_last = cumq[:, -1, :]  # (B, H)
        w = jnp.exp(cum_last[:, None, :] - cumq) * dtq  # (B, c, H)
        dh = jnp.einsum(
            "bsh,bshd,bsn->bhdn", w, xq.astype(jnp.float32), Bq
        )
        h_new = jnp.exp(cum_last)[:, :, None, None] * h + dh
        return h_new, (y_intra + y_inter).astype(u.dtype)

    h0 = jnp.zeros((B, H, hd, N), jnp.float32)
    xs = (
        jnp.moveaxis(xc, 1, 0),
        jnp.moveaxis(Bc, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
        jnp.moveaxis(dtc, 1, 0),
        jnp.moveaxis(cum, 1, 0),
    )
    h_last, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, hd)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, T, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    out = constrain(out, ("batch", "seq", "act_embed"))
    if return_state:
        conv_state = xBC_tail_state(p, cfg, u)
        return out, {"ssm": h_last, "conv": conv_state}
    return out


def xBC_tail_state(p, cfg: ArchConfig, u: jax.Array):
    """Last (K-1) pre-conv xBC rows, the decode-time conv state."""
    _, xBC_pre, _ = _mamba2_split(p, cfg, u)
    K = cfg.ssm_conv
    return xBC_pre[:, -(K - 1) :, :].astype(jnp.float32)


def init_mamba2_state(cfg: ArchConfig, batch: int) -> dict:
    di, N, H, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((batch, H, hd, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), jnp.float32),
    }


def mamba2_state_axes() -> dict:
    return {"ssm": ("batch", None, None, None), "conv": ("batch", None, None)}


def mamba2_decode_step(p: dict, u: jax.Array, cfg: ArchConfig, state: dict):
    """u: (B, 1, D); O(1) recurrence.  Returns (y (B,1,D), new_state)."""
    B = u.shape[0]
    di, N, H, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC_pre, dtr = _mamba2_split(p, cfg, u)
    xBC_pre = xBC_pre[:, 0].astype(jnp.float32)  # (B, conv_dim)
    conv = state["conv"]  # (B, K-1, conv_dim)
    window = jnp.concatenate([conv, xBC_pre[:, None, :]], axis=1)  # (B, K, C)
    w = p["conv_w"].astype(jnp.float32)
    xBC = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(jnp.float32)
    )
    new_conv = window[:, 1:, :]
    x = xBC[:, :di].reshape(B, H, hd)
    Bm = xBC[:, di : di + N]
    Cm = xBC[:, di + N :]
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, H)
    a = jnp.exp(dt * (-jnp.exp(p["A_log"])))  # (B, H)
    h = state["ssm"]
    h = a[:, :, None, None] * h + jnp.einsum(
        "bh,bhd,bn->bhdn", dt, x, Bm
    )
    y = jnp.einsum("bn,bhdn->bhd", Cm, h) + p["D"][None, :, None] * x
    y = y.reshape(B, 1, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"ssm": h, "conv": new_conv}


def mamba2_scan_ref(p: dict, u: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Naive per-step oracle (tests only)."""
    B, T, _ = u.shape
    state = init_mamba2_state(cfg, B)
    ys = []
    for t in range(T):
        y, state = mamba2_decode_step(p, u[:, t : t + 1], cfg, state)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)


# ===========================================================================
# RWKV6 ("Finch": data-dependent decay)
# ===========================================================================

_LOGW_MIN = -4.0  # per-step log-decay clamp (chunked-form fp32 safety)


def init_rwkv6(key, cfg: ArchConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    H = d // cfg.rwkv_head_size
    ml, dl = cfg.rwkv_mix_lora, cfg.rwkv_decay_lora
    p = {
        # token-shift data-dependent mixing (5 targets: r, k, v, g, w)
        "mu_x": jnp.full((d,), 0.5, dt),
        "mu": jnp.full((5, d), 0.5, dt),
        "mix_w1": _init_dense(_key(key, "mw1"), (d, 5 * ml), dt, 0.02),
        "mix_w2": _init_dense(_key(key, "mw2"), (5, ml, d), dt, 0.02),
        # projections
        "wr": _init_dense(_key(key, "wr"), (d, d), dt),
        "wk": _init_dense(_key(key, "wk"), (d, d), dt),
        "wv": _init_dense(_key(key, "wv"), (d, d), dt),
        "wg": _init_dense(_key(key, "wg"), (d, d), dt),
        "wo": _init_dense(
            _key(key, "wo"), (d, d), dt,
            scale=(d**-0.5) / math.sqrt(2 * max(cfg.n_layers, 1)),
        ),
        # data-dependent decay LoRA: logw = -exp(w0 + tanh(x A) B)
        "w0": jnp.zeros((d,), jnp.float32),
        "decay_A": _init_dense(_key(key, "dA"), (d, dl), dt, 0.02),
        "decay_B": _init_dense(_key(key, "dB"), (dl, d), dt, 0.02),
        "bonus": jnp.zeros((H, cfg.rwkv_head_size), jnp.float32),  # u
        "ln_x": jnp.ones((d,), dt),  # per-head group norm scale
    }
    return p


def rwkv6_axes() -> dict:
    return {
        "mu_x": (None,),
        "mu": (None, None),
        "mix_w1": ("embed", None),
        "mix_w2": (None, None, "embed"),
        "wr": ("embed", "heads"),
        "wk": ("embed", "heads"),
        "wv": ("embed", "heads"),
        "wg": ("embed", "heads"),
        "wo": ("heads", "embed"),
        "w0": (None,),
        "decay_A": ("embed", None),
        "decay_B": (None, "embed"),
        "bonus": (None, None),
        "ln_x": ("norm",),
    }


def init_channel_mix(key, cfg: ArchConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_r": jnp.full((d,), 0.5, dt),
        "wk": _init_dense(_key(key, "cwk"), (d, f), dt),
        "wv": _init_dense(
            _key(key, "cwv"), (f, d), dt,
            scale=(f**-0.5) / math.sqrt(2 * max(cfg.n_layers, 1)),
        ),
        "wr": _init_dense(_key(key, "cwr"), (d, d), dt),
    }


def channel_mix_axes() -> dict:
    return {
        "mu_k": (None,),
        "mu_r": (None,),
        "wk": ("embed", "ff"),
        "wv": ("ff", "embed"),
        "wr": ("embed", "heads"),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]):
    """Previous-token features: (B, T, D) -> (B, T, D) shifted right."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _rwkv_mix(p, x, xprev):
    """Data-dependent lerp producing the 5 mixed inputs (r, k, v, g, w)."""
    dx = xprev - x
    xxx = x + dx * p["mu_x"]
    ml = p["mix_w2"].shape[1]
    lora = jnp.tanh(xxx @ p["mix_w1"])  # (B, T, 5*ml)
    B_, T_, _ = lora.shape
    lora = lora.reshape(B_, T_, 5, ml)
    adjust = jnp.einsum("btfm,fmd->btfd", lora, p["mix_w2"])  # (B,T,5,D)
    mixed = x[:, :, None, :] + dx[:, :, None, :] * (
        p["mu"][None, None] + adjust
    )
    return [mixed[:, :, i, :] for i in range(5)]


def _rwkv_logw(p, xw):
    """Per-channel log decay in [-4, ~0)."""
    z = p["w0"] + (jnp.tanh(xw @ p["decay_A"]) @ p["decay_B"]).astype(jnp.float32)
    logw = -jnp.exp(jnp.clip(z, -12.0, math.log(-_LOGW_MIN)))
    return jnp.maximum(logw, _LOGW_MIN)


def rwkv6_time_mix(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    chunk: int = 16,
    shift_state: Optional[jax.Array] = None,
    wkv_state: Optional[jax.Array] = None,
    return_state: bool = False,
):
    """RWKV6 time mixing, chunked.  x: (B, T, D)."""
    B, T, D = x.shape
    hs = cfg.rwkv_head_size
    H = D // hs
    xprev = _token_shift(x, shift_state)
    xr, xk, xv, xg, xw = _rwkv_mix(p, x, xprev)
    r = (xr @ p["wr"]).reshape(B, T, H, hs).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, T, H, hs).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, T, H, hs).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    logw = _rwkv_logw(p, xw).reshape(B, T, H, hs)  # (B,T,H,hs) per-channel

    c = min(chunk, T)
    while T % c:
        c -= 1
    nc = T // c
    rc = r.reshape(B, nc, c, H, hs)
    kc = k.reshape(B, nc, c, H, hs)
    vc = v.reshape(B, nc, c, H, hs)
    wc = logw.reshape(B, nc, c, H, hs)
    cum = jnp.cumsum(wc, axis=2)  # inclusive per-channel log decay
    u = p["bonus"]  # (H, hs)

    # strict causal mask (s < t); the s == t term is the bonus
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)

    def chunk_step(S, inp):
        """S: (B, H, hs_k, hs_v) carried wkv state."""
        rq, kq, vq, cumq, wq = inp
        # contribution of s<t to y_t: (r_t ⊙ e^{cum_{t-1}}) (k_s ⊙ e^{-cum_s})
        # cum_{t-1} = cum_t - w_t
        r_dec = rq * jnp.exp(cumq - wq)  # (B, c, H, hs)
        k_dec = kq * jnp.exp(-cumq)
        att = jnp.einsum("bthn,bshn->bhts", r_dec, k_dec)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhts,bshn->bthn", att, vq)
        # bonus (current token)
        rk = jnp.einsum("bthn,bthn->bth", rq * u[None, None], kq)
        y_bonus = rk[..., None] * vq
        # inter-chunk: y_t += (r_t ⊙ e^{cum_{t-1}}) . S_prev
        y_inter = jnp.einsum("bthn,bhnm->bthm", r_dec, S)
        # state update: S' = diag(e^{cum_last}) S + Σ_s (k_s e^{cum_last - cum_s}) v_s
        cum_last = cumq[:, -1]  # (B, H, hs)
        k_up = kq * jnp.exp(cum_last[:, None] - cumq)
        S_new = (
            jnp.exp(cum_last)[..., None] * S
            + jnp.einsum("bshn,bshm->bhnm", k_up, vq)
        )
        return S_new, y_intra + y_bonus + y_inter

    S0 = (
        wkv_state
        if wkv_state is not None
        else jnp.zeros((B, H, hs, hs), jnp.float32)
    )
    xs = tuple(
        jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, cum, wc)
    )
    S_last, ys = jax.lax.scan(chunk_step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, hs)
    # per-head group norm then gate
    y = rms_norm(y, jnp.ones((hs,), jnp.float32), 64e-5).reshape(B, T, D)
    y = (y.astype(x.dtype) * p["ln_x"]) * g
    out = y @ p["wo"]
    out = constrain(out, ("batch", "seq", "act_embed"))
    if return_state:
        return out, x[:, -1:, :], S_last
    return out


def rwkv6_time_mix_step(
    p: dict, x: jax.Array, cfg: ArchConfig, shift_state, wkv_state
):
    """Single-token recurrence.  x: (B, 1, D)."""
    B, _, D = x.shape
    hs = cfg.rwkv_head_size
    H = D // hs
    xprev = shift_state  # (B, 1, D)
    xr, xk, xv, xg, xw = _rwkv_mix(p, x, xprev)
    r = (xr @ p["wr"]).reshape(B, H, hs).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, H, hs).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, H, hs).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(_rwkv_logw(p, xw).reshape(B, H, hs))
    u = p["bonus"]
    # y = r . (S + u ⊙ k^T v)
    kv = jnp.einsum("bhn,bhm->bhnm", k, v)
    y = jnp.einsum("bhn,bhnm->bhm", r, wkv_state + u[None, :, :, None] * kv)
    S_new = w[..., None] * wkv_state + kv
    y = rms_norm(y, jnp.ones((hs,), jnp.float32), 64e-5).reshape(B, 1, D)
    y = (y.astype(x.dtype) * p["ln_x"]) * g
    return y @ p["wo"], x, S_new


def channel_mix(p: dict, x: jax.Array, shift_state=None, return_state=False):
    xprev = _token_shift(x, shift_state)
    xk = x + (xprev - x) * p["mu_k"]
    xr = x + (xprev - x) * p["mu_r"]
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    h = constrain(h, ("batch", "seq", "act_ff"))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"])
    if return_state:
        return out, x[:, -1:, :]
    return out


def channel_mix_step(p: dict, x: jax.Array, shift_state):
    xprev = shift_state
    xk = x + (xprev - x) * p["mu_k"]
    xr = x + (xprev - x) * p["mu_r"]
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"]), x


def rwkv6_scan_ref(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Per-step oracle for the chunked time-mix (tests only)."""
    B, T, D = x.shape
    hs = cfg.rwkv_head_size
    H = D // hs
    shift = jnp.zeros((B, 1, D), x.dtype)
    S = jnp.zeros((B, H, hs, hs), jnp.float32)
    ys = []
    for t in range(T):
        y, shift, S = rwkv6_time_mix_step(p, x[:, t : t + 1], cfg, shift, S)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
