"""Pure-jnp oracle for the fused Kronecker transform."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kron_mul_ref(x: jax.Array, A: jax.Array, B: jax.Array) -> jax.Array:
    """y = (A ⊗ B) x per row; x: (..., p*q)."""
    p, q = A.shape[0], B.shape[0]
    X = x.reshape(*x.shape[:-1], p, q)
    Y = jnp.einsum("ji,...iq->...jq", A, X)
    Y = jnp.einsum("...jq,kq->...jk", Y, B)
    return Y.reshape(*x.shape[:-1], p * q).astype(x.dtype)


def kron_mul_dense_ref(x: jax.Array, A: jax.Array, B: jax.Array) -> jax.Array:
    """Materialized (A ⊗ B) matmul — the thing the kernel avoids."""
    U = jnp.kron(A, B)
    return (x @ U.T).astype(x.dtype)
