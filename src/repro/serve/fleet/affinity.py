"""Sticky prefix-affinity routing (DESIGN.md §15).

Each replica's prefix-cache trie is per-process: a shared prompt header
only pays off when its requests land on the replica that already holds
the pages.  The router hashes the prompt HEADER (the first
``header_len`` tokens — page-aligned workloads share exactly these) and
picks a replica by rendezvous (highest-random-weight) hashing:

- stable: the same header always prefers the same replica, across
  router restarts and regardless of replica health churn (the
  preference is computed over ALL replica slots, healthy or not, so a
  replica that bounces gets its old traffic back);
- minimally disruptive: when the preferred replica is out, only ITS
  headers move (to their second-choice replica) — rendezvous hashing's
  defining property, no ring to rebuild.

Hashes are ``zlib.crc32`` — process-stable (``hash()`` is salted by
PYTHONHASHSEED) and already the repo's idiom for cross-process
determinism (shadow selection, param init).
"""
from __future__ import annotations

import zlib

import numpy as np

__all__ = ["prefix_key", "rendezvous_rank"]


def prefix_key(prompt, header_len: int = 16) -> int:
    """Affinity key for a prompt: crc32 over its first ``header_len``
    token ids (int32 little-endian bytes).  Prompts sharing a header at
    least that long share a key — the same prefix granularity the trie
    caches at page size 16."""
    head = np.asarray(prompt, np.int32).reshape(-1)[:header_len]
    return zlib.crc32(head.astype("<i4").tobytes())


def rendezvous_rank(key: int, n: int) -> list:
    """Replica indices ranked by rendezvous weight for ``key`` (best
    first): each (key, replica) pair gets an independent crc32 score;
    the ranking is stable per key and uniform across keys.  ``n`` is the
    fleet's replica-slot count — rank over ALL slots and let the caller
    skip unavailable ones, so stickiness survives a bounce."""
    if n < 1:
        raise ValueError(f"need at least one replica slot, got {n}")
    scores = [
        (zlib.crc32(f"{key}:{i}".encode()), i) for i in range(n)
    ]
    scores.sort(key=lambda s: (-s[0], s[1]))
    return [i for _, i in scores]
