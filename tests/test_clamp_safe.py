"""Algorithm 5 (clamp-safe rounding, Theorem 7) tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_hessian

from repro.core.clamp_safe import clamp_safe_round, solve_clamp_safe_L
from repro.core.ldlq import ldl_decomposition, ldlq, quantize_nearest
from repro.core.proxy import proxy_loss


def _counterexample(n=64, d=16, c=0.01):
    H = np.ones((n, n)) + np.eye(n)
    H[n - 1, n - 1] = 1.0
    H[0, 1 : n - 1] += 2 * c
    H[1 : n - 1, 0] += 2 * c
    H[0, n - 1] += c
    H[n - 1, 0] += c
    H[0, 0] += 4 * c + n * c**2
    W = 0.499 * np.ones((d, n)) + 0.002 * (np.arange(n) % 2)
    return jnp.asarray(W, jnp.float32), jnp.asarray(H, jnp.float32)


def test_solution_is_feasible_unit_upper():
    H = make_hessian(48, seed=1)
    c = 0.3
    L = solve_clamp_safe_L(H, c)
    n = H.shape[0]
    # unit upper triangular
    np.testing.assert_allclose(np.diag(np.asarray(L)), np.ones(n), atol=1e-5)
    assert float(jnp.max(jnp.abs(jnp.tril(L, -1)))) < 1e-6
    # column-norm constraint e_i^T L^T L e_i <= 1 + c
    col_sq = np.sum(np.asarray(L) ** 2, axis=0)
    assert col_sq.max() <= 1 + c + 1e-4


def test_large_c_recovers_ldl():
    """With the constraint slack, the optimum is the LDL factor."""
    H = make_hessian(32, seed=2, damp=1e-1)
    L = solve_clamp_safe_L(H, c=1e6, iters=500)
    Udot, _ = ldl_decomposition(H)
    Linv_expected = jnp.eye(32) + Udot  # L^{-1} from the LDL factor
    Lres = np.asarray(L @ Linv_expected)
    np.testing.assert_allclose(Lres, np.eye(32), atol=5e-2)


def test_objective_no_worse_than_projected_start():
    H = make_hessian(40, seed=3)
    c = 0.2
    L = solve_clamp_safe_L(H, c, iters=400)
    obj = float(jnp.trace(H @ L.T @ L))
    # identity L is always feasible: solver must beat or match it
    obj_eye = float(jnp.trace(H))
    assert obj <= obj_eye * 1.0001


def test_beats_clamped_ldlq_on_counterexample():
    """Fig. 4 / Thm 7: where clamping breaks LDLQ, Algorithm 5 survives."""
    W, H = _counterexample()
    maxq = 15
    Udot, _ = ldl_decomposition(H)
    l_ldlq = float(proxy_loss(ldlq(W, Udot, maxq), W, H))
    l_safe = float(
        proxy_loss(
            clamp_safe_round(W, H, maxq, jax.random.PRNGKey(0), c=0.1),
            W, H,
        )
    )
    assert l_safe < l_ldlq * 0.25, (l_safe, l_ldlq)


def test_rounded_weights_stay_in_range():
    W, H = _counterexample()
    out = np.asarray(clamp_safe_round(W, H, 15, jax.random.PRNGKey(1), c=0.1))
    assert out.min() >= 0.0 and out.max() <= 15.0
    assert set(np.unique(out)) <= set(float(v) for v in range(16))
