"""Unified cached prefill/decode forward over fp and QuIP-quantized models.

A :class:`CachedDecoder` holds per-layer *blocks*: norm params plus one
callable per linear projection, keyed exactly like
``launch.quantize.QuantizedModel.blocks`` ("attn.wq", ..., "mlp.wo").  For
the fp ``Model`` the callables close over dense params (``layers.apply_w``);
for a ``QuantizedModel`` they ARE the :class:`QuantizedLinear` layers, so
every projection runs the packed ``D⁻¹ → V → quant_matmul → Uᵀ`` structured
path — this replaces the old per-token full-recompute serving loop with a
real KV-cached decode for quantized weights.

Two decode paths share the block structure:

  * **gather-dense (reference oracle)** — :meth:`__call__`: the engine
    gathers every context page into a dense ``(L, B, S, KV, hd)`` window
    and the forward concatenates new K/V.  Handles chunked prefill
    (``tokens (1, C)``) and batched decode (``tokens (B, 1)``).
  * **paged fast path** — :meth:`decode_paged`: one jitted dispatch that
    (1) runs every projection — routing ``QuantizedLinear`` through the
    Pallas ``quant_matmul`` kernel path instead of the XLA unpack
    fallback, (2) computes attention *in place* against the physical page
    pool via ``kernels.paged_attention`` (per-lane block tables + context
    lengths, self-token folded in analytically), and (3) scatters the new
    K/V into the donated pool tensors.  No per-step dense KV copy exists
    anywhere in this path.

Prefill has the same split: the gather-dense path runs one ``(1, C)``
chunk per request, while :meth:`prefill_paged` runs a whole padded
cross-request chunk batch ``(B, C)`` as one jitted dispatch — projections
through the ``quant_matmul`` kernel dispatch, causal chunk attention over
the page pool via ``kernels.paged_attention.paged_gqa_prefill`` (ragged
per-lane prior-context lengths), and a donated in-place scatter of every
chunk token's K/V (padded tails land on the scratch page).

Two further fused entries ride the same trunks:

  * :meth:`verify_paged` — speculative draft-and-verify: a ``(B, K+1)``
    chunk batch ``[last_emitted, d_1 .. d_K]`` per decode lane runs the
    PREFILL trunk (``paged_gqa_verify`` — the chunked-prefill kernel
    reused as the verifier), then selects a token at every chunk position
    ON DEVICE (:func:`sample_tokens`) and counts the longest accepted
    draft prefix — one dispatch emits up to K+1 tokens per lane;
  * :meth:`decode_paged_sample` — the one-token decode dispatch with the
    same on-device selection epilogue fused in, so non-speculative
    serving also never ships logits to the host.

On-device selection is a pure function of (request seed, emission index)
via ``jax.random.fold_in``, so sampled streams are reproducible across
batch composition, scheduling, eviction/replay, and speculative grouping
— and a greedy (temperature-0) lane is the exact argmax, which keeps the
speculative path token-identical to one-token decode.  For int8 pools the
verify trunk round-trips the chunk's own K/V through the page quantizer
before attention, matching what the one-token path would read back from
the pool for already-scattered draft tokens (DESIGN.md §10).

Masking uses the same where-set convention as the quantized recompute path
so cached logits match it bit-for-bit up to matmul reassociation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.quantizer import QuantizedLinear
from repro.kernels.paged_attention.ops import (
    paged_gqa_decode,
    paged_gqa_prefill,
    paged_gqa_verify,
)
from repro.models import layers as L
from repro.models.transformer import unstack_layers
from repro.serve.faults import NO_FAULTS, FaultPlan
from repro.serve.kv_cache import PagedKVPool, quantize_kv_int8
from repro.serve.telemetry import NULL_TRACER, Tracer

__all__ = ["CachedDecoder", "sample_tokens"]


def _poison_lanes(logits, lanes):
    """Overwrite the given batch lanes of ``logits`` with NaN — the
    nan_logits fault: exactly what a rotted artifact or an unstable
    kernel would hand the sampler.  Fault path only (never jitted)."""
    if not lanes:
        return logits
    out = np.asarray(logits).copy()
    out[np.asarray(lanes, np.int32)] = np.nan
    return jnp.asarray(out)


def sample_tokens(logits, temps, top_ps, seeds, draws, greedy_only=False):
    """Fused on-device token selection over a step's logits.

    logits (B, T, V); temps/top_ps (B,) fp32; seeds/draws (B,) int32 —
    ``draws[b]`` is how many tokens lane b has already drawn, so chunk
    position t selects with the per-request key
    ``fold_in(PRNGKey(seeds[b]), draws[b] + t)``: the stream is a pure
    function of (seed, emission index), hence reproducible across batch
    composition, scheduling order, eviction/replay, and speculative
    grouping — a verify tick draws exactly the token sequential decode
    would have drawn at each position.  ``temp == 0`` lanes take the
    exact argmax (the greedy/--check path).  Top-p keeps the smallest
    sorted prefix with mass >= top_p (always at least the head), the same
    rule as the host path.  Returns (B, T) int32.

    ``greedy_only`` (static) compiles out the whole draw: when the caller
    knows every lane is temperature-0 (the common serving case and every
    ``--check``), the dispatch carries only the argmax — the sort/scan/
    PRNG sub-graph would otherwise dominate a smoke-scale verify tick.
    """
    T, V = logits.shape[1], logits.shape[2]
    greedy = jnp.argmax(logits, axis=-1)
    if greedy_only:
        return greedy.astype(jnp.int32)

    def draw(lg, temp, top_p, seed, idx):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), idx)
        z = lg.astype(jnp.float32) / jnp.where(temp > 0.0, temp, 1.0)
        p = jax.nn.softmax(z)
        order = jnp.argsort(-p)
        ps = p[order]
        csum = jnp.cumsum(ps)
        # nucleus filter; the head always survives (SamplingParams pins
        # top_p > 0, but a degenerate caller must get argmax, not tail)
        keep = (csum - ps < top_p).at[0].set(True)
        ps = jnp.where(keep, ps, 0.0)
        u = jax.random.uniform(key) * ps.sum()  # inverse-CDF, unnormalized
        pick = jnp.searchsorted(jnp.cumsum(ps), u, side="right")
        return order[jnp.clip(pick, 0, V - 1)]

    sampled = jax.vmap(  # lanes x chunk positions
        lambda lg, tp, pp, sd, d0: jax.vmap(
            lambda l1, t: draw(l1, tp, pp, sd, d0 + t)
        )(lg, jnp.arange(T, dtype=jnp.int32))
    )(logits, temps, top_ps, seeds, draws)
    return jnp.where(temps[:, None] > 0.0, sampled, greedy).astype(jnp.int32)


def _int8_roundtrip(x: jax.Array) -> jax.Array:
    """Quantize-dequantize through the int8 page quantizer: the value a
    later read of this token's K/V would see after the pool scatter."""
    q, s = quantize_kv_int8(x)
    return (q.astype(jnp.float32) * s[..., None]).astype(x.dtype)


def _linear(p, cfg: ArchConfig, bias=None) -> Callable:
    if bias is None:
        return lambda x: L.apply_w(p, x, cfg)
    return lambda x: L.apply_w(p, x, cfg) + bias


def _fp_blocks(params, cfg: ArchConfig) -> list[dict]:
    blocks = []
    for lp in unstack_layers(params):
        at, mp = lp["attn"], lp["mlp"]
        blk = {
            "ln1": lp["ln1"],
            "ln2": lp["ln2"],
            "attn.wq": _linear(at["wq"], cfg, at.get("bq")),
            "attn.wk": _linear(at["wk"], cfg, at.get("bk")),
            "attn.wv": _linear(at["wv"], cfg, at.get("bv")),
            "attn.wo": _linear(at["wo"], cfg),
            "mlp.wi": _linear(mp["wi"], cfg, mp.get("bi")),
            "mlp.wo": _linear(mp["wo"], cfg, mp.get("bo")),
        }
        if cfg.mlp == "swiglu":
            blk["mlp.wg"] = _linear(mp["wg"], cfg)
        if cfg.qk_norm:
            blk["q_norm"] = at["q_norm"]
            blk["k_norm"] = at["k_norm"]
        blocks.append(blk)
    return blocks


@dataclasses.dataclass
class CachedDecoder:
    """KV-cached forward shared by the fp and quantized serving paths."""

    cfg: ArchConfig
    embed: dict
    final_norm: dict
    blocks: list
    paged: bool = False  # engine default: decode via the paged fast path
    paged_interpret: bool = False  # force the Pallas kernel (interpret) off-TPU
    # span sink for the fused dispatches; Engine.attach_tracer swaps in
    # its live tracer (the NULL_TRACER default costs one no-op call)
    tracer: Tracer = dataclasses.field(default=NULL_TRACER, repr=False)
    # fault-injection plan (serve/faults.py); the engine points this at
    # its own plan and maintains the dispatch context (tick, lane_rids).
    # Hooks on the inert default iterate an empty rule list.
    faults: FaultPlan = dataclasses.field(default=NO_FAULTS, repr=False)

    def __post_init__(self):
        if self.cfg.family != "dense":
            raise ValueError(
                f"serving adapter supports the dense family, got {self.cfg.family}"
            )
        # blocks close over their params -> jit treats them as constants;
        # one compile per (adapter, tokens/ctx shape) pair.
        self._fwd = jax.jit(self._forward)
        # fused decode: pool tensors are donated and updated in place by
        # the trailing scatter — one dispatch per engine decode step.
        self._fwd_paged = jax.jit(self._forward_paged, donate_argnums=(6, 7))
        self._fwd_paged_q = jax.jit(
            self._forward_paged_q, donate_argnums=(6, 7, 8, 9)
        )
        # fused batched prefill: same donation contract, one dispatch per
        # engine prefill tick over the whole cross-request chunk batch.
        self._fwd_prefill = jax.jit(
            self._forward_prefill_paged, donate_argnums=(6, 7)
        )
        self._fwd_prefill_q = jax.jit(
            self._forward_prefill_paged_q, donate_argnums=(6, 7, 8, 9)
        )
        # fused decode + on-device selection (non-speculative fast path);
        # the trailing static bool picks the all-greedy argmax-only graph
        self._fwd_paged_s = jax.jit(
            self._forward_paged_sample, donate_argnums=(10, 11),
            static_argnums=(12,),
        )
        self._fwd_paged_sq = jax.jit(
            self._forward_paged_sample_q, donate_argnums=(10, 11, 12, 13),
            static_argnums=(14,),
        )
        # fused speculative verify: prefill trunk + on-device selection +
        # draft acceptance, one dispatch per engine verify tick
        self._fwd_verify = jax.jit(
            self._forward_verify, donate_argnums=(12, 13),
            static_argnums=(14,),
        )
        self._fwd_verify_q = jax.jit(
            self._forward_verify_q, donate_argnums=(12, 13, 14, 15),
            static_argnums=(16,),
        )
        # quality probe: dense teacher-forced forward + per-layer
        # activation reductions (serve/quality.py canaries); compiles
        # only if a canary actually runs
        self._fwd_probe = jax.jit(self._forward_probe)

    # ---- constructors ---------------------------------------------------

    @classmethod
    def from_model(cls, model, params, **kw) -> "CachedDecoder":
        return cls(
            cfg=model.cfg,
            embed=params["embed"],
            final_norm=params["final_norm"],
            blocks=_fp_blocks(params, model.cfg),
            **kw,
        )

    @classmethod
    def from_quantized(cls, qm, **kw) -> "CachedDecoder":
        # QuantizedModel.blocks already has the expected structure, with
        # QuantizedLinear instances as the projection callables.
        return cls(
            cfg=qm.cfg, embed=qm.embed, final_norm=qm.final_norm,
            blocks=qm.blocks, **kw,
        )

    # ---- engine hooks ----------------------------------------------------

    def trace_tags(self) -> dict:
        """Static tags merged into every span/event this adapter's tracer
        records (Engine.attach_tracer calls this once).  Distributed
        adapters override with mesh geometry."""
        return {}

    def make_pool(self, **kw) -> PagedKVPool:
        """Build the engine's KV pool.  Distributed adapters override this
        to place the physical pages sharded over their mesh."""
        return PagedKVPool(self.cfg, **kw)

    def _place(self, x, dtype=None):
        """Device placement for small per-step host arrays (tokens, block
        tables, context lengths, page addresses).  Distributed adapters
        override to commit them replicated on the mesh."""
        return jnp.asarray(x, dtype)

    def _place_tree(self, arrays: tuple):
        """Place a whole step's small host arrays in ONE device_put call
        (a tuple pytree) — per-array placement round-trips dominate a
        smoke-scale dispatch.  Distributed adapters override to commit
        the tuple replicated on the mesh."""
        return jax.device_put(arrays)

    # ---- gather-dense reference path ------------------------------------

    def __call__(self, tokens, positions, ctx_k, ctx_v, ctx_len):
        """Cached forward (gather-dense reference).

        tokens    (B, T) int32 — new tokens (decode: T=1; prefill: B=1);
        positions (B, T) int32 — absolute position of each new token;
        ctx_k/v   (L, B, S, KV, hd) — gathered context pages (post-RoPE K);
        ctx_len   (B,) int32 — valid context tokens per lane.

        Returns (logits (B, T, V), k_new (L, B, T, KV, hd), v_new (same)).
        """
        if self.faults.rules:
            self.faults.check_dispatch()
        logits, k_new, v_new = self._fwd(tokens, positions, ctx_k, ctx_v,
                                         ctx_len)
        if self.faults.rules:
            logits = _poison_lanes(logits, self.faults.nan_lanes())
        return logits, k_new, v_new

    def _forward(self, tokens, positions, ctx_k, ctx_v, ctx_len):
        cfg = self.cfg
        x = L.embed(self.embed, tokens)
        new_k, new_v = [], []
        for i, blk in enumerate(self.blocks):
            x, k, v = self._block(blk, x, positions, ctx_k[i], ctx_v[i], ctx_len)
            new_k.append(k)
            new_v.append(v)
        x = L.norm_apply(self.final_norm, x, cfg)
        logits = L.lm_logits(self.embed, x)
        return logits, jnp.stack(new_k), jnp.stack(new_v)

    def _block(self, blk, x, positions, ck, cv, ctx_len):
        cfg = self.cfg
        B, T, _ = x.shape
        S = ck.shape[1]
        h = L.norm_apply(blk["ln1"], x, cfg)
        q, k, v = self._qkv(blk, h, positions)
        k_all = jnp.concatenate([ck.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([cv.astype(v.dtype), v], axis=1)
        s = L._gqa_scores(q, k_all, cfg)  # (B, KV, G, T, S+T)
        # context keys: valid below each lane's ctx_len; new keys: causal
        # within the chunk (their absolute positions are >= every ctx pos).
        mask_ctx = jnp.arange(S)[None, None, :] < ctx_len[:, None, None]
        mask_ctx = jnp.broadcast_to(mask_ctx, (B, T, S))
        mask_new = jnp.broadcast_to(
            jnp.tril(jnp.ones((T, T), bool))[None], (B, T, T)
        )
        mask = jnp.concatenate([mask_ctx, mask_new], axis=-1)
        s = jnp.where(mask[:, None, None], s, jnp.finfo(s.dtype).min)
        probs = jax.nn.softmax(s, axis=-1)
        o = L._gqa_out(probs, v_all, cfg)
        o = o.astype(x.dtype).reshape(B, T, cfg.q_dim)
        x = x + blk["attn.wo"](o)
        return self._mlp(blk, x), k, v

    # ---- quality probe ---------------------------------------------------

    def activation_probe(self, tokens):
        """Teacher-forced causal forward over full sequences with
        per-layer activation reductions fused into the same dispatch
        (serve/quality.py canary probe; DESIGN.md §13).

        tokens (B, S) int32.  Returns ``(logits (B, S, V) float32 np,
        {"absmax": (L+1,), "sat": (L+1,)})`` — entry i is the hidden
        state entering block i (the residual stream the block's linears
        consume), entry L the final pre-norm hidden state; ``sat`` is
        the fraction of elements at or beyond
        :data:`repro.serve.quality.SAT_THRESHOLD` (an fp16-overflow
        early warning).  The sequence is padded to the next power of two
        (causal attention — pad positions cannot influence real ones,
        and are masked out of the reductions), bounding compiles across
        canary/shadow lengths.  Runs the dense reference trunk with an
        empty context window: the KV pool is never touched, so an
        in-flight engine's traffic stays token-identical.
        """
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 2:
            raise ValueError(f"tokens must be (B, S), got {tokens.shape}")
        B, S = tokens.shape
        Sp = 1
        while Sp < S:
            Sp <<= 1
        padded = np.zeros((B, Sp), np.int32)
        padded[:, :S] = tokens
        positions = np.tile(np.arange(Sp, dtype=np.int32), (B, 1))
        cfg = self.cfg
        ctx = jnp.zeros(
            (cfg.n_layers, B, 0, cfg.n_kv_heads, cfg.head_dim), jnp.float32
        )
        with self.tracer.span("dispatch:activation_probe",
                              lanes=B, tokens=S):
            logits, absmax, sat = self._fwd_probe(
                jnp.asarray(padded), jnp.asarray(positions), ctx, ctx,
                jnp.zeros((B,), jnp.int32), jnp.int32(S),
            )
        return np.asarray(logits[:, :S], np.float32), {
            "absmax": np.asarray(absmax, np.float64),
            "sat": np.asarray(sat, np.float64),
        }

    def _forward_probe(self, tokens, positions, ctx_k, ctx_v, ctx_len,
                       n_valid):
        from repro.serve.quality import SAT_THRESHOLD

        cfg = self.cfg
        B, T = tokens.shape
        valid = (jnp.arange(T, dtype=jnp.int32) < n_valid)[None, :, None]
        n_el = jnp.maximum(n_valid * B, 1)
        absmax, sat = [], []

        def reduce(x):
            ax = jnp.abs(x.astype(jnp.float32)) * valid
            absmax.append(jnp.max(ax))
            sat.append(
                jnp.sum(ax >= SAT_THRESHOLD) / (n_el * x.shape[-1])
            )

        x = L.embed(self.embed, tokens)
        for i, blk in enumerate(self.blocks):
            reduce(x)
            x, _, _ = self._block(
                blk, x, positions, ctx_k[i], ctx_v[i], ctx_len
            )
        reduce(x)
        x = L.norm_apply(self.final_norm, x, cfg)
        logits = L.lm_logits(self.embed, x)
        return logits, jnp.stack(absmax), jnp.stack(sat)

    # ---- shared block pieces --------------------------------------------

    def _proj(self, blk, name, h):
        """Apply one projection; on the paged fast path QuantizedLinear
        goes through the Pallas quant_matmul kernel dispatch (batched
        decode matvec, affine dequant in the epilogue) instead of the XLA
        unpack fallback."""
        f = blk[name]
        if isinstance(f, QuantizedLinear):
            return f(h, use_kernel=True)
        return f(h)

    def _qkv(self, blk, h, positions, *, kernel_proj: bool = False):
        """(q, k, v) each (B, T, heads, hd), qk-normed + RoPE'd."""
        cfg = self.cfg
        B, T, _ = h.shape
        proj = (lambda n: self._proj(blk, n, h)) if kernel_proj else (
            lambda n: blk[n](h)
        )
        q = proj("attn.wq").reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = proj("attn.wk").reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = proj("attn.wv").reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = L.rms_norm(q, blk["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, blk["k_norm"], cfg.norm_eps)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _mlp(self, blk, x, *, kernel_proj: bool = False):
        cfg = self.cfg
        h = L.norm_apply(blk["ln2"], x, cfg)
        proj = (lambda n, z: self._proj(blk, n, z)) if kernel_proj else (
            lambda n, z: blk[n](z)
        )
        up = proj("mlp.wi", h)
        if cfg.mlp == "swiglu":
            up = jax.nn.silu(up) * proj("mlp.wg", h)
        else:
            up = jax.nn.gelu(up)
        return x + proj("mlp.wo", up)

    # ---- paged fast path -------------------------------------------------

    def decode_paged(self, tokens, positions, block_tables, ctx_len,
                     pages, offs, pool):
        """Fused decode step against ``pool`` (PagedKVPool), in place.

        tokens/positions (B, 1) int32; block_tables (B, Pa) int32 bucketed
        to the attended prefix; ctx_len (B,) int32; pages/offs (B,) int32
        physical address of each lane's new token (scratch for pad lanes).

        Mutates ``pool.k``/``pool.v`` (+ scales for int8 pools) via donated
        buffers and returns logits (B, 1, V).  The caller still owns the
        host-side length accounting (``pool.note_written``).
        """
        if self.faults.rules:
            self.faults.check_dispatch()
        toks = np.asarray(tokens, np.int32)
        with self.tracer.span("dispatch:decode_paged", lanes=toks.shape[0]):
            args = self._place_tree((
                toks, np.asarray(positions, np.int32),
                np.asarray(block_tables, np.int32),
                np.asarray(ctx_len, np.int32),
                np.asarray(pages, np.int32), np.asarray(offs, np.int32),
            ))
            if pool.is_int8:
                logits, pool.k, pool.v, pool.k_scale, pool.v_scale = (
                    self._fwd_paged_q(
                        *args, pool.k, pool.v, pool.k_scale, pool.v_scale
                    )
                )
            else:
                logits, pool.k, pool.v = self._fwd_paged(
                    *args, pool.k, pool.v
                )
        if self.faults.rules:
            logits = _poison_lanes(logits, self.faults.nan_lanes())
        return logits

    def _paged_trunk(self, tokens, positions, block_tables, ctx_len,
                     pool_k, pool_v, k_scale, v_scale):
        """Embed -> blocks (paged attention) -> logits; returns the new
        per-layer K/V stacked (L, B, KV, hd) for the trailing scatter."""
        cfg = self.cfg
        x = L.embed(self.embed, tokens)  # (B, 1, D)
        new_k, new_v = [], []
        for i, blk in enumerate(self.blocks):
            x, k, v = self._block_paged(
                blk, x, positions, i, pool_k, pool_v, k_scale, v_scale,
                block_tables, ctx_len,
            )
            new_k.append(k)
            new_v.append(v)
        x = L.norm_apply(self.final_norm, x, cfg)
        logits = L.lm_logits(self.embed, x)
        return logits, jnp.stack(new_k), jnp.stack(new_v)

    def _forward_paged(self, tokens, positions, block_tables, ctx_len,
                       pages, offs, pool_k, pool_v):
        logits, kn, vn = self._paged_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            None, None,
        )
        pool_k = pool_k.at[:, pages, offs].set(kn.astype(pool_k.dtype))
        pool_v = pool_v.at[:, pages, offs].set(vn.astype(pool_v.dtype))
        return logits, pool_k, pool_v

    def _forward_paged_q(self, tokens, positions, block_tables, ctx_len,
                         pages, offs, pool_k, pool_v, k_scale, v_scale):
        logits, kn, vn = self._paged_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            k_scale, v_scale,
        )
        kq, ks = quantize_kv_int8(kn)
        vq, vs = quantize_kv_int8(vn)
        pool_k = pool_k.at[:, pages, offs].set(kq)
        pool_v = pool_v.at[:, pages, offs].set(vq)
        k_scale = k_scale.at[:, pages, offs].set(ks)
        v_scale = v_scale.at[:, pages, offs].set(vs)
        return logits, pool_k, pool_v, k_scale, v_scale

    # ---- fused decode + on-device selection -------------------------------

    def decode_paged_sample(self, tokens, positions, block_tables, ctx_len,
                            pages, offs, sampling, pool):
        """:meth:`decode_paged` with the token draw fused into the same
        dispatch: the host never sees logits unless it asks for them.

        ``sampling`` is ``(temps, top_ps, seeds, draws)``, each ``(B,)``
        (see :func:`sample_tokens`).  Returns ``(sel (B, 1) int32,
        logits (B, 1, V))``; mutates the pool via donated buffers.
        """
        if self.faults.rules:
            self.faults.check_dispatch()
        toks = np.asarray(tokens, np.int32)
        with self.tracer.span(
            "dispatch:decode_paged_sample", lanes=toks.shape[0]
        ):
            args = self._place_tree((
                toks, np.asarray(positions, np.int32),
                np.asarray(block_tables, np.int32),
                np.asarray(ctx_len, np.int32),
                np.asarray(pages, np.int32), np.asarray(offs, np.int32),
                *self._np_sampling(sampling),
            ))
            greedy = self._all_greedy(sampling)
            if pool.is_int8:
                sel, logits, pool.k, pool.v, pool.k_scale, pool.v_scale = (
                    self._fwd_paged_sq(
                        *args, pool.k, pool.v, pool.k_scale, pool.v_scale,
                        greedy,
                    )
                )
            else:
                sel, logits, pool.k, pool.v = self._fwd_paged_s(
                    *args, pool.k, pool.v, greedy
                )
        if self.faults.rules:
            logits = _poison_lanes(logits, self.faults.nan_lanes())
        return sel, logits

    @staticmethod
    def _np_sampling(sampling):
        temps, top_ps, seeds, draws = sampling
        return (
            np.asarray(temps, np.float32), np.asarray(top_ps, np.float32),
            np.asarray(seeds, np.int32), np.asarray(draws, np.int32),
        )

    @staticmethod
    def _all_greedy(sampling) -> bool:
        """Static all-lanes-greedy flag: lets the jit drop the sampling
        sub-graph entirely (one extra compile, reused every greedy step)."""
        return bool((np.asarray(sampling[0]) == 0.0).all())

    def _forward_paged_sample(self, tokens, positions, block_tables,
                              ctx_len, pages, offs, temps, top_ps, seeds,
                              draws, pool_k, pool_v, greedy_only=False):
        logits, kn, vn = self._paged_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            None, None,
        )
        sel = sample_tokens(logits, temps, top_ps, seeds, draws, greedy_only)
        pool_k = pool_k.at[:, pages, offs].set(kn.astype(pool_k.dtype))
        pool_v = pool_v.at[:, pages, offs].set(vn.astype(pool_v.dtype))
        return sel, logits, pool_k, pool_v

    def _forward_paged_sample_q(self, tokens, positions, block_tables,
                                ctx_len, pages, offs, temps, top_ps, seeds,
                                draws, pool_k, pool_v, k_scale, v_scale,
                                greedy_only=False):
        logits, kn, vn = self._paged_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            k_scale, v_scale,
        )
        sel = sample_tokens(logits, temps, top_ps, seeds, draws, greedy_only)
        kq, ks = quantize_kv_int8(kn)
        vq, vs = quantize_kv_int8(vn)
        pool_k = pool_k.at[:, pages, offs].set(kq)
        pool_v = pool_v.at[:, pages, offs].set(vq)
        k_scale = k_scale.at[:, pages, offs].set(ks)
        v_scale = v_scale.at[:, pages, offs].set(vs)
        return sel, logits, pool_k, pool_v, k_scale, v_scale

    def _block_paged(self, blk, x, positions, layer, pool_k, pool_v,
                     k_scale, v_scale, block_tables, ctx_len):
        cfg = self.cfg
        B = x.shape[0]
        h = L.norm_apply(blk["ln1"], x, cfg)
        q, k, v = self._qkv(blk, h, positions, kernel_proj=True)
        o = self._paged_attention(
            q[:, 0], k[:, 0], v[:, 0], pool_k, pool_v, k_scale, v_scale,
            block_tables, ctx_len, layer=layer,
        )
        o = o.astype(x.dtype).reshape(B, 1, cfg.q_dim)
        x = x + self._proj(blk, "attn.wo", o)
        return self._mlp(blk, x, kernel_proj=True), k[:, 0], v[:, 0]

    def _paged_attention(self, q, k_new, v_new, pool_k, pool_v, k_scale,
                         v_scale, block_tables, ctx_len, *, layer):
        """One layer of decode attention against the pool.  Distributed
        adapters override this with a ``shard_map`` over the model axis so
        each device attends only its local KV-head page slice."""
        return paged_gqa_decode(
            q, k_new, v_new, pool_k, pool_v, block_tables, ctx_len,
            layer=layer, k_scale=k_scale, v_scale=v_scale,
            interpret=self.paged_interpret,
        )

    # ---- paged batched prefill -------------------------------------------

    def prefill_paged(self, tokens, positions, block_tables, ctx_len,
                      pages, offs, pool):
        """Fused cross-request prefill chunk batch against ``pool``.

        tokens/positions (B, C) int32 — lane b carries one request's chunk
        (front-aligned, zero-padded tail); block_tables (B, Pa) int32
        bucketed to the longest PRIOR context; ctx_len (B,) int32 prior
        context per lane (the chunk start); pages/offs (B, C) int32
        physical address of every chunk token (scratch for padding).

        Mutates ``pool.k``/``pool.v`` (+ scales for int8 pools) via donated
        buffers and returns logits (B, C, V).  The caller owns the host-
        side length accounting (``pool.note_span_written``).
        """
        if self.faults.rules:
            self.faults.check_dispatch()
        toks = np.asarray(tokens, np.int32)
        with self.tracer.span(
            "dispatch:prefill_paged",
            lanes=toks.shape[0], chunk=toks.shape[1],
        ):
            args = self._place_tree((
                toks, np.asarray(positions, np.int32),
                np.asarray(block_tables, np.int32),
                np.asarray(ctx_len, np.int32),
                np.asarray(pages, np.int32), np.asarray(offs, np.int32),
            ))
            if pool.is_int8:
                logits, pool.k, pool.v, pool.k_scale, pool.v_scale = (
                    self._fwd_prefill_q(
                        *args, pool.k, pool.v, pool.k_scale, pool.v_scale
                    )
                )
            else:
                logits, pool.k, pool.v = self._fwd_prefill(
                    *args, pool.k, pool.v
                )
        if self.faults.rules:
            logits = _poison_lanes(logits, self.faults.nan_lanes())
        return logits

    def _prefill_trunk(self, tokens, positions, block_tables, ctx_len,
                       pool_k, pool_v, k_scale, v_scale, verify=False):
        """Embed -> blocks (paged chunk attention) -> logits; returns the
        chunk's per-layer K/V stacked (L, B, C, KV, hd) for the scatter.
        ``verify`` marks the speculative verifier: attention goes through
        ``paged_gqa_verify`` and, over int8 pools, the chunk's own K/V is
        round-tripped through the page quantizer before attention, so
        intra-chunk reads match what one-token decode would read back
        from the pool once the draft tokens are scattered."""
        cfg = self.cfg
        x = L.embed(self.embed, tokens)  # (B, C, D)
        new_k, new_v = [], []
        for i, blk in enumerate(self.blocks):
            x, k, v = self._block_prefill_paged(
                blk, x, positions, i, pool_k, pool_v, k_scale, v_scale,
                block_tables, ctx_len, verify=verify,
            )
            new_k.append(k)
            new_v.append(v)
        x = L.norm_apply(self.final_norm, x, cfg)
        logits = L.lm_logits(self.embed, x)
        return logits, jnp.stack(new_k), jnp.stack(new_v)

    def _forward_prefill_paged(self, tokens, positions, block_tables,
                               ctx_len, pages, offs, pool_k, pool_v):
        logits, kn, vn = self._prefill_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            None, None,
        )
        # kn/vn (L, B, C, KV, hd); pages/offs (B, C) broadcast together
        pool_k = pool_k.at[:, pages, offs].set(kn.astype(pool_k.dtype))
        pool_v = pool_v.at[:, pages, offs].set(vn.astype(pool_v.dtype))
        return logits, pool_k, pool_v

    def _forward_prefill_paged_q(self, tokens, positions, block_tables,
                                 ctx_len, pages, offs, pool_k, pool_v,
                                 k_scale, v_scale):
        logits, kn, vn = self._prefill_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            k_scale, v_scale,
        )
        kq, ks = quantize_kv_int8(kn)
        vq, vs = quantize_kv_int8(vn)
        pool_k = pool_k.at[:, pages, offs].set(kq)
        pool_v = pool_v.at[:, pages, offs].set(vq)
        k_scale = k_scale.at[:, pages, offs].set(ks)
        v_scale = v_scale.at[:, pages, offs].set(vs)
        return logits, pool_k, pool_v, k_scale, v_scale

    # ---- speculative draft-and-verify -------------------------------------

    def verify_paged(self, tokens, positions, block_tables, ctx_len,
                     pages, offs, drafts, n_drafts, sampling, pool):
        """One fused speculative verify tick against ``pool``, in place.

        tokens (B, K+1) int32 — lane b carries ``[last_emitted, d_1 ..
        d_K]`` (zero-padded past its draft count) at absolute positions
        ``ctx_len[b] .. ctx_len[b] + K``; drafts (B, K) int32 the proposed
        tokens; n_drafts (B,) int32 valid drafts per lane; pages/offs
        (B, K+1) physical addresses for every fed token's K/V (scratch
        for padding); ``sampling = (temps, top_ps, seeds, draws)`` per
        :func:`sample_tokens`.

        The dispatch runs the PREFILL trunk over the (B, K+1) chunk batch
        (``paged_gqa_verify`` — the chunked-prefill kernel as verifier),
        selects a token at every chunk position on device, counts each
        lane's longest accepted draft prefix, and scatters ALL fed
        tokens' K/V into the donated pool buffers (the engine rolls back
        the rejected tail via ``pool.truncate``).  Returns
        ``(sel (B, K+1) int32, n_acc (B,) int32, logits (B, K+1, V))`` —
        lane b emits ``sel[b, :n_acc[b] + 1]``.
        """
        if self.faults.rules:
            self.faults.check_dispatch()
        toks = np.asarray(tokens, np.int32)
        with self.tracer.span(
            "dispatch:verify_paged",
            lanes=toks.shape[0], width=toks.shape[1],
        ):
            args = self._place_tree((
                toks, np.asarray(positions, np.int32),
                np.asarray(block_tables, np.int32),
                np.asarray(ctx_len, np.int32),
                np.asarray(pages, np.int32), np.asarray(offs, np.int32),
                np.asarray(drafts, np.int32), np.asarray(n_drafts, np.int32),
                *self._np_sampling(sampling),
            ))
            greedy = self._all_greedy(sampling)
            if pool.is_int8:
                sel, n_acc, logits, pool.k, pool.v, pool.k_scale, \
                    pool.v_scale = self._fwd_verify_q(
                        *args, pool.k, pool.v, pool.k_scale, pool.v_scale,
                        greedy,
                    )
            else:
                sel, n_acc, logits, pool.k, pool.v = self._fwd_verify(
                    *args, pool.k, pool.v, greedy
                )
        if self.faults.rules:
            logits = _poison_lanes(logits, self.faults.nan_lanes())
        return sel, n_acc, logits

    @staticmethod
    def _accept(sel, drafts, n_drafts):
        """Longest accepted draft prefix per lane: draft i is accepted
        while every draft before it was and the device selection at its
        predicting position drew exactly it — so continuing the chunk is
        indistinguishable from sequential decode having emitted it."""
        K = drafts.shape[1]
        ok = (drafts == sel[:, :K]) & (
            jnp.arange(K, dtype=jnp.int32)[None] < n_drafts[:, None]
        )
        return jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)

    def _forward_verify(self, tokens, positions, block_tables, ctx_len,
                        pages, offs, drafts, n_drafts, temps, top_ps,
                        seeds, draws, pool_k, pool_v, greedy_only=False):
        logits, kn, vn = self._prefill_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            None, None, verify=True,
        )
        sel = sample_tokens(logits, temps, top_ps, seeds, draws, greedy_only)
        n_acc = self._accept(sel, drafts, n_drafts)
        pool_k = pool_k.at[:, pages, offs].set(kn.astype(pool_k.dtype))
        pool_v = pool_v.at[:, pages, offs].set(vn.astype(pool_v.dtype))
        return sel, n_acc, logits, pool_k, pool_v

    def _forward_verify_q(self, tokens, positions, block_tables, ctx_len,
                          pages, offs, drafts, n_drafts, temps, top_ps,
                          seeds, draws, pool_k, pool_v, k_scale, v_scale,
                          greedy_only=False):
        logits, kn, vn = self._prefill_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            k_scale, v_scale, verify=True,
        )
        sel = sample_tokens(logits, temps, top_ps, seeds, draws, greedy_only)
        n_acc = self._accept(sel, drafts, n_drafts)
        kq, ks = quantize_kv_int8(kn)
        vq, vs = quantize_kv_int8(vn)
        pool_k = pool_k.at[:, pages, offs].set(kq)
        pool_v = pool_v.at[:, pages, offs].set(vq)
        k_scale = k_scale.at[:, pages, offs].set(ks)
        v_scale = v_scale.at[:, pages, offs].set(vs)
        return sel, n_acc, logits, pool_k, pool_v, k_scale, v_scale

    def _block_prefill_paged(self, blk, x, positions, layer, pool_k, pool_v,
                             k_scale, v_scale, block_tables, ctx_len,
                             verify=False):
        cfg = self.cfg
        B, C, _ = x.shape
        h = L.norm_apply(blk["ln1"], x, cfg)
        q, k, v = self._qkv(blk, h, positions, kernel_proj=True)
        # verify over int8 pools: the chunk's attention view round-trips
        # through the page quantizer (what the pool will return for these
        # tokens once scattered), while the fp original rides along as the
        # DIAGONAL override (what one-token decode folds analytically for
        # the self position) — and is what gets scattered, quantized by
        # the pool exactly as one-token decode would
        rt = verify and k_scale is not None
        ka, va = (_int8_roundtrip(k), _int8_roundtrip(v)) if rt else (k, v)
        ks, vs = (k, v) if rt else (None, None)
        o = self._paged_prefill_attention(
            q, ka, va, pool_k, pool_v, k_scale, v_scale, block_tables,
            ctx_len, layer=layer, verify=verify, k_self=ks, v_self=vs,
        )
        o = o.astype(x.dtype).reshape(B, C, cfg.q_dim)
        x = x + self._proj(blk, "attn.wo", o)
        return self._mlp(blk, x, kernel_proj=True), k, v

    def _paged_prefill_attention(self, q, k_new, v_new, pool_k, pool_v,
                                 k_scale, v_scale, block_tables, ctx_len,
                                 *, layer, verify=False, k_self=None,
                                 v_self=None):
        """One layer of chunk-batch prefill attention against the pool
        (``paged_gqa_verify`` — the same kernel — when the chunk is a
        speculative verify group; ``k/v_self`` is its int8-exactness
        diagonal override).  Distributed adapters override this with a
        ``shard_map`` over the model axis, mirroring
        :meth:`_paged_attention`."""
        op = paged_gqa_verify if verify else paged_gqa_prefill
        return op(
            q, k_new, v_new, pool_k, pool_v, block_tables, ctx_len,
            layer=layer, k_scale=k_scale, v_scale=v_scale,
            k_self=k_self, v_self=v_self,
            interpret=self.paged_interpret,
        )
