"""Deterministic fault injection for the serving stack.

A :class:`FaultPlan` is a small list of trigger→fault rules that the
engine, pool, adapter, and artifact loader consult at well-defined
points.  The default plan is empty and every hook degrades to an
iteration over an empty list, so the hot path pays nothing when no
faults are armed.

Fault kinds
-----------

``alloc_fail``
    The engine's per-tick page claim for a decode lane fails.  The
    targeted request FAILS with ``finish_reason="alloc_fail"``; nothing
    else is touched.
``pool_exhausted``
    One :meth:`PagedKVPool.extend`/:meth:`admit` call reports no pages.
    Transient: the engine recovers through its normal evict/requeue or
    defer paths, so no request fails — this exercises the recovery
    machinery itself.
``nan_logits``
    The adapter poisons the targeted request's lane of the returned
    logits with NaN *after* the fused dispatch — exactly what a corrupt
    artifact or a numerically unstable kernel would produce.  With
    ``EngineConfig.screen_logits`` the lane is quarantined (FAILED,
    ``finish_reason="nan_logits"``) while co-batched lanes keep their
    exact token streams.
``dispatch_error``
    The adapter raises :class:`FaultInjected` at the entry of a fused
    dispatch, before any pool buffer is touched.  The engine fails only
    the targeted request; surviving lanes retry next tick and stay
    token-identical to a fault-free run.
``corrupt_shard``
    Artifact loading sees a checksum mismatch on the given shard and
    raises :class:`~repro.checkpoint.store.ArtifactCorruption`.
``cancel``
    The engine calls :meth:`Engine.cancel` on the given request id at
    the given tick boundary — deterministic mid-flight cancellation
    from CLI fault plans and benchmarks.
``slow_client``
    The front door stalls the targeted request's SSE write path for
    ``ms`` milliseconds per consult — a client that stops reading.
``disconnect``
    The front door drops the targeted request's connection once
    ``tokens`` tokens have streamed (default 1) — exercising the
    disconnect → :meth:`Engine.cancel` path without a real client
    misbehaving on cue.
``admission_burst``
    The front door injects ``n`` synthetic low-priority admissions at
    the matching tick — a retry storm on demand, driving the admission
    backpressure and degradation-ladder machinery.
``replica_kill``
    The replica process exits IMMEDIATELY (``os._exit(137)``) at the
    matching tick boundary — indistinguishable from a ``kill -9`` to
    the fleet supervisor and to every client streaming from it.  Fired
    by the front door's tick loop, so it composes with ``tick=``.
``replica_hang``
    The engine thread sleeps forever at the matching tick boundary: a
    wedged dispatch.  The event loop stays alive (``/healthz`` still
    answers — flipping to 503 once ``last_tick_age_s`` passes the
    stall threshold), so this exercises the watchdog-then-hard-kill
    path rather than crash detection.
``replica_slow``
    The engine thread sleeps ``ms`` milliseconds per matching tick
    (``times`` firings) — a degraded replica that stays healthy but
    falls behind, driving the router's over-pressure fallback.

Rule triggers: ``tick`` (engine step index, from the steps counter),
``rid`` (request id), ``shard`` (artifact shard index), ``times`` (how
often the rule fires before disarming; default once).  Network-layer
parameters: ``tokens`` (disconnect threshold), ``ms`` (slow-client
stall / replica_slow tick delay), ``n`` (burst size).  A rule with no
``tick`` fires at the first opportunity; a rule with no ``rid`` binds
to the first live lane of the dispatch it fires on.

The plan string grammar (``--fault-plan``)::

    kind[@key=val[,key=val...]][;rule...]

e.g. ``"alloc_fail@rid=0;nan_logits@rid=2;cancel@rid=4,tick=6"``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.checkpoint.store import ArtifactCorruption

__all__ = [
    "FAULT_KINDS",
    "AdmissionRejected",
    "ArtifactCorruption",
    "FaultInjected",
    "FaultPlan",
    "FaultRule",
    "NO_FAULTS",
    "parse_fault_plan",
]

FAULT_KINDS = (
    "alloc_fail",
    "pool_exhausted",
    "nan_logits",
    "dispatch_error",
    "corrupt_shard",
    "cancel",
    # ---- network-layer faults (serve/frontdoor, DESIGN.md §14) ----
    "slow_client",  # stall the SSE write path for the targeted stream
    "disconnect",  # drop the client connection mid-stream
    "admission_burst",  # inject a burst of synthetic admissions at a tick
    # ---- replica-level faults (serve/fleet, DESIGN.md §15) ----
    "replica_kill",  # the replica process exits abruptly (as if kill -9)
    "replica_hang",  # the engine thread wedges forever (watchdog food)
    "replica_slow",  # the engine thread stalls ms per tick (degraded)
)


class AdmissionRejected(ValueError):
    """Structured admission backpressure from :meth:`Engine.submit`.

    ``retryable=True`` means the rejection is transient (bounded queue
    full, tenant rate limit, load shed): back off — for
    ``retry_after_s`` seconds when set — and resubmit.
    ``retryable=False`` means this engine can never serve the request
    (it exceeds per-sequence or total pool capacity) and resubmitting
    is pointless.

    ``str()`` carries every actionable detail (reason, needed/available
    pages, queue occupancy, retry-after, the retryable flag) so CLI
    errors and HTTP response bodies never need to reach into the
    attributes; :meth:`to_dict` is the structured form the front door
    serializes, and :attr:`http_status` the HTTP mapping (429 for
    retryable backpressure, 413 for a request that can never fit).

    Subclasses :class:`ValueError` so callers of the old bare-ValueError
    contract keep working.
    """

    def __init__(self, reason: str, *, retryable: bool,
                 needed_pages: Optional[int] = None,
                 available_pages: Optional[int] = None,
                 pending: Optional[int] = None,
                 limit: Optional[int] = None,
                 retry_after_s: Optional[float] = None,
                 tenant: Optional[str] = None):
        self.reason = reason
        self.retryable = retryable
        self.needed_pages = needed_pages
        self.available_pages = available_pages
        self.pending = pending
        self.limit = limit
        self.retry_after_s = retry_after_s
        self.tenant = tenant
        parts = [f"admission rejected ({reason})"]
        if tenant is not None:
            parts.append(f"tenant {tenant!r}")
        if needed_pages is not None:
            parts.append(f"needs {needed_pages} pages, "
                         f"{available_pages} available")
        if limit is not None:
            parts.append(f"{pending} pending >= max_queue {limit}")
        if retry_after_s is not None:
            parts.append(f"retry after {retry_after_s:.3g}s")
        parts.append("retryable" if retryable else "not retryable")
        super().__init__("; ".join(parts))

    @property
    def http_status(self) -> int:
        """HTTP mapping: 413 (payload too large) for a request this pool
        can NEVER hold, 429 (too many requests) for every transient
        rejection — queue_full, rate_limited, shed."""
        return 413 if self.reason == "over_capacity" else 429

    def to_dict(self) -> dict:
        """JSON-serializable body for HTTP error responses (None fields
        omitted so clients see only the relevant context)."""
        out = {"error": self.reason, "retryable": self.retryable,
               "detail": str(self)}
        for key in ("needed_pages", "available_pages", "pending", "limit",
                    "retry_after_s", "tenant"):
            v = getattr(self, key)
            if v is not None:
                out[key] = v
        return out


class FaultInjected(RuntimeError):
    """Raised by an armed ``dispatch_error`` rule at an adapter entry."""

    def __init__(self, rule: "FaultRule", rid: Optional[int] = None):
        self.rule = rule
        self.rid = rid
        super().__init__(f"injected dispatch fault (rid={rid}, rule={rule})")


@dataclasses.dataclass
class FaultRule:
    kind: str
    tick: Optional[int] = None
    rid: Optional[int] = None
    shard: Optional[int] = None
    times: int = 1
    # ---- network-layer rule parameters (serve/frontdoor) ----
    tokens: Optional[int] = None  # disconnect: after this many streamed
    #   tokens (default: the first one)
    ms: Optional[int] = None  # slow_client: stall per consult, milliseconds
    n: Optional[int] = None  # admission_burst: synthetic submits per firing
    fired: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{', '.join(FAULT_KINDS)}")
        if self.times < 1:
            raise ValueError(f"times must be >= 1, got {self.times}")
        if self.kind == "cancel" and self.rid is None:
            raise ValueError("cancel rules must name a rid")
        if self.kind == "slow_client" and self.ms is None:
            raise ValueError("slow_client rules must set ms= (stall length)")
        if self.kind == "replica_slow" and self.ms is None:
            raise ValueError("replica_slow rules must set ms= (tick delay)")
        if self.kind == "admission_burst" and (self.n is None or self.n < 1):
            raise ValueError("admission_burst rules must set n= (burst size)")

    @property
    def armed(self) -> bool:
        return self.fired < self.times


class FaultPlan:
    """An ordered set of :class:`FaultRule` plus the dispatch context the
    engine maintains (current ``tick``, ``lane_rids`` of the in-flight
    dispatch).  ``log`` records every firing for telemetry/tests."""

    def __init__(self, rules=()):
        self.rules = [r if isinstance(r, FaultRule) else FaultRule(**r)
                      for r in rules]
        self.tick = 0
        self.lane_rids: tuple = ()
        # lanes whose logits this dispatch actually CONSUMES (decode,
        # verify, and prefill chunks reaching the prompt boundary) —
        # nan_logits only fires there, so the poison is always observable
        # by the screen instead of vanishing with a discarded chunk
        self.poison_rids: tuple = ()
        self.log: list = []

    def __repr__(self):
        return f"FaultPlan({self.rules!r}, tick={self.tick})"

    @property
    def active(self) -> bool:
        return any(r.armed for r in self.rules)

    def _record(self, rule: FaultRule, **ctx) -> FaultRule:
        rule.fired += 1
        self.log.append({"tick": self.tick, "kind": rule.kind, **ctx})
        return rule

    def _tick_match(self, rule: FaultRule) -> bool:
        return rule.tick is None or rule.tick == self.tick

    def fire(self, kind: str, rid: Optional[int] = None,
             shard: Optional[int] = None) -> Optional[FaultRule]:
        """Consume and return the first armed rule of ``kind`` matching
        the given context, or None.  A rule pinned to a rid only fires
        when that rid is offered."""
        for rule in self.rules:
            if rule.kind != kind or not rule.armed:
                continue
            if not self._tick_match(rule):
                continue
            if rule.rid is not None and rule.rid != rid:
                continue
            if rule.shard is not None and rule.shard != shard:
                continue
            return self._record(rule, rid=rid, shard=shard)
        return None

    # ------------------------------------------------------------------
    # adapter-side hooks (lane_rids is set by the engine per dispatch)

    def check_dispatch(self) -> None:
        """Raise :class:`FaultInjected` if a ``dispatch_error`` rule is
        armed for this dispatch.  Called at the entry of every fused
        forward, before any donated pool buffer is consumed."""
        for rule in self.rules:
            if rule.kind != "dispatch_error" or not rule.armed:
                continue
            if not self._tick_match(rule):
                continue
            rid = rule.rid
            if rid is not None and rid not in self.lane_rids:
                continue
            if rid is None:
                rid = next((r for r in self.lane_rids if r is not None),
                           None)
            self._record(rule, rid=rid)
            raise FaultInjected(rule, rid=rid)

    def nan_lanes(self) -> list:
        """Lane indices of the current dispatch to poison with NaN
        (consumes matching ``nan_logits`` rules)."""
        lanes = []
        for rule in self.rules:
            if rule.kind != "nan_logits" or not rule.armed:
                continue
            if not self._tick_match(rule):
                continue
            if rule.rid is not None:
                if rule.rid not in self.poison_rids:
                    continue
                lane = self.lane_rids.index(rule.rid)
            else:
                lane = next((i for i, r in enumerate(self.lane_rids)
                             if r is not None and r in self.poison_rids),
                            None)
                if lane is None:
                    continue
            self._record(rule, rid=self.lane_rids[lane], lane=lane)
            lanes.append(lane)
        return lanes

    # ------------------------------------------------------------------
    # engine / loader hooks

    def cancel_rids(self) -> list:
        """Request ids whose ``cancel`` rules fire at the current tick."""
        rids = []
        for rule in self.rules:
            if rule.kind != "cancel" or not rule.armed:
                continue
            if not self._tick_match(rule):
                continue
            self._record(rule, rid=rule.rid)
            rids.append(rule.rid)
        return rids

    # ------------------------------------------------------------------
    # front-door (router/stream) hooks — serve/frontdoor consults these
    # on the network path, so chaos plans cover slow clients, mid-stream
    # disconnects, and synthetic admission bursts without a real client
    # misbehaving on cue

    def stall_ms(self, rid: Optional[int] = None) -> Optional[int]:
        """Milliseconds to stall the stream write for ``rid`` (consumes a
        matching ``slow_client`` rule), or None."""
        for rule in self.rules:
            if rule.kind != "slow_client" or not rule.armed:
                continue
            if not self._tick_match(rule):
                continue
            if rule.rid is not None and rule.rid != rid:
                continue
            self._record(rule, rid=rid, ms=rule.ms)
            return rule.ms
        return None

    def disconnect_after(self, rid: Optional[int], n_sent: int) -> bool:
        """Whether the stream for ``rid`` should be forcibly dropped now,
        ``n_sent`` tokens in (consumes a matching ``disconnect`` rule once
        the stream has shipped ``rule.tokens`` tokens; default 1)."""
        for rule in self.rules:
            if rule.kind != "disconnect" or not rule.armed:
                continue
            if not self._tick_match(rule):
                continue
            if rule.rid is not None and rule.rid != rid:
                continue
            if n_sent < (rule.tokens if rule.tokens is not None else 1):
                continue
            self._record(rule, rid=rid, tokens=n_sent)
            return True
        return False

    def replica_disruption(self) -> Optional[FaultRule]:
        """The replica-level fault to apply at this tick boundary, or
        None.  Consulted by the front door's tick loop BEFORE the tick
        runs, with ``self.tick`` set to the count of completed ticks —
        so ``tick=N`` disrupts after exactly N clean ticks.  Kills and
        hangs are terminal for the process; ``replica_slow`` fires up
        to ``times`` and sleeps ``ms`` per firing."""
        for rule in self.rules:
            if rule.kind not in ("replica_kill", "replica_hang",
                                 "replica_slow") or not rule.armed:
                continue
            if not self._tick_match(rule):
                continue
            return self._record(rule, ms=rule.ms)
        return None

    def admission_burst(self) -> int:
        """Synthetic admissions the router should inject this tick
        (consumes matching ``admission_burst`` rules; 0 when none fire)."""
        total = 0
        for rule in self.rules:
            if rule.kind != "admission_burst" or not rule.armed:
                continue
            if not self._tick_match(rule):
                continue
            self._record(rule, n=rule.n)
            total += rule.n
        return total

    def corrupt_shards(self) -> set:
        """Shard indices whose manifest digests the loader should treat
        as mismatched (consumes ``corrupt_shard`` rules)."""
        shards = set()
        for rule in self.rules:
            if rule.kind != "corrupt_shard" or not rule.armed:
                continue
            self._record(rule, shard=rule.shard)
            shards.add(0 if rule.shard is None else rule.shard)
        return shards


#: Shared inert default: hooks that consult it iterate an empty rule
#: list.  Never mutate it — engines build their own plan.
NO_FAULTS = FaultPlan()


def parse_fault_plan(spec: str) -> FaultPlan:
    """Parse the ``--fault-plan`` grammar (see module docstring)."""
    rules = []
    for part in (p.strip() for p in spec.split(";")):
        if not part:
            continue
        kind, _, argstr = part.partition("@")
        kw = {}
        if argstr:
            for item in argstr.split(","):
                key, eq, val = item.partition("=")
                key = key.strip()
                if not eq or key not in ("tick", "rid", "shard", "times",
                                         "tokens", "ms", "n"):
                    raise ValueError(
                        f"bad fault rule argument {item!r} in {part!r}; "
                        "expected tick=/rid=/shard=/times=/tokens=/ms=/n=")
                try:
                    kw[key] = int(val)
                except ValueError:
                    raise ValueError(
                        f"fault rule argument {item!r} is not an integer")
        rules.append(FaultRule(kind=kind.strip(), **kw))
    if not rules:
        raise ValueError(f"empty fault plan {spec!r}")
    return FaultPlan(rules)
