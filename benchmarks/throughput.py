"""Table 4 analogue: decode-path cost, fp16 vs QuIP quantized matmul.

The paper measures per-token generation latency (QuIP 81ms vs OPTQ 53ms on
an A6000).  Without a TPU we report BOTH:
  * measured CPU wall-time of the two inference paths (relative cost of
    the incoherence transforms — the paper's 1.5x observation);
  * the TPU roofline view: weight bytes/token and arithmetic intensity of
    the 2-bit packed path vs bf16 (the 16/bits x reduction that makes
    2-bit decode compute- rather than HBM-bound — DESIGN.md §3).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.quantizer import QuipConfig, quantize_layer
from repro.kernels.quant_matmul import ops as qmm
from repro.runtime.roofline import HW

from benchmarks.common import emit, timeit


def run(args) -> dict:
    m = n = args.dim
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (m, n)) * 0.02
    X = jax.random.normal(jax.random.PRNGKey(1), (2048, n))
    H = X.T @ X / 2048 + 1e-3 * jnp.eye(n)
    results = {}

    # build the quantized layer (full QuIP path: transforms + packed int2)
    for bits in (2, 3, 4):
        qcfg = QuipConfig(bits=bits, method="ldlq", use_kernel=False)
        layer, _ = quantize_layer(W, H, qcfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (args.batch, n))

        fp = jax.jit(lambda x: x @ W.T)
        qp = jax.jit(layer.__call__)
        t_fp = timeit(fp, x, iters=args.iters)
        t_q = timeit(qp, x, iters=args.iters)
        results[f"fp32_matvec@{bits}b_ref"] = t_fp
        results[f"quip_path@{bits}b"] = t_q
        emit(f"throughput/fp_matmul_{m}x{n}", t_fp, f"batch={args.batch}")
        emit(
            f"throughput/quip_{bits}b_{m}x{n}", t_q,
            f"slowdown_vs_fp={t_q / t_fp:.2f}x (paper: ~1.5x)",
        )

        # TPU roofline view (per token): bytes of weights moved
        bytes_bf16 = m * n * 2
        bytes_packed = packing.packed_rows(n, bits) * m * 4
        flops = 2 * m * n
        hw = HW()
        t_mem_bf16 = bytes_bf16 / hw.hbm_bw
        t_mem_q = bytes_packed / hw.hbm_bw
        t_compute = flops / hw.peak_flops
        results[f"tpu_intensity@{bits}b"] = flops / bytes_packed
        emit(
            f"throughput/tpu_roofline_{bits}b", 0.0,
            f"wbytes/token {bytes_bf16}->{bytes_packed} "
            f"({bytes_bf16/bytes_packed:.1f}x); decode t_mem "
            f"{t_mem_bf16*1e6:.1f}us->{t_mem_q*1e6:.1f}us vs t_compute "
            f"{t_compute*1e6:.2f}us",
        )
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--out", default="experiments/throughput.json")
    args = ap.parse_args(argv)
    results = run(args)
    print(json.dumps(results, indent=1))
    if args.out:
        import pathlib

        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
