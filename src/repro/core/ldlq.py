"""LDLQ: adaptive rounding with linear feedback (QuIP Sec. 3).

Implements the family of rounding methods

    What = Q(W + (W - What) @ U)                                  (Eq. 2)

with ``U`` strictly upper triangular, and the optimal LDL assignment

    H = (Udot + I) D (Udot + I)^T                                 (Eq. 4)

Also implements the OPTQ/GPTQ reference algorithm (used by tests to verify
Theorem 6: OPTQ is exactly LDLQ) and the nearest / stochastic baselines.

All routines operate on the *integer quantization grid* ``[0, 2^b - 1]``;
scaling in and out of that grid is the job of
:mod:`repro.core.incoherence` (Algorithms 1 and 2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "ldl_decomposition",
    "quantize_nearest",
    "quantize_stoch",
    "ldlq",
    "ldlq_blocked",
    "optq_reference",
]


def ldl_decomposition(H: jax.Array) -> tuple[jax.Array, jax.Array]:
    """UDU^T ("upper") LDL decomposition used by QuIP.

    Returns ``(Udot, D)`` with ``Udot`` *strictly* upper triangular and ``D``
    the non-negative diagonal (as a vector) such that

        H = (Udot + I) diag(D) (Udot + I)^T.

    Computed from a Cholesky factorization of the index-reversed matrix:
    if P is the flip permutation and P H P = L L^T, then U = P L P is upper
    triangular and H = U U^T; unit-normalizing columns of U gives the result.
    """
    Hr = H[::-1, ::-1]
    L = jnp.linalg.cholesky(Hr)
    U = L[::-1, ::-1]  # upper triangular, H = U @ U.T
    d = jnp.diagonal(U)
    Ut = U / d[None, :]  # unit upper triangular
    D = d * d
    n = H.shape[0]
    Udot = Ut - jnp.eye(n, dtype=H.dtype)
    return Udot, D


def quantize_nearest(z: jax.Array, maxq: int) -> jax.Array:
    """Nearest rounding to the grid {0, ..., maxq} with clamping."""
    return jnp.clip(jnp.round(z), 0, maxq)


def quantize_stoch(z: jax.Array, maxq: int, key: jax.Array) -> jax.Array:
    """Unbiased stochastic rounding to the grid {0, ..., maxq}: E[Q(z)] = z."""
    lo = jnp.floor(z)
    frac = z - lo
    up = jax.random.uniform(key, z.shape, dtype=z.dtype) < frac
    return jnp.clip(lo + up.astype(z.dtype), 0, maxq)


def _make_q(maxq: int, stochastic: bool, key: Optional[jax.Array]):
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")

        def q(z, k):
            return quantize_stoch(z, maxq, k)
    else:

        def q(z, k):  # noqa: ARG001 - uniform signature
            return quantize_nearest(z, maxq)

    return q


@functools.partial(jax.jit, static_argnames=("maxq", "stochastic"))
def ldlq(
    W: jax.Array,
    Udot: jax.Array,
    maxq: int,
    *,
    stochastic: bool = False,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference LDLQ: sequential column rounding with linear feedback.

    ``W``: (m, n) weights already mapped onto the quantization grid domain.
    ``Udot``: (n, n) strictly upper triangular linear feedback (from
    :func:`ldl_decomposition`, or any other member of the Eq.-2 class).

    O(m n^2); the production path is :func:`ldlq_blocked` /
    ``repro.kernels.ldlq``.
    """
    m, n = W.shape
    q = _make_q(maxq, stochastic, key)
    keys = (
        jax.random.split(key, n)
        if stochastic
        else jnp.zeros((n, 2), dtype=jnp.uint32)
    )

    def body(k, What):
        # (W - What) is zero for columns >= k (they are still unquantized),
        # and Udot[:, k] is supported on rows < k, so the full matvec equals
        # the triangular one.
        corr = (W - What) @ Udot[:, k]
        val = W[:, k] + corr
        return What.at[:, k].set(q(val, keys[k]))

    return jax.lax.fori_loop(0, n, body, W)


@functools.partial(jax.jit, static_argnames=("maxq", "block", "stochastic"))
def ldlq_blocked(
    W: jax.Array,
    Udot: jax.Array,
    maxq: int,
    *,
    block: int = 128,
    stochastic: bool = False,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Blocked LDLQ (GPTQ-style two-level schedule), XLA-only version.

    Processes ``block`` columns with sequential in-block feedback, then
    applies the trailing correction ``E_blk @ Udot[blk, rest]`` as one MXU
    matmul.  Mathematically identical to :func:`ldlq` (the feedback is
    linear, so it splits across the block boundary exactly).

    n must be divisible by ``block`` (configs are; tests pad).
    """
    m, n = W.shape
    assert n % block == 0, f"n={n} not divisible by block={block}"
    nb = n // block
    q = _make_q(maxq, stochastic, key)
    keys = (
        jax.random.split(key, n).reshape(nb, block, 2)
        if stochastic
        else jnp.zeros((nb, block, 2), dtype=jnp.uint32)
    )

    def outer(carry, inp):
        # Err holds (W - What) for already-quantized columns, 0 elsewhere.
        What, Err = carry
        i, ks = inp
        Wblk = jax.lax.dynamic_slice(W, (0, i * block), (m, block))
        Upanel = jax.lax.dynamic_slice(Udot, (0, i * block), (n, block))
        Ublk = jax.lax.dynamic_slice(
            Udot, (i * block, i * block), (block, block)
        )
        # Feedback from all previous blocks, one MXU matmul (the Pallas
        # production path in repro.kernels.ldlq mirrors this schedule).
        base = Err @ Upanel  # (m, block)

        def inner(k, st):
            Wq, E = st  # E = (Wblk - Wq) for in-block quantized columns
            corr = base[:, k] + E @ Ublk[:, k]
            val = Wblk[:, k] + corr
            qv = q(val, ks[k])
            Wq = Wq.at[:, k].set(qv)
            E = E.at[:, k].set(Wblk[:, k] - qv)
            return Wq, E

        Wq, E = jax.lax.fori_loop(
            0, block, inner, (Wblk, jnp.zeros_like(Wblk))
        )
        What = jax.lax.dynamic_update_slice(What, Wq, (0, i * block))
        Err = jax.lax.dynamic_update_slice(Err, E, (0, i * block))
        return (What, Err), None

    (What, _), _ = jax.lax.scan(
        outer, (W, jnp.zeros_like(W)), (jnp.arange(nb), keys)
    )
    return What


def optq_reference(W: jax.Array, H: jax.Array, maxq: int) -> jax.Array:
    """Textbook OPTQ/GPTQ (Frantar et al. 2023), used as a test oracle.

    After quantizing column t it updates every remaining column with the
    scaled error via the Cholesky factor of H^{-1}.  Per Theorem 6 this is
    exactly LDLQ; we keep the historically-distinct implementation (matrix
    inversion + Cholesky, the inefficiency QuIP removes) as the oracle.
    """
    n = H.shape[0]
    Hinv = jnp.linalg.inv(H)
    # Upper Cholesky of Hinv: Hinv = C^T C with C upper triangular.
    C = jnp.linalg.cholesky(Hinv, upper=True)

    def body(k, Wcur):
        c_kk = C[k, k]
        qv = quantize_nearest(Wcur[:, k], maxq)
        err = (Wcur[:, k] - qv) / c_kk
        mask = (jnp.arange(n) > k).astype(Wcur.dtype)
        Wcur = Wcur - jnp.outer(err, C[k, :] * mask)
        return Wcur.at[:, k].set(qv)

    return jax.lax.fori_loop(0, n, body, W)
