"""Graceful-drain accounting and the KV-pool leak gate.

Drain protocol (DESIGN.md §14): on SIGTERM/SIGINT the front door stops
admitting (readyz flips 503, generate returns 503 ``draining``), keeps
ticking until every in-flight lane is terminal, and past
``--drain-timeout-s`` cancels the stragglers.  The exit gate is the
same invariant ``launch/serve.py`` enforces: zero leaked pages — every
page still resident must be accounted to the prefix cache, and no
sequence slot may remain mapped.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.serve.engine import Engine

__all__ = ["DrainReport", "leak_gate"]


def leak_gate(pool) -> tuple:
    """(leaked_pages, residual_slots): pages in use beyond what the
    prefix cache holds, and sequence slots still mapped.  Both must be
    zero after a clean drain."""
    return pool.pages_in_use - pool.cached_pages, len(pool._slots)


@dataclasses.dataclass
class DrainReport:
    """What a drain did — the front door's exit value."""

    reason: str  # "sigterm" | "sigint" | "requested"
    duration_s: float
    completed: int  # requests that finished naturally during the drain
    cancelled: int  # in-flight requests cancelled at the deadline
    deadline_hit: bool
    leaked_pages: int
    residual_slots: int
    served_total: int  # requests finished over the server's lifetime

    @property
    def clean(self) -> bool:
        return self.leaked_pages == 0 and self.residual_slots == 0

    @property
    def exit_code(self) -> int:
        return 0 if self.clean else 1

    def lines(self) -> list:
        """Human-readable summary (the CLI prints these verbatim)."""
        out = [
            f"drain[{self.reason}] finished in {self.duration_s:.3f}s: "
            f"{self.completed} completed, {self.cancelled} cancelled"
            + (" (deadline hit)" if self.deadline_hit else ""),
            f"served {self.served_total} requests total",
        ]
        if self.clean:
            out.append("leak gate: clean (0 leaked pages, 0 mapped slots)")
        else:
            out.append(
                f"leak gate: FAILED ({self.leaked_pages} leaked pages, "
                f"{self.residual_slots} mapped slots)"
            )
        return out


def capture(engine: "Engine", *, reason: str, t0: float, completed: int,
            cancelled: int, deadline_hit: bool) -> DrainReport:
    leaked, slots = leak_gate(engine.pool)
    return DrainReport(
        reason=reason, duration_s=engine.now() - t0, completed=completed,
        cancelled=cancelled, deadline_hit=deadline_hit,
        leaked_pages=leaked, residual_slots=slots,
        served_total=len(engine.finished),
    )
