"""Table 6 analogue: tr(D)/tr(H) and approximate rank of real-activation
Hessians across layers (paper: ratio <= 0.65, H approximately low-rank)."""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hessian import damp
from repro.core.proxy import trD_trH
from repro.data import make_calibration
from repro.models import layers as Lm

from benchmarks.common import emit, trained_lm


def run(args) -> dict:
    cfg, model, params = trained_lm(steps=args.train_steps)
    calib = make_calibration(cfg.vocab, n_segments=8, seg_len=128, seed=7)
    # tap the input activations of each block's attention + mlp
    x = Lm.embed(params["embed"], calib.tokens)
    positions = jnp.arange(calib.tokens.shape[1], dtype=jnp.int32)
    ratios, ranks = [], []
    layer_params = [
        jax.tree.map(lambda a: a[i], params["layers"])
        for i in range(cfg.n_layers)
    ]
    for lp in layer_params:
        h = Lm.norm_apply(lp["ln1"], x, cfg)
        X = h.reshape(-1, cfg.d_model).astype(jnp.float32)
        H = damp(X.T @ X / X.shape[0], 0.01)
        ratios.append(float(trD_trH(H)))
        ev = np.linalg.eigvalsh(np.asarray(H))
        ranks.append(float((ev > 0.01 * ev.max()).mean()))
        x = x + Lm.attention_full(lp["attn"], h, cfg, positions=positions)
        h2 = Lm.norm_apply(lp["ln2"], x, cfg)
        x = x + Lm.mlp_apply(lp["mlp"], h2, cfg)
    results = {
        "trD_trH_mean": float(np.mean(ratios)),
        "trD_trH_per_layer": ratios,
        "approx_frac_rank_mean": float(np.mean(ranks)),
        "approx_frac_rank_per_layer": ranks,
    }
    emit("trd_trh/mean", 0.0,
         f"trD/trH={results['trD_trH_mean']:.3f} (paper<=0.65) "
         f"frac_rank={results['approx_frac_rank_mean']:.3f}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--out", default="experiments/trd_trh.json")
    args = ap.parse_args(argv)
    results = run(args)
    print(json.dumps({k: v for k, v in results.items() if "per_layer" not in k},
                     indent=1))
    if args.out:
        import pathlib

        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
