"""Deterministic synthetic data: a learnable LM stream + calibration sets.

No C4 on this offline box (DESIGN.md §8); the pipeline is source-agnostic:
``token_batches`` is the contract every driver consumes ((step -> batch)
pure function of (seed, step), which is ALSO the straggler/fault-tolerance
mechanism — any host can recompute any shard of any step without
coordination).

The LM stream is a k-th order Markov chain over the vocab with a few
hundred "motif" templates, giving a real gap between an untrained and a
trained model (used by benchmarks/quality_grid to reproduce the paper's
perplexity orderings).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "token_batches", "CalibrationSet", "make_calibration"]


@dataclasses.dataclass
class SyntheticLM:
    """Order-1 Markov token source with motif insertions (deterministic)."""

    vocab: int
    seed: int = 0
    n_motifs: int = 64
    motif_len: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        # sparse-ish transition structure: each token has 32 likely successors
        self.n_succ = min(32, v)
        self.succ = rng.integers(0, v, size=(v, self.n_succ), dtype=np.int32)
        self.succ_p = rng.dirichlet(np.ones(self.n_succ) * 0.5, size=v).astype(
            np.float32
        )
        self.motifs = rng.integers(
            0, v, size=(self.n_motifs, self.motif_len), dtype=np.int32
        )

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), dtype=np.int32)
        tok = rng.integers(0, self.vocab, size=batch).astype(np.int32)
        for t in range(seq):
            u = rng.random(batch)
            cdf = np.cumsum(self.succ_p[tok], axis=-1)
            idx = (u[:, None] > cdf).sum(-1).clip(0, self.n_succ - 1)
            tok = self.succ[tok, idx]
            out[:, t] = tok
        # splice motifs (they give n-gram structure worth >0 bits)
        n_splice = max(1, seq // (4 * self.motif_len))
        for b in range(batch):
            for _ in range(n_splice):
                m = rng.integers(0, self.n_motifs)
                p = rng.integers(0, max(1, seq - self.motif_len))
                out[b, p : p + self.motif_len] = self.motifs[m]
        return out


def token_batches(
    vocab: int,
    global_batch: int,
    seq_len: int,
    *,
    seed: int = 0,
    start_step: int = 0,
) -> Iterator[dict]:
    """Deterministic (seed, step) -> batch stream.

    Restart/recompute contract: batch(step) depends only on (seed, step),
    so resume-from-checkpoint replays the exact stream and any host can
    regenerate any shard (straggler hot-spare semantics, DESIGN.md §4).
    """
    src = SyntheticLM(vocab, seed)
    step = start_step
    while True:
        rng = np.random.default_rng((seed << 20) ^ step)
        toks = src.sample(rng, global_batch, seq_len + 1)
        yield {
            "tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:]),
        }
        step += 1


@dataclasses.dataclass
class CalibrationSet:
    """Paper Sec. 6: 128 random segments of 2048 tokens (scaled-down knobs)."""

    tokens: jax.Array  # (n_seg, seg_len)

    @property
    def n_segments(self) -> int:
        return self.tokens.shape[0]


def make_calibration(
    vocab: int,
    *,
    n_segments: int = 128,
    seg_len: int = 2048,
    seed: int = 1234,
    source_seed: int = 0,
) -> CalibrationSet:
    """Calibration/eval segments.

    ``source_seed`` picks the LANGUAGE (the Markov source — must match the
    training stream's seed for held-out evaluation, exactly as the paper's
    calibration and eval text come from the same corpus); ``seed`` picks
    the SAMPLES (held-out randomness).
    """
    src = SyntheticLM(vocab, source_seed)
    rng = np.random.default_rng(seed)
    toks = src.sample(rng, n_segments, seg_len)
    return CalibrationSet(tokens=jnp.asarray(toks))
