"""Pallas quant_matmul kernel: interpret=True vs pure-jnp oracle sweeps.

Per the kernel deliverable contract: sweep shapes/dtypes and
assert_allclose against ref.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.incoherence import from_grid
from repro.kernels.quant_matmul import ops
from repro.kernels.quant_matmul.kernel import quant_matmul_kernel
from repro.kernels.quant_matmul.ref import grid_matmul_ref, quant_matmul_ref


def _mk(bits, m, n, bk=None, seed=0):
    maxq = 2**bits - 1
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    Wq = jax.random.randint(k1, (m, n), 0, maxq + 1)
    packed = packing.pack(Wq, bits)
    return Wq, packed


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_kernel_grid_matmul_interpret(bits):
    """Raw kernel (integer-grid matmul) vs oracle, tile-aligned shapes."""
    vals = 32 // bits
    bK = vals * 128 // np.gcd(vals, 128)  # lcm: one lane-aligned K tile
    B, M, K = 16, 128, bK
    Wq, packed = _mk(bits, M, K)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, K), jnp.float32)
    out = quant_matmul_kernel(
        x, packed, bits=bits, bB=8, bM=128, bK=bK, interpret=True
    )
    ref = grid_matmul_ref(x, packed, bits, K)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("bits", [2, 3, 4])
@pytest.mark.parametrize(
    "B,M,K", [(4, 96, 192), (1, 200, 130), (33, 128, 512)]
)
def test_ops_wrapper_padding_interpret(bits, B, M, K):
    """Public wrapper handles non-tile shapes + affine dequant, vs full ref."""
    maxq = 2**bits - 1
    Wq, packed = _mk(bits, M, K, seed=B)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, K), jnp.float32) * 0.3
    s = jnp.float32(0.17)
    out = ops.quant_matmul(x, packed, bits, K, s, maxq, interpret=True)
    ref = quant_matmul_ref(x, packed, bits, K, s, maxq)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ops_dtypes(dtype):
    bits, B, M, K = 2, 8, 128, 256
    maxq = 2**bits - 1
    Wq, packed = _mk(bits, M, K)
    x = (jax.random.normal(jax.random.PRNGKey(3), (B, K)) * 0.2).astype(dtype)
    out = ops.quant_matmul(x, packed, bits, K, jnp.float32(0.1), maxq, interpret=True)
    assert out.dtype == dtype
    ref = quant_matmul_ref(x, packed, bits, K, jnp.float32(0.1), maxq)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=2e-2, atol=2e-2
    )


def test_leading_batch_dims():
    bits, M, K = 4, 128, 256
    maxq = 2**bits - 1
    _, packed = _mk(bits, M, K)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 3, K), jnp.float32)
    out = ops.quant_matmul(x, packed, bits, K, jnp.float32(0.2), maxq, interpret=True)
    assert out.shape == (2, 3, M)
    flat = ops.quant_matmul(
        x.reshape(6, K), packed, bits, K, jnp.float32(0.2), maxq, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(out).reshape(6, M), np.asarray(flat), rtol=1e-5
    )


def test_cpu_fallback_matches_ref():
    """Without interpret/force flags on CPU, dispatches to the jnp oracle."""
    bits, B, M, K = 2, 4, 64, 96
    maxq = 2**bits - 1
    _, packed = _mk(bits, M, K)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, K))
    out = ops.quant_matmul(x, packed, bits, K, jnp.float32(0.3), maxq)
    ref = quant_matmul_ref(x, packed, bits, K, jnp.float32(0.3), maxq)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
