"""Driver integration tests: train (fault-tolerant), quantize, serve."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import quantize as qz
from repro.launch import serve as sv
from repro.launch import train as tr


@pytest.mark.slow
def test_train_driver_failure_recovery(tmp_path):
    """Injected failure -> restore from checkpoint -> identical replay."""
    rc = tr.main([
        "--arch", "qwen3-14b", "--smoke", "--steps", "8",
        "--global-batch", "2", "--seq-len", "16",
        "--save-every", "3", "--fail-at", "5",
        "--ckpt-dir", str(tmp_path), "--log-every", "2",
    ])
    assert rc == 0
    from repro.checkpoint import latest_step

    assert latest_step(tmp_path) == 8


@pytest.mark.slow
def test_train_driver_resume(tmp_path):
    """A second invocation resumes from the final checkpoint."""
    args = [
        "--arch", "qwen3-14b", "--smoke", "--steps", "4",
        "--global-batch", "2", "--seq-len", "16",
        "--save-every", "2", "--ckpt-dir", str(tmp_path),
    ]
    assert tr.main(args) == 0
    # extend to 6 steps: resumes at 4, not 0
    args[args.index("--steps") + 1] = "6"
    assert tr.main(args) == 0
    from repro.checkpoint import latest_step

    assert latest_step(tmp_path) == 6


@pytest.mark.slow
def test_quantize_driver_2bit_close_to_fp(tmp_path):
    out = tmp_path / "q.json"
    rc = qz.main([
        "--arch", "mistral-large-123b", "--smoke", "--bits", "2",
        "--calib-segments", "8", "--calib-len", "64",
        "--out", str(out),
    ])
    assert rc == 0
    import json

    rec = json.loads(out.read_text())
    # 2-bit with IncP stays within 25% relative ppl of fp on the smoke model
    assert rec["ppl_quant"] < rec["ppl_fp16"] * 1.25


def test_streaming_hessians_bit_identical():
    """Regression for the streaming calibration path: block Hessians (and
    thus every downstream packed weight) must be BIT-identical for every
    chunk size, including the one-shot whole-batch path (chunk=0)."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.models.transformer import unstack_layers
    from repro.models import layers as L
    from repro.data import make_calibration

    cfg = get_smoke_config("qwen3-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = make_calibration(cfg.vocab, n_segments=6, seg_len=16, seed=7)
    positions = jnp.arange(calib.tokens.shape[1], dtype=jnp.int32)
    x = L.embed(params["embed"], calib.tokens)
    lp = unstack_layers(params)[0]
    ref = qz.block_hessians(lp, x, cfg, positions, chunk=0)
    for chunk in (1, 2, 4, 5):
        got = qz.block_hessians(lp, x, cfg, positions, chunk=chunk)
        assert set(got) == set(ref)
        for name in ref:
            np.testing.assert_array_equal(
                np.asarray(got[name]), np.asarray(ref[name]),
                err_msg=f"{name} @ chunk={chunk}",
            )


@pytest.mark.slow
def test_streaming_quantize_bit_identical_model():
    """End-to-end: quantize_dense_model with streaming chunks emits the
    exact packed codes of the one-shot path (activation advance included)."""
    from repro.configs import get_smoke_config
    from repro.core.quantizer import QuipConfig
    from repro.data import make_calibration
    from repro.models import build_model

    cfg = get_smoke_config("qwen3-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = make_calibration(cfg.vocab, n_segments=4, seg_len=24, seed=7)
    qcfg = QuipConfig(bits=2, method="ldlq", use_kernel=False)
    qms = [
        qz.quantize_dense_model(params, cfg, qcfg, calib.tokens, seed=0,
                                verbose=False, calib_chunk=chunk)
        for chunk in (0, 1)
    ]
    for blk0, blk1 in zip(qms[0].blocks, qms[1].blocks):
        for name, val in blk0.items():
            if hasattr(val, "packed"):
                np.testing.assert_array_equal(
                    np.asarray(val.packed), np.asarray(blk1[name].packed),
                    err_msg=name,
                )


@pytest.mark.slow
def test_serve_driver_quantized_generation():
    """In-process quantize -> engine serve; --check verifies the cached
    decode against the recompute oracle (rc != 0 on divergence)."""
    rc = sv.main([
        "--arch", "qwen3-14b", "--smoke", "--requests", "2",
        "--prompt-len", "16", "--gen", "4", "--quantize", "--bits", "4",
        "--check",
    ])
    assert rc == 0


@pytest.mark.slow
def test_quantize_artifact_then_serve(tmp_path):
    """quantize --out-dir -> serve --load-quantized, no re-quantization."""
    rc = qz.main([
        "--arch", "qwen3-14b", "--smoke", "--bits", "2",
        "--calib-segments", "4", "--calib-len", "32",
        "--out-dir", str(tmp_path / "art"),
    ])
    assert rc == 0
    rc = sv.main([
        "--arch", "qwen3-14b", "--smoke", "--requests", "4",
        "--prompt-len", "16", "--gen", "4",
        "--load-quantized", str(tmp_path / "art"), "--check",
    ])
    assert rc == 0
