"""Per-op breakdown of the weighted HLO accounting (§Perf profiling tool).

The dry-run is our profiler: rank ops by loop-weighted bytes/FLOPs and
attribute them through the ``metadata op_name`` source path XLA carries
(e.g. ".../bqkgd,bskd->bkgqs/dot_general").  Usage:

    PYTHONPATH=src python -m repro.runtime.hlo_breakdown \
        experiments/hlo/qwen3-14b__train_4k__pod1.hlo.zst --top 25
"""
from __future__ import annotations

import argparse
import re
from collections import defaultdict

from repro.runtime.hlo_analysis import (
    _CALLS_RE,
    _COLLECTIVES,
    _SKIP_BYTES_OPCODES,
    _collective_payload,
    _comp_weights,
    _convert_comps,
    _slice_comps,
    _dot_flops,
    _fusion_bodies,
    _fusion_traffic_bytes,
    _inplace_comps,
    _op_traffic_bytes,
    _operand_names,
    _parse_computations,
    _shape_bytes,
)

_META_RE = re.compile(r'op_name="([^"]+)"')


def _attr(line: str) -> str:
    m = _META_RE.search(line)
    if not m:
        return "(no-metadata)"
    parts = m.group(1).split("/")
    return "/".join(parts[-2:]) if len(parts) >= 2 else m.group(1)


def breakdown(text: str, n_devices: int = 1):
    comps = _parse_computations(text)
    weights = _comp_weights(comps)
    fusion_bodies = _fusion_bodies(comps)
    inplace = _inplace_comps(comps)
    convert_bodies = _convert_comps(comps)
    slice_bodies = _slice_comps(comps)
    by_bytes: dict = defaultdict(float)
    by_flops: dict = defaultdict(float)
    by_coll: dict = defaultdict(float)
    for comp in comps.values():
        w = weights.get(comp.name, 1.0)
        in_fusion = comp.name in fusion_bodies
        for op in comp.ops:
            key = f"{op.opcode:22s} {_attr(op.line)}"
            if op.opcode == "dot":
                by_flops[key] += w * _dot_flops(op, comp)
            base = op.opcode.split("-start")[0]
            if base in _COLLECTIVES and "-done" not in op.opcode:
                by_coll[key] += w * _collective_payload(op, comp, n_devices)
                continue
            if in_fusion or op.opcode in _SKIP_BYTES_OPCODES:
                continue
            callee_inplace = callee_convert = callee_slices = False
            if op.opcode == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    callee_inplace = m.group(1) in inplace
                    callee_convert = m.group(1) in convert_bodies
                    callee_slices = m.group(1) in slice_bodies
            by_bytes[key] += w * _fusion_traffic_bytes(
                op, comp, callee_inplace, callee_convert, callee_slices
            )
    return by_bytes, by_flops, by_coll


def _print_top(title: str, d: dict, top: int, unit: float, suffix: str):
    total = sum(d.values())
    print(f"\n== {title} (total {total/unit:.2f} {suffix}) ==")
    for k, v in sorted(d.items(), key=lambda kv: -kv[1])[:top]:
        print(f"  {v/unit:10.2f} {suffix}  {100*v/max(total,1e-9):5.1f}%  {k}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--devices", type=int, default=256)
    args = ap.parse_args(argv)
    if args.path.endswith(".zst"):
        import zstandard

        text = zstandard.ZstdDecompressor().decompress(
            open(args.path, "rb").read()
        ).decode()
    else:
        text = open(args.path).read()
    by_bytes, by_flops, by_coll = breakdown(text, args.devices)
    _print_top("HBM bytes (per device)", by_bytes, args.top, 1e9, "GB")
    _print_top("FLOPs (per device)", by_flops, args.top, 1e12, "TF")
    _print_top("collective bytes (per device)", by_coll, args.top, 1e9, "GB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
