"""Public wrapper for the fused Kronecker transform kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kron_mul.kernel import kron_mul_kernel
from repro.kernels.kron_mul.ref import kron_mul_ref


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret", "force_kernel"))
def kron_mul(
    x: jax.Array,
    A: jax.Array,
    B: jax.Array,
    *,
    interpret: bool = False,
    force_kernel: bool = False,
) -> jax.Array:
    """y = (A ⊗ B) x along the last axis; arbitrary leading dims."""
    if not (on_tpu() or interpret or force_kernel):
        return kron_mul_ref(x, A, B)
    p, q = A.shape[0], B.shape[0]
    n = p * q
    lead = x.shape[:-1]
    N = 1
    for d in lead:
        N *= d
    x2 = x.reshape(N, n)
    bB = min(256, _ceil_to(N, 8))
    Np = _ceil_to(N, bB)
    if Np != N:
        x2 = jnp.pad(x2, ((0, Np - N), (0, 0)))
    y = kron_mul_kernel(x2, A, B, p=p, q=q, bB=bB, interpret=interpret)
    return y[:N].reshape(*lead, n)
