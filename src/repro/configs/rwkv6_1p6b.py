"""rwkv6-1.6b [ssm] — "Finch", data-dependent decay — arXiv:2404.05892."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # d_model / rwkv_head_size
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    rwkv_head_size=64,
    rwkv_decay_lora=64,
    rwkv_mix_lora=32,
    microbatch=32,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b-smoke",
        family="rwkv",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        rwkv_head_size=16,
        rwkv_decay_lora=8,
        rwkv_mix_lora=4,
        dtype="float32",
        microbatch=2,
        remat="none",
    )
