"""Unified cached prefill/decode forward over fp and QuIP-quantized models.

A :class:`CachedDecoder` holds per-layer *blocks*: norm params plus one
callable per linear projection, keyed exactly like
``launch.quantize.QuantizedModel.blocks`` ("attn.wq", ..., "mlp.wo").  For
the fp ``Model`` the callables close over dense params (``layers.apply_w``);
for a ``QuantizedModel`` they ARE the :class:`QuantizedLinear` layers, so
every projection runs the packed ``D⁻¹ → V → quant_matmul → Uᵀ`` structured
path — this replaces the old per-token full-recompute serving loop with a
real KV-cached decode for quantized weights.

The single forward handles both phases:

  * chunked prefill: ``tokens (1, C)`` attending to previously-written
    context pages + itself (causal);
  * batched decode: ``tokens (B, 1)`` with per-lane absolute positions, so
    sequences of different lengths decode in one batch (continuous
    batching).

Masking uses the same where-set convention as the quantized recompute path
so cached logits match it bit-for-bit up to matmul reassociation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.transformer import unstack_layers

__all__ = ["CachedDecoder"]


def _linear(p, cfg: ArchConfig, bias=None) -> Callable:
    if bias is None:
        return lambda x: L.apply_w(p, x, cfg)
    return lambda x: L.apply_w(p, x, cfg) + bias


def _fp_blocks(params, cfg: ArchConfig) -> list[dict]:
    blocks = []
    for lp in unstack_layers(params):
        at, mp = lp["attn"], lp["mlp"]
        blk = {
            "ln1": lp["ln1"],
            "ln2": lp["ln2"],
            "attn.wq": _linear(at["wq"], cfg, at.get("bq")),
            "attn.wk": _linear(at["wk"], cfg, at.get("bk")),
            "attn.wv": _linear(at["wv"], cfg, at.get("bv")),
            "attn.wo": _linear(at["wo"], cfg),
            "mlp.wi": _linear(mp["wi"], cfg, mp.get("bi")),
            "mlp.wo": _linear(mp["wo"], cfg, mp.get("bo")),
        }
        if cfg.mlp == "swiglu":
            blk["mlp.wg"] = _linear(mp["wg"], cfg)
        if cfg.qk_norm:
            blk["q_norm"] = at["q_norm"]
            blk["k_norm"] = at["k_norm"]
        blocks.append(blk)
    return blocks


@dataclasses.dataclass
class CachedDecoder:
    """KV-cached forward shared by the fp and quantized serving paths."""

    cfg: ArchConfig
    embed: dict
    final_norm: dict
    blocks: list

    def __post_init__(self):
        if self.cfg.family != "dense":
            raise ValueError(
                f"serving adapter supports the dense family, got {self.cfg.family}"
            )
        # blocks close over their params -> jit treats them as constants;
        # one compile per (adapter, tokens/ctx shape) pair.
        self._fwd = jax.jit(self._forward)

    # ---- constructors ---------------------------------------------------

    @classmethod
    def from_model(cls, model, params) -> "CachedDecoder":
        return cls(
            cfg=model.cfg,
            embed=params["embed"],
            final_norm=params["final_norm"],
            blocks=_fp_blocks(params, model.cfg),
        )

    @classmethod
    def from_quantized(cls, qm) -> "CachedDecoder":
        # QuantizedModel.blocks already has the expected structure, with
        # QuantizedLinear instances as the projection callables.
        return cls(
            cfg=qm.cfg, embed=qm.embed, final_norm=qm.final_norm,
            blocks=qm.blocks,
        )

    # ---- forward --------------------------------------------------------

    def __call__(self, tokens, positions, ctx_k, ctx_v, ctx_len):
        """Cached forward.

        tokens    (B, T) int32 — new tokens (decode: T=1; prefill: B=1);
        positions (B, T) int32 — absolute position of each new token;
        ctx_k/v   (L, B, S, KV, hd) — gathered context pages (post-RoPE K);
        ctx_len   (B,) int32 — valid context tokens per lane.

        Returns (logits (B, T, V), k_new (L, B, T, KV, hd), v_new (same)).
        """
        return self._fwd(tokens, positions, ctx_k, ctx_v, ctx_len)

    def _forward(self, tokens, positions, ctx_k, ctx_v, ctx_len):
        cfg = self.cfg
        x = L.embed(self.embed, tokens)
        new_k, new_v = [], []
        for i, blk in enumerate(self.blocks):
            x, k, v = self._block(blk, x, positions, ctx_k[i], ctx_v[i], ctx_len)
            new_k.append(k)
            new_v.append(v)
        x = L.norm_apply(self.final_norm, x, cfg)
        logits = L.lm_logits(self.embed, x)
        return logits, jnp.stack(new_k), jnp.stack(new_v)

    def _block(self, blk, x, positions, ck, cv, ctx_len):
        cfg = self.cfg
        B, T, _ = x.shape
        S = ck.shape[1]
        h = L.norm_apply(blk["ln1"], x, cfg)
        q = blk["attn.wq"](h).reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = blk["attn.wk"](h).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = blk["attn.wv"](h).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = L.rms_norm(q, blk["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, blk["k_norm"], cfg.norm_eps)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        k_all = jnp.concatenate([ck.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([cv.astype(v.dtype), v], axis=1)
        s = L._gqa_scores(q, k_all, cfg)  # (B, KV, G, T, S+T)
        # context keys: valid below each lane's ctx_len; new keys: causal
        # within the chunk (their absolute positions are >= every ctx pos).
        mask_ctx = jnp.arange(S)[None, None, :] < ctx_len[:, None, None]
        mask_ctx = jnp.broadcast_to(mask_ctx, (B, T, S))
        mask_new = jnp.broadcast_to(
            jnp.tril(jnp.ones((T, T), bool))[None], (B, T, T)
        )
        mask = jnp.concatenate([mask_ctx, mask_new], axis=-1)
        s = jnp.where(mask[:, None, None], s, -1e30)
        probs = jax.nn.softmax(s, axis=-1)
        o = L._gqa_out(probs, v_all, cfg)
        o = o.astype(x.dtype).reshape(B, T, cfg.q_dim)
        x = x + blk["attn.wo"](o)
        h = L.norm_apply(blk["ln2"], x, cfg)
        up = blk["mlp.wi"](h)
        if cfg.mlp == "swiglu":
            up = jax.nn.silu(up) * blk["mlp.wg"](h)
        else:
            up = jax.nn.gelu(up)
        return x + blk["mlp.wo"](up), k, v
