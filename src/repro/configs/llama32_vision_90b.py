"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer;
patch frontend stubbed — hf:meta-llama/Llama-3.2-11B-Vision family."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,        # 80 self + 20 gated cross-attn
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    cross_every=5,
    n_patches=1024,
    mlp="swiglu",
    rope_theta=5e5,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b-smoke",
        family="vlm",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        cross_every=2,
        n_patches=16,
        mlp="swiglu",
        dtype="float32",
        microbatch=2,
        remat="none",
    )
