"""Tensor-parallel serving tests (serve/distributed.py).

Need a multi-device host: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (scripts/ci.sh
does); on the default single-device CPU the whole module skips.
Everything asserts TOKEN-IDENTICAL behavior vs the single-device engine —
sharding is a layout choice, never a numerics choice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_hessian, make_weights

from repro.configs import get_smoke_config
from repro.core.quantizer import QuipConfig, quantize_layer
from repro.data import make_calibration
from repro.models import build_model
from repro.serve import (
    CachedDecoder,
    DistributedCachedDecoder,
    Engine,
    EngineConfig,
    make_serving_mesh,
    save_quantized,
)
from repro.serve.distributed import (
    PACKED_AXES,
    shard_quantized_linear,
    shard_quantized_model,
)
from repro.runtime.sharding import MeshContext, serving_rules

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a multi-device host "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def mesh():
    return make_serving_mesh(1, 2)


@pytest.fixture(scope="module")
def ctx(mesh):
    return MeshContext(mesh=mesh, rules=serving_rules())


def _smoke_cfg():
    return get_smoke_config("qwen3-14b")


@pytest.fixture(scope="module")
def quantized_smoke():
    from repro.launch.quantize import quantize_dense_model

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = make_calibration(cfg.vocab, n_segments=4, seg_len=32, seed=7)
    qcfg = QuipConfig(bits=2, method="ldlq", use_kernel=False)
    qm = quantize_dense_model(params, cfg, qcfg, calib.tokens, seed=0,
                              verbose=False)
    return cfg, qm, qcfg


def _run_engine(adapter, prompts, gen, **ecfg_kw):
    kw = dict(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8, paged_decode=True,
    )
    kw.update(ecfg_kw)
    engine = Engine(adapter, EngineConfig(**kw))
    reqs = [engine.submit(np.asarray(p), max_new=gen) for p in prompts]
    engine.run()
    return engine, [np.asarray(r.out_tokens) for r in reqs]


# ---------------------------------------------------------------------------
# Sharded quantized linears
# ---------------------------------------------------------------------------


def test_sharded_linear_outputs_match_unsharded(ctx):
    """Column- and row-parallel packed placements both reproduce the
    unsharded structured inference path (up to matmul reassociation)."""
    W, H = make_weights(64, 128, seed=3), make_hessian(128, seed=3)
    layer, _ = quantize_layer(
        W, H, QuipConfig(bits=2, use_kernel=False), seed=1,
        collect_stats=False,
    )
    x = make_weights(5, 128, seed=9)
    y0 = np.asarray(layer(x))
    for name in ("attn.wq", "attn.wo"):  # one column-, one row-parallel
        sharded = shard_quantized_linear(layer, ctx, name)
        y = np.asarray(jax.jit(lambda xx: sharded(xx))(x))
        np.testing.assert_allclose(y, y0, rtol=0, atol=1e-5)


def test_shard_quantized_model_layout_and_originals(ctx, quantized_smoke):
    """Every packed tensor lands model-axis-sharded per PACKED_AXES; the
    input model's arrays are untouched (fully replicated, single device)."""
    _, qm, _ = quantized_smoke
    sq = shard_quantized_model(qm, ctx)
    for blk, blk0 in zip(sq.blocks, qm.blocks):
        for name, axes in PACKED_AXES.items():
            if name not in blk:
                continue
            spec = tuple(blk[name].packed.sharding.spec)
            want = tuple("model" if a else None for a in axes)
            assert spec == want, (name, spec)
            # original stays where it was
            assert len(blk0[name].packed.devices()) == 1


# ---------------------------------------------------------------------------
# Sharded artifact load round-trip
# ---------------------------------------------------------------------------


def test_sharded_artifact_load_roundtrip(tmp_path, mesh, quantized_smoke):
    """load(mesh=...) streams packed codes straight onto the mesh and the
    resulting per-linear outputs match the plainly-loaded artifact."""
    from repro.serve.artifacts import load_quantized

    cfg, qm, qcfg = quantized_smoke
    save_quantized(tmp_path / "art", qm, qcfg)
    adapter, meta = DistributedCachedDecoder.load(tmp_path / "art", mesh=mesh)
    assert meta["quip_config"]["bits"] == 2
    qm_plain, _ = load_quantized(tmp_path / "art")
    for blk_s, blk_p in zip(adapter.blocks, qm_plain.blocks):
        for name in PACKED_AXES:
            if name not in blk_s:
                continue
            lin_s, lin_p = blk_s[name], blk_p[name]
            assert "model" in tuple(lin_s.packed.sharding.spec)
            x = make_weights(3, lin_p.n, seed=13)
            np.testing.assert_allclose(
                np.asarray(lin_s(x)), np.asarray(lin_p(x)), rtol=0, atol=1e-5
            )


# ---------------------------------------------------------------------------
# Sharded page pool
# ---------------------------------------------------------------------------


def _adapters(mesh):
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return (
        CachedDecoder.from_model(model, params),
        DistributedCachedDecoder.from_model(model, params, mesh=mesh),
        model, params,
    )


@pytest.mark.parametrize("dtype", [None, jnp.int8])
def test_sharded_pool_accounting_and_roundtrip(mesh, dtype):
    """The sharded pool is byte-for-byte the same accounting machine as
    the single-device pool (admit/extend/release are host-side), its
    physical pages split over KV heads (device_bytes == total/mp), and
    write/gather round-trips bit-identically."""
    plain, dist, *_ = _adapters(mesh)
    kw = dict(n_pages=9, page_size=4, n_slots=3, max_pages_per_seq=4,
              dtype=dtype)
    p0, p1 = plain.make_pool(**kw), dist.make_pool(**kw)
    mp = mesh.shape["model"]
    assert p1.total_bytes() == p0.total_bytes()
    assert p1.device_bytes() == p0.total_bytes() // mp
    assert tuple(p1.k.sharding.spec) == (None, None, None, "model", None)
    # identical admit/extend/evict decisions
    for pool in (p0, p1):
        a = pool.admit(5)
        b = pool.admit(9)
        assert (a, b) == (0, 1)
        assert pool.extend(a, 8) and not pool.extend(b, 17)
        pool.release(b)
        assert pool.pages_in_use == 2
    # write/gather round-trip through the sharded buffers
    cfg = _smoke_cfg()
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jax.random.normal(jax.random.PRNGKey(2), (L, 6, KV, hd), jnp.float32)
    for pool in (p0, p1):
        pool.write_span(0, 0, 6, k, -k)
    g0, g1 = p0.gather([0])[0], p1.gather([0])[0]
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))


# ---------------------------------------------------------------------------
# TP engine vs single-device engine: token parity
# ---------------------------------------------------------------------------


def test_tp_engine_fp_token_parity(mesh):
    plain, dist, model, params = _adapters(mesh)
    cfg = model.cfg
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=3).tokens
    _, t0 = _run_engine(plain, prompts, 6)
    eng, t1 = _run_engine(dist, prompts, 6)
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)
    assert eng.pool.device_bytes() * mesh.shape["model"] \
        == eng.pool.total_bytes()


def test_tp_engine_quantized_token_parity(mesh, quantized_smoke):
    """The ISSUE acceptance check: a 2-device model mesh serving sharded
    packed weights over the sharded pool emits the exact token stream of
    the single-device paged engine."""
    cfg, qm, _ = quantized_smoke
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=12,
                               seed=5).tokens
    _, t0 = _run_engine(CachedDecoder.from_quantized(qm), prompts, 5)
    _, t1 = _run_engine(
        DistributedCachedDecoder.from_quantized(qm, mesh=mesh), prompts, 5
    )
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)


def test_tp_engine_int8_pages_token_parity(mesh):
    plain, dist, model, params = _adapters(mesh)
    cfg = model.cfg
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=9,
                               seed=8).tokens
    _, t0 = _run_engine(plain, prompts, 5, kv_int8=True)
    _, t1 = _run_engine(dist, prompts, 5, kv_int8=True)
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)


def test_tp_engine_eviction_token_parity(mesh):
    """Eviction/requeue (host-side scheduling over the sharded pool) and
    re-prefill through the sharded gather path keep exact tokens."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8,
                               seed=4).tokens
    dist = DistributedCachedDecoder.from_model(model, params, mesh=mesh)
    eng, t1 = _run_engine(dist, prompts, 8, n_slots=3, page_size=4,
                          n_pages=10)
    assert eng.stats["evictions"] > 0
    plain = CachedDecoder.from_model(model, params)
    _, t0 = _run_engine(plain, prompts, 8, n_slots=3, page_size=4,
                        n_pages=10)
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)


def test_tp_engine_batched_prefill_token_parity(mesh):
    """Batched paged prefill under shard_map (chunk attention over the
    KV-head-sharded pool) emits the single-device engine's exact tokens."""
    plain, dist, model, params = _adapters(mesh)
    cfg = model.cfg
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=3).tokens
    _, t0 = _run_engine(plain, prompts, 6, paged_prefill=True)
    eng, t1 = _run_engine(dist, prompts, 6, paged_prefill=True)
    assert eng.stats["prefill_batches"] > 0
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)


def test_tp_engine_batched_prefill_int8_token_parity(mesh):
    plain, dist, model, params = _adapters(mesh)
    cfg = model.cfg
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=9,
                               seed=8).tokens
    _, t0 = _run_engine(plain, prompts, 5, paged_prefill=True, kv_int8=True)
    _, t1 = _run_engine(dist, prompts, 5, paged_prefill=True, kv_int8=True)
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)


def test_tp_engine_prefix_cache_token_parity(mesh):
    """Prefix-cache hits over the sharded pool: the COW page copy and
    shared-page mapping are layout-preserving (host accounting is device-
    agnostic), so TP serving with prefix caching matches single-device."""
    plain, dist, model, params = _adapters(mesh)
    cfg = model.cfg
    base = make_calibration(cfg.vocab, n_segments=1, seg_len=8, seed=5).tokens
    prompts = np.tile(np.asarray(base), (3, 1))  # 8 tokens == 2 full pages

    def run(adapter):
        engine = Engine(adapter, EngineConfig(
            max_seq_len=prompts.shape[1] + 5, n_slots=4, page_size=4,
            token_budget=32, prefill_chunk=8, paged_decode=True,
            paged_prefill=True, prefix_cache=True,
        ))
        reqs = [
            engine.submit(np.asarray(p), max_new=5, arrival=0.2 * i)
            for i, p in enumerate(prompts)
        ]
        engine.run()
        return engine, [np.asarray(r.out_tokens) for r in reqs]

    e0, t0 = run(plain)
    e1, t1 = run(dist)
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)
    s0, s1 = e0.summary(), e1.summary()
    assert s1["prefix_hit_tokens"] == s0["prefix_hit_tokens"] > 0
    assert s1["cow_copies"] == s0["cow_copies"] >= 1  # copy-on-admit ran


def test_indivisible_kv_heads_fall_back_replicated(quantized_smoke):
    """A model axis the KV-head count cannot divide degrades to the
    replicated pool + single-device attention math — same tokens, no
    crash (the divisibility fallback)."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices for an indivisible model axis")
    cfg, qm, _ = quantized_smoke
    assert cfg.n_kv_heads % 4 != 0  # smoke config has 2 KV heads
    mesh4 = make_serving_mesh(1, 4)
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=10,
                               seed=6).tokens
    _, t0 = _run_engine(CachedDecoder.from_quantized(qm), prompts, 4)
    dist = DistributedCachedDecoder.from_quantized(qm, mesh=mesh4)
    eng, t1 = _run_engine(dist, prompts, 4)
    assert not dist._pool_sharded
    assert eng.pool.device_bytes() == eng.pool.total_bytes()
    for a, b in zip(t0, t1):
        np.testing.assert_array_equal(a, b)


def test_tp_engine_speculative_token_parity(mesh):
    """Speculative draft-and-verify under shard_map: the TP engine's
    greedy stream is token-identical to the single-device speculative
    engine (which is itself pinned to the one-token path), with real
    draft acceptance on a cyclic workload."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.tile(np.asarray([7, 91, 33, 150], np.int32), (3, 8))
    gen = 10
    kw = dict(speculative_k=4, device_sample=True)
    eng_tp, tp = _run_engine(
        DistributedCachedDecoder.from_model(model, params, mesh=mesh),
        prompts, gen, **kw,
    )
    assert eng_tp.summary()["accepted_tokens"] > 0
    _, single = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen, **kw,
    )
    for a, b in zip(tp, single):
        np.testing.assert_array_equal(a, b)


def test_tp_engine_speculative_int8_token_parity(mesh):
    """Speculative verify over int8 sharded pages (round-tripped chunk
    K/V + fp diagonal override, all under shard_map) matches the
    single-device int8 speculative engine exactly."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.tile(np.asarray([7, 91, 33, 150], np.int32), (3, 8))
    gen = 8
    kw = dict(speculative_k=4, device_sample=True, kv_int8=True)
    _, tp = _run_engine(
        DistributedCachedDecoder.from_model(model, params, mesh=mesh),
        prompts, gen, **kw,
    )
    _, single = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen, **kw,
    )
    for a, b in zip(tp, single):
        np.testing.assert_array_equal(a, b)


def test_tp_engine_device_sampled_stream_parity(mesh):
    """On-device sampling (fold_in keys) is layout-independent: the TP
    engine draws the exact sampled stream of the single-device engine."""
    from repro.serve.scheduler import SamplingParams

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=2).tokens
    gen = 6
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=23)

    def run(adapter):
        engine = Engine(adapter, EngineConfig(
            max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
            token_budget=32, prefill_chunk=8, paged_decode=True,
            device_sample=True,
        ))
        reqs = [engine.submit(np.asarray(p), max_new=gen, sampling=sp)
                for p in prompts]
        engine.run()
        return [np.asarray(r.out_tokens) for r in reqs]

    tp = run(DistributedCachedDecoder.from_model(model, params, mesh=mesh))
    single = run(CachedDecoder.from_model(model, params))
    for a, b in zip(tp, single):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Telemetry under TP: tracing never changes tokens; spans carry mesh tags
# ---------------------------------------------------------------------------


def test_tp_engine_traced_token_parity_and_mesh_tags(mesh, tmp_path):
    """A sync tracer attached to the TP engine must not perturb the token
    stream, and every exported span must carry the mesh geometry tags
    (DistributedCachedDecoder.trace_tags) so distributed traces stay
    interpretable offline."""
    from repro.serve import Tracer, phase_breakdown, validate_chrome_trace

    plain, dist, model, params = _adapters(mesh)
    cfg = model.cfg
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=9).tokens
    gen = 5
    _, t0 = _run_engine(plain, prompts, gen)
    tracer = Tracer(sync=True)
    engine = Engine(dist, EngineConfig(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8, paged_decode=True,
    ), tracer=tracer)
    reqs = [engine.submit(np.asarray(p), max_new=gen) for p in prompts]
    engine.run()
    for a, b in zip(t0, [np.asarray(r.out_tokens) for r in reqs]):
        np.testing.assert_array_equal(a, b)
    assert tracer.tags["mesh_model"] == mesh.shape["model"]
    assert tracer.tags["mesh_data"] == mesh.shape["data"]
    assert tracer.tags["pool_sharded"] is True
    obj = tracer.export_chrome_trace(tmp_path / "tp_trace.json")
    validate_chrome_trace(obj)
    spans = [e for e in obj["traceEvents"] if e.get("ph") == "X"]
    assert spans and all(
        e["args"]["mesh_model"] == mesh.shape["model"] for e in spans
    )
    assert phase_breakdown(tracer.spans)["coverage"] >= 0.95


# ---------------------------------------------------------------------------
# Quality canaries under TP: the sharded canary scores what the unsharded
# one would (DESIGN.md §13)
# ---------------------------------------------------------------------------


def test_tp_canary_nll_matches_single_device(mesh):
    """The teacher-forced canary probe runs the same dense trunk on
    single-device and TP adapters; GSPMD sharding is a layout choice,
    so the NLL must agree to float tolerance (the probe's NLL itself is
    float64 on host — any divergence is real logit drift)."""
    from repro.serve.quality import teacher_forced_nll

    plain, dist, model, _ = _adapters(mesh)
    canary = make_calibration(model.cfg.vocab, n_segments=2, seg_len=12,
                              seed=99).tokens
    single = teacher_forced_nll(plain, canary)
    sharded = teacher_forced_nll(dist, canary)
    assert abs(single - sharded) < 1e-6


def test_tp_engine_canary_gauge_matches_offline(mesh):
    """End-to-end: a TP engine's canary gauge equals the offline
    teacher-forced NLL computed through the same sharded adapter."""
    from repro.serve.quality import teacher_forced_nll

    _, dist, model, _ = _adapters(mesh)
    cfg = model.cfg
    canary = make_calibration(cfg.vocab, n_segments=2, seg_len=12,
                              seed=99).tokens
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=8,
                               seed=3).tokens
    gen = 3
    engine = Engine(dist, EngineConfig(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8, paged_decode=True,
        canary_every=1e-4,
    ))
    engine.attach_canary(canary)
    for p in prompts:
        engine.submit(np.asarray(p), max_new=gen)
    engine.run()
    s = engine.summary()
    assert s["canary_runs"] >= 1
    assert s["canary_nll"] == teacher_forced_nll(dist, canary)
