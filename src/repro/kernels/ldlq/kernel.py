"""Pallas TPU kernel: in-block sequential LDLQ rounding.

The LDLQ column recurrence is sequential in n but embarrassingly parallel
in m (rows/neurons quantize independently — Eq. 1 is per-row).  The blocked
schedule (GPTQ-style, kernels mirror `core.ldlq.ldlq_blocked`):

  outer (XLA):  base = Err_prev @ U_panel  — one big MXU matmul
  inner (THIS): for k in range(nb):        — nb = 128 columns
                    val = W[:, k] + base[:, k] + E @ U_blk[:, k]
                    q   = clamp(round(val)); E[:, k] = W[:, k] + base[:,k] - q

The kernel grids over ROW blocks (bM x nb panels in VMEM); the inner
fori_loop does nb (bM,)·(nb,) mat-vecs on the VPU with the error matrix E
resident in VMEM — the sequential part never touches HBM.  nb = 128
matches the VREG lane width and MXU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ldlq_kernel(w_ref, b_ref, u_ref, q_ref, e_ref, *, nb: int, maxq: int):
    W = w_ref[...].astype(jnp.float32)  # (bM, nb) raw block weights
    base = b_ref[...].astype(jnp.float32)  # (bM, nb) cross-block feedback
    U = u_ref[...].astype(jnp.float32)  # (nb, nb) strictly upper block

    def body(k, carry):
        Q, E = carry
        corr = E @ jax.lax.dynamic_slice(U, (0, k), (nb, 1))  # (bM, 1)
        wk = jax.lax.dynamic_slice(W, (0, k), (W.shape[0], 1))
        bk = jax.lax.dynamic_slice(base, (0, k), (W.shape[0], 1))
        q = jnp.clip(jnp.round(wk + bk + corr), 0.0, float(maxq))
        # the recurrence feeds back (W - What), NOT (W + base - What)
        Q = jax.lax.dynamic_update_slice(Q, q, (0, k))
        E = jax.lax.dynamic_update_slice(E, wk - q, (0, k))
        return Q, E

    Q0 = jnp.zeros_like(W)
    E0 = jnp.zeros_like(W)
    Q, E = jax.lax.fori_loop(0, nb, body, (Q0, E0))
    q_ref[...] = Q.astype(q_ref.dtype)
    e_ref[...] = E.astype(e_ref.dtype)


@functools.partial(jax.jit, static_argnames=("nb", "bM", "maxq", "interpret"))
def ldlq_block_kernel(
    Wb: jax.Array,
    base: jax.Array,
    Ub: jax.Array,
    *,
    nb: int,
    bM: int = 256,
    maxq: int = 3,
    interpret: bool = False,
):
    """Wb, base: (M, nb); Ub: (nb, nb).  M % bM == 0.

    Returns (Q, E): quantized block and its true error (W_block - Q)."""
    M, n = Wb.shape
    if n != nb:
        raise ValueError(
            f"W block has {n} columns but the kernel was asked for nb={nb}"
        )
    if M % bM:
        raise ValueError(
            f"row count M={M} must be a multiple of the row tile bM={bM}"
        )
    grid = (M // bM,)
    return pl.pallas_call(
        functools.partial(_ldlq_kernel, nb=nb, maxq=maxq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bM, nb), lambda i: (i, 0)),
            pl.BlockSpec((bM, nb), lambda i: (i, 0)),
            pl.BlockSpec((nb, nb), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bM, nb), lambda i: (i, 0)),
            pl.BlockSpec((bM, nb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, nb), jnp.float32),
            jax.ShapeDtypeStruct((M, nb), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(Wb, base, Ub)
