"""Self-drafting proposal sources for speculative decode.

A drafter proposes up to K likely next tokens for a lane from nothing but
that lane's own token history (prompt + generated so far) — no second
model, no extra device work.  The engine feeds the proposals to the fused
verifier dispatch (``CachedDecoder.verify_paged``), which accepts the
longest prefix matching what the target model would have emitted anyway.
Wrong proposals cost one rolled-back page write, never a wrong token, so
a drafter only ever trades wasted verify FLOPs for accepted tokens.

:class:`NgramDrafter` is prompt-lookup decoding: find the most recent
earlier occurrence of the lane's trailing n-gram and propose its
continuation, one token at a time — each drafted token is appended to a
hypothetical history before the next lookup, so a periodic stream
(repeated spans, code/JSON boilerplate, retrieval-echoed prompt text)
drafts at full depth K instead of truncating at the history's edge.
"""
from __future__ import annotations

import numpy as np

__all__ = ["NgramDrafter", "make_drafter"]


class NgramDrafter:
    """Prompt-lookup drafter over a lane's own token history.

    ``max_ngram`` bounds the pattern length tried (longest first — longer
    matches are more specific, so their continuations are more likely to
    be accepted); the minimum is a single-token match.
    """

    def __init__(self, k: int, max_ngram: int = 3):
        if k < 1:
            raise ValueError(f"draft depth k must be >= 1, got {k}")
        if max_ngram < 1:
            raise ValueError(f"max_ngram must be >= 1, got {max_ngram}")
        self.k = k
        self.max_ngram = max_ngram

    @staticmethod
    def _lookup_next(hist: np.ndarray, N: int, max_ngram: int):
        """Token following the most recent earlier occurrence of the
        trailing n-gram of ``hist[:N]`` (longest n first), or None.
        Shifted-slice compares, no window materialization — this runs
        per lane per drafted token on the engine's hot path."""
        for n in range(min(max_ngram, N - 1), 0, -1):
            # candidate starts j in [0, N-1-n]: the continuation
            # hist[j+n] always exists, and the trailing n-gram itself
            # (j == N-n) is excluded by the range
            m = hist[0 : N - n] == hist[N - n]
            for i in range(1, n):
                m &= hist[i : N - n + i] == hist[N - n + i]
            hit = np.flatnonzero(m)
            if hit.size:
                return int(hist[hit[-1] + n])
        return None

    def propose(self, history, k: int | None = None) -> np.ndarray:
        """Up to ``k`` (default: the drafter's depth) proposed tokens for
        the given history; may return fewer (or none) when no n-gram
        matches.  Iterative: each drafted token extends the hypothetical
        history before the next lookup, so periodic tails draft at full
        depth rather than stopping at the history's end."""
        k = self.k if k is None else min(k, self.k)
        src = np.asarray(history, np.int32).reshape(-1)
        N = len(src)
        hist = np.empty(N + k, np.int32)
        hist[:N] = src
        drafted = 0
        while drafted < k:
            nxt = self._lookup_next(hist, N + drafted, self.max_ngram)
            if nxt is None:
                break
            hist[N + drafted] = nxt
            drafted += 1
        return hist[N : N + drafted].copy()


def make_drafter(kind: str, k: int, **kw):
    """Build a drafter by name (``launch/serve.py --draft``)."""
    if kind == "ngram":
        return NgramDrafter(k, **kw)
    raise ValueError(f"unknown drafter {kind!r} (available: ngram)")
