"""End-to-end PTQ scenario: train a small LM briefly, QuIP-quantize it to
2 bits block-by-block (paper Sec. 6 schedule), and serve both models.

    PYTHONPATH=src python examples/quantize_and_serve.py
"""
import jax
import jax.numpy as jnp

from benchmarks.common import trained_lm
from repro.core.quantizer import QuipConfig
from repro.data import make_calibration
from repro.launch.quantize import perplexity, quantize_dense_model

cfg, model, params = trained_lm(steps=120)
calib = make_calibration(cfg.vocab, n_segments=16, seg_len=128, seed=7)
eval_toks = make_calibration(cfg.vocab, n_segments=8, seg_len=128, seed=99).tokens

print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")
ppl_fp = perplexity(
    lambda t: model.logits(params, model.forward(params, {"tokens": t})[0]),
    eval_toks,
)
print(f"fp32 perplexity: {ppl_fp:.2f}")

for bits in (4, 2):
    qcfg = QuipConfig(bits=bits, method="ldlq", incoherence=True, use_kernel=False)
    qm = quantize_dense_model(params, cfg, qcfg, calib.tokens, verbose=False)
    ppl = perplexity(qm.logits, eval_toks)
    print(f"QuIP {bits}-bit perplexity: {ppl:.2f} "
          f"({(ppl/ppl_fp-1)*100:+.1f}% vs fp)")

# greedy generation through the packed 2-bit path — KV-cached continuous
# batching (repro.serve), not per-token prefix recompute
import numpy as np

from repro.serve import Engine, EngineConfig

engine = Engine(qm.cached_decoder(), EngineConfig(max_seq_len=16 + 12))
for p in np.asarray(eval_toks[:2, :16]):
    engine.submit(p, max_new=12)
done = engine.run()
print("2-bit generation:", done[0].out_tokens)
print("engine:", engine.summary())
