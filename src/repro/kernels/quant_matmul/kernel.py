"""Pallas TPU kernel: packed low-bit weight × activation matmul.

Computes ``acc[b, j] = Σ_k x[b, k] · unpack(packed)[k, j]`` where ``packed``
holds b-bit integer grid values packed along the reduction dim
(``repro.core.packing`` layout: value ``k = kp*vals + v`` lives in bits
``[bits*v, bits*(v+1))`` of word ``packed[kp, j]``).

TPU mapping
-----------
* 3D grid ``(B/bB, M/bM, K/bK)``; K innermost ("arbitrary") so the f32
  output tile stays resident in VMEM and is revisited as an accumulator.
* Per step the kernel unpacks a ``(bK/vals, bM)`` int32 word tile into a
  ``(bK, bM)`` operand on the VPU (shift+mask, one reshape across the
  sublane axis) and feeds the MXU via ``jnp.dot`` with fp32 accumulation.
* Packing along K means the unpacked tile is already in (K, M) operand
  layout — no in-VMEM transpose.
* Arithmetic intensity vs a bf16 weight matmul rises ~16/bits×: at 2 bits a
  d_model=8192 decode matvec moves 16× fewer weight bytes, which is what
  makes 2-bit decode compute- rather than HBM-bound (DESIGN.md §3).

The affine dequant ``w = (2s/maxq)·q − s`` is applied *outside* (ops.py):
``z = (2s/maxq)·acc − s·Σ_k x[b,k]`` — keeping the kernel a pure integer-
grid matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _qmm_kernel(x_ref, p_ref, o_ref, *, bits: int, n_k_tiles: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = 32 // bits
    mask = jnp.uint32(2**bits - 1)
    words = p_ref[...].astype(jnp.uint32)  # (bKp, bM)
    bkp, bm = words.shape
    shifts = (jnp.arange(vals, dtype=jnp.uint32) * bits)[None, :, None]
    w = ((words[:, None, :] >> shifts) & mask).astype(jnp.float32)
    w = w.reshape(bkp * vals, bm)  # (bK, bM) grid values, K-major
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "bB", "bM", "bK", "interpret")
)
def quant_matmul_kernel(
    x: jax.Array,
    packed: jax.Array,
    *,
    bits: int,
    bB: int = 128,
    bM: int = 128,
    bK: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """x: (B, K) fp; packed: (K/vals, M) int32 → (B, M) f32 grid-matmul.

    B, M, K must be multiples of the respective tiles (ops.py pads).
    bK must be a multiple of ``vals = 32 // bits``.
    """
    B, K = x.shape
    vals = 32 // bits
    Kp, M = packed.shape
    if Kp * vals != K:
        raise ValueError(
            f"packed rows {Kp} x {vals} vals/word = {Kp * vals} does not "
            f"cover the reduction dim K={K} of x {x.shape} at {bits} bits"
        )
    if B % bB or M % bM or K % bK:
        raise ValueError(
            f"dims (B={B}, M={M}, K={K}) must be multiples of tiles "
            f"(bB={bB}, bM={bM}, bK={bK}) — pad via ops.quant_matmul"
        )
    if bK % vals:
        raise ValueError(
            f"K tile bK={bK} must be a multiple of vals-per-word {vals} "
            f"({bits}-bit packing)"
        )
    grid = (B // bB, M // bM, K // bK)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, bits=bits, n_k_tiles=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bK), lambda i, j, k: (i, k)),
            pl.BlockSpec((bK // vals, bM), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bB, bM), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, packed)
