"""Fault-tolerant checkpointing: atomic sharded npz, keep-k, auto-resume."""
from repro.checkpoint.store import (
    CheckpointManager,
    latest_step,
    load_arrays,
    load_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointManager",
    "save_checkpoint",
    "load_checkpoint",
    "load_arrays",
    "latest_step",
]
