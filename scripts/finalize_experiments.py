"""Splice the live roofline + perf tables into EXPERIMENTS.md.

    PYTHONPATH=src python scripts/finalize_experiments.py
"""
from __future__ import annotations

import json
import pathlib
import re

DRY = pathlib.Path("experiments/dryrun")


def fmt_s(v):
    if v >= 1:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v*1e3:.1f}ms"
    return f"{v*1e6:.0f}us"


def row(rec):
    t = rec["roofline"]
    step = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return (
        f"| {rec['arch']} | {rec['shape']} | {fmt_s(t['compute_s'])} | "
        f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
        f"{t['dominant']} | {t['useful_ratio']:.2f} | "
        f"{t['mfu_bound']*100:.2f}% |"
    )


def baseline_table() -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant |"
        " useful | MFU-bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    skips = []
    for f in sorted(DRY.glob("*__pod1.json")):
        if not f.name.endswith("__pod1.json"):
            continue
        rec = json.load(open(f))
        if rec.get("status") == "ok":
            lines.append(row(rec))
    # note assigned skips
    from repro.configs import ARCH_IDS, get_config, shapes_for, SHAPES

    for a in ARCH_IDS:
        names = {s.name for s in shapes_for(get_config(a))}
        for s in SHAPES:
            if s not in names:
                skips.append(f"| {a} | {s} | — | — | — | skipped (full attention) | — | — |")
    return "\n".join(lines + skips)


def variant_rows(cell_prefix: str, tags: list[str]) -> str:
    lines = [
        "| variant | compute | memory | collective | step bound | MFU-bound |",
        "|---|---|---|---|---|---|",
    ]
    for tag in tags:
        f = DRY / (f"{cell_prefix}.{tag}.json" if tag else f"{cell_prefix}.json")
        if not f.exists():
            continue
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            lines.append(f"| {tag or 'baseline'} | ERROR | | | | |")
            continue
        t = rec["roofline"]
        step = max(t["compute_s"], t["memory_s"], t["collective_s"])
        lines.append(
            f"| {tag or 'baseline'} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{fmt_s(step)}** | {t['mfu_bound']*100:.2f}% |"
        )
    return "\n".join(lines)


def perf_final() -> str:
    out = ["### Final measured ladders (post accounting-v3; per-device per step)\n"]
    out.append("**Cell A — mistral-large-123b decode_32k**\n")
    out.append(variant_rows(
        "mistral-large-123b__decode_32k__pod1",
        ["", "a1_bf16pv", "a2_serving", "a3_int8kv", "a4_w2bit",
         "a5_tp256", "a6_tp64"],
    ))
    out.append(
        "\nEnd-to-end: step bound 578 ms -> 74 ms (**7.8x**), "
        "collective-bound -> memory-bound, MFU-bound 0.11% -> 0.84%.  "
        "a5/a6 re-slice the same 256 chips (decode wants max TP, not FSDP: "
        "activations are tiny, weights dominate; TP-64 balances weight "
        "reads against per-layer activation all-reduces).  The remaining "
        "memory term still includes dequant+weight-dot traffic the Pallas "
        "quant_matmul keeps in VMEM on TPU — kernel-adjusted step "
        "~= 45 ms (**~13x** vs baseline).  The paper-faithful ladder is a1 "
        "(the paper gives no distribution scheme); a2-a6 are beyond-paper "
        "(serving rules, int8 KV, mesh re-slicing) + the paper's own 2-bit "
        "weights as a first-class model path (a4).\n")
    out.append("**Cell B — qwen3-14b train_4k**\n")
    out.append(variant_rows(
        "qwen3-14b__train_4k__pod1",
        ["", "b1_bf16pv", "b2_ctxpar", "b3_remat_dots", "b4_mb32",
         "b5_mb32_dots", "b6_bf16probs", "b7_fsdp2d", "b8_zero256",
         "b9_zero256_full", "b10_qc512"],
    ))
    out.append(
        "\nEnd-to-end: step bound 28.5 s -> 7.7 s (**3.7x**), MFU-bound "
        "8.2% -> **23.9%**.  The decisive iteration is b8/b9: profiling "
        "showed the dominant collective is the Megatron TP activation "
        "all-reduce, NOT FSDP weight gathers (b7's 2D-weight hypothesis "
        "REFUTED) — and a 14B model on 256 chips does not need tensor "
        "parallelism at all.  Re-slicing the same chips to (data=256, "
        "model=1) pure-ZeRO removes the TP all-reduces AND the 40-heads-"
        "on-16 divisibility problem in one move.  b9 swaps to full remat "
        "to fit HBM (temp 50.8 -> 17.4 GB; ~9% over the 16 GB v5e budget — "
        "fits v5p trivially; on v5e, host-offload the fp32 master or run "
        "(data=128, model=2)).  b2 (context parallelism) and b6 (bf16 "
        "probs) REFUTED as measured; b10 (<5%) hits the stopping rule.  "
        "Identified next step: Pallas flash attention (scores never reach "
        "HBM) -> memory term ~3 s, step ~4.4 s (collective-bound), "
        "MFU-bound ~42%.\n")
    out.append("**Cell C — rwkv6-1.6b decode_32k**\n")
    out.append(variant_rows(
        "rwkv6-1.6b__decode_32k__pod1", ["", "c1_serving"]))
    out.append(
        "\nEnd-to-end: 5.2 ms -> 1.8 ms (**2.9x**), collective-bound -> "
        "memory-bound, MFU-bound 0.14% -> 0.40%.\n")
    out.append("**Bonus cell D — arctic-480b train_4k (most collective-bound MoE)**\n")
    out.append(variant_rows(
        "arctic-480b__train_4k__pod1", ["", "d1_mb64", "d2_ep8", "d3_ep_only"]))
    out.append(
        "\nD1 (microbatch 64 + dots remat): confirmed, small (5%).  "
        "D2 (mesh (32,8), smaller EP groups): REFUTED — shrinking "
        "attention/dense TP grows data-axis traffic faster than it saves "
        "dispatch.  D3 (EP-only expert sharding, `expert_embed -> None`): "
        "the profiled top term IS the per-microbatch (E,C,F) all-reduce "
        "from FSDP-sharding the expert contraction dim, and removing it "
        "cuts collectives 24% (MFU 2.33 -> 3.08%) — but leaves arctic's "
        "457B expert params sharded only 16x: 288 GB/device temp.  "
        "REFUTED BY CAPACITY: even fully sharded, AdamW fp32 state is "
        "22.5 GB/device for this model at 256 chips; the honest fixes are "
        "Adafactor (implemented in optim/) or more chips, not a sharding "
        "rule.  Default rules keep EP x FSDP; the EP-only axis stays "
        "available for small-expert MoEs.\n")
    out.append("**Paper anchor — llama2-70b decode_32k, full QuIP serving stack**\n")
    out.append(variant_rows(
        "llama2-70b__decode_32k__pod1", ["paper_w2bit", "paper_best"]))
    out.append(
        "\npaper_w2bit = 16x16 mesh + serving rules + int8 KV + the paper's "
        "2-bit weights; paper_best adds the A6 mesh re-slice (4, 64).  "
        "54 ms per 128-sequence decode step = 2.4k tok/s/pod for the "
        "paper's own Table-1 model, with the 2-bit weights contributing "
        "the 8x weight-byte reduction that makes the step cache- rather "
        "than weight-bound (the TPU translation of the paper's Table 4).\n")
    return "\n".join(out)


def multipod_table() -> str:
    lines = [
        "| arch | pod1 step | pod1 MFU | pod2 step | pod2 MFU | scaling |",
        "|---|---|---|---|---|---|",
    ]
    for f1 in sorted(DRY.glob("*__train_4k__pod1.json")):
        arch = f1.name.split("__")[0]
        f2 = DRY / f"{arch}__train_4k__pod2.json"
        if not f2.exists():
            continue
        r1, r2 = json.load(open(f1)), json.load(open(f2))
        if r1.get("status") != "ok" or r2.get("status") != "ok":
            continue
        t1, t2 = r1["roofline"], r2["roofline"]
        s1 = max(t1["compute_s"], t1["memory_s"], t1["collective_s"])
        s2 = max(t2["compute_s"], t2["memory_s"], t2["collective_s"])
        lines.append(
            f"| {arch} | {fmt_s(s1)} | {t1['mfu_bound']*100:.2f}% | "
            f"{fmt_s(s2)} | {t2['mfu_bound']*100:.2f}% | {s1/s2:.2f}x |"
        )
    return "\n".join(lines)


def main():
    md = pathlib.Path("EXPERIMENTS.md").read_text()
    md = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\nPer-cell)",
        "<!-- ROOFLINE_TABLE -->\n" + baseline_table() + "\n\n",
        md, flags=re.S,
    ) if "<!-- ROOFLINE_TABLE -->" in md else md
    md = re.sub(
        r"<!-- PERF_FINAL -->.*?(?=\n### Stopping)",
        "<!-- PERF_FINAL -->\n" + perf_final() + "\n",
        md, flags=re.S,
    ) if "<!-- PERF_FINAL -->" in md else md
    md = re.sub(
        r"<!-- MULTIPOD_TABLE -->.*?(?=\n## |\Z)",
        "<!-- MULTIPOD_TABLE -->\n" + multipod_table() + "\n\n",
        md, flags=re.S,
    ) if "<!-- MULTIPOD_TABLE -->" in md else md
    pathlib.Path("EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
