"""Slot-based paged KV-cache pool (vLLM-style, pure JAX).

Physical storage is one tensor per K/V of shape

    (n_layers, n_pages, page_size, n_kv_heads, head_dim)

and each admitted sequence owns a *slot*: a row of a block table mapping
logical page index -> physical page.  Pages are claimed lazily as the
sequence grows (``extend``) and returned on ``release``, so the pool can
overcommit: ``n_slots * max_pages_per_seq`` may exceed ``n_pages``.  The
engine resolves page exhaustion by evicting a victim sequence.

Physical page 0 is reserved as a scratch page: padded batch lanes and
padded prefill tokens scatter their (ignored) writes there, which keeps
every device op shape-static — one compile for gather, one for scatter.

Keys are stored post-RoPE, matching ``models.layers.cache_store``.

Pages may be stored int8 (``dtype=jnp.int8``): values are quantized
per-(token, head) on scatter (symmetric, scale = max|x|/127, matching
``models.layers._quantize_kv``) with fp32 scales in parallel
``(L, P, ps, KV)`` tensors.  ``gather`` dequantizes; the paged-attention
kernel reads the int8 pages + scales directly (1 byte/elem of KV traffic).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

__all__ = ["PagedKVPool", "pages_needed", "quantize_kv_int8"]


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def page_bucket(n_pages: int, cap: int) -> int:
    """Round a page count up to a power of two, clamped to ``cap``.

    The paged decode dispatch is shape-static per block-table width; both
    the engine and the decode micro-benchmark bucket through here so the
    benchmark always measures the dispatch shape production uses.
    """
    b = 1
    while b < max(1, n_pages):
        b *= 2
    return min(b, cap)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter(phys: jax.Array, pages: jax.Array, offs: jax.Array,
             vals: jax.Array) -> jax.Array:
    """phys (L, P, ps, KV, hd); pages/offs (T,); vals (L, T, KV, hd)."""
    return phys.at[:, pages, offs].set(vals.astype(phys.dtype))


def quantize_kv_int8(vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 for (..., hd) values.

    Shared by the pool's scatter and the adapter's fused paged-decode step,
    so both write bit-identical pages; delegates to the dense cache path's
    quantizer so the two KV representations can never drift apart.
    """
    from repro.models.layers import _quantize_kv

    return _quantize_kv(vals)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_q(phys: jax.Array, scales: jax.Array, pages: jax.Array,
               offs: jax.Array, vals: jax.Array):
    """int8 variant: quantize vals (L, T, KV, hd), store values + scales."""
    q, sc = quantize_kv_int8(vals)
    return phys.at[:, pages, offs].set(q), scales.at[:, pages, offs].set(sc)


@jax.jit
def _gather(phys: jax.Array, block_tables: jax.Array) -> jax.Array:
    """phys (L, P, ps, KV, hd), block_tables (B, Pmax) ->
    (L, B, Pmax*ps, KV, hd) contiguous per-sequence windows."""
    g = phys[:, block_tables]  # (L, B, Pmax, ps, KV, hd)
    L, B = g.shape[0], g.shape[1]
    return g.reshape(L, B, -1, *phys.shape[-2:])


@functools.partial(jax.jit, static_argnames=("dtype",))
def _gather_q(phys: jax.Array, scales: jax.Array, block_tables: jax.Array,
              dtype) -> jax.Array:
    """int8 variant of :func:`_gather`: dequantize to ``dtype``."""
    g = phys[:, block_tables].astype(jnp.float32)
    s = scales[:, block_tables]
    L, B = g.shape[0], g.shape[1]
    return (g * s[..., None]).astype(dtype).reshape(
        L, B, -1, *phys.shape[-2:]
    )


@dataclasses.dataclass
class _Slot:
    pages: list  # physical page ids, logical order
    length: int  # valid tokens written


class PagedKVPool:
    """Page accounting (host) + paged K/V storage (device).

    ``admit(n_tokens)`` -> slot id or None (not enough free pages/slots);
    ``extend(slot, new_len)`` -> bool (claims pages to cover ``new_len``);
    ``release(slot)`` returns all pages.  ``gather``/``write`` move data.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        n_pages: int,
        page_size: int,
        n_slots: int,
        max_pages_per_seq: int,
        dtype=None,
    ):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_pages_per_seq = max_pages_per_seq
        dt = jnp.dtype(dtype) if dtype is not None else jnp.dtype(cfg.dtype)
        # fp dtype handed out by gather (and used for dequantized int8 reads)
        self._fp_dtype = jnp.dtype(cfg.dtype) if dt == jnp.int8 else dt
        shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        if dt == jnp.int8:
            self.k_scale = jnp.zeros(shape[:-1], jnp.float32)
            self.v_scale = jnp.zeros(shape[:-1], jnp.float32)
        else:
            self.k_scale = self.v_scale = None
        self._free_pages = list(range(n_pages - 1, 0, -1))  # pop() -> low ids
        self._free_slots = list(range(n_slots - 1, -1, -1))
        self._slots: dict[int, _Slot] = {}
        self.peak_pages_in_use = 0

    # ---- accounting -----------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free_pages)

    @property
    def occupancy(self) -> float:
        return self.pages_in_use / (self.n_pages - 1)

    def seq_capacity_tokens(self) -> int:
        return self.max_pages_per_seq * self.page_size

    def fits(self, n_tokens: int) -> bool:
        """Whether a sequence of n_tokens can EVER be resident."""
        return (
            n_tokens <= self.seq_capacity_tokens()
            and pages_needed(n_tokens, self.page_size) <= self.n_pages - 1
        )

    def admit(self, n_tokens: int) -> Optional[int]:
        need = max(1, pages_needed(n_tokens, self.page_size))
        if not self._free_slots or need > len(self._free_pages):
            return None
        if need > self.max_pages_per_seq:
            return None
        slot = self._free_slots.pop()
        self._slots[slot] = _Slot(
            pages=[self._free_pages.pop() for _ in range(need)], length=0
        )
        self.peak_pages_in_use = max(self.peak_pages_in_use, self.pages_in_use)
        return slot

    def extend(self, slot: int, new_len: int) -> bool:
        """Claim pages so the slot can hold ``new_len`` tokens."""
        st = self._slots[slot]
        need = pages_needed(new_len, self.page_size) - len(st.pages)
        if need <= 0:
            return True
        if (
            need > len(self._free_pages)
            or len(st.pages) + need > self.max_pages_per_seq
        ):
            return False
        for _ in range(need):
            st.pages.append(self._free_pages.pop())
        self.peak_pages_in_use = max(self.peak_pages_in_use, self.pages_in_use)
        return True

    def release(self, slot: int) -> None:
        st = self._slots.pop(slot)
        self._free_pages.extend(st.pages)
        self._free_slots.append(slot)

    def length(self, slot: int) -> int:
        return self._slots[slot].length

    @property
    def is_int8(self) -> bool:
        return self.k_scale is not None

    def _storage(self) -> list:
        arrs = [self.k, self.v]
        if self.is_int8:
            arrs += [self.k_scale, self.v_scale]
        return arrs

    def total_bytes(self) -> int:
        """Logical bytes of KV page storage (all shards together)."""
        return sum(a.nbytes for a in self._storage())

    def device_bytes(self) -> int:
        """Bytes of KV page storage resident on ONE device.  For a pool
        sharded over kv heads this is ~1/mp of :meth:`total_bytes`."""
        return sum(
            a.addressable_shards[0].data.nbytes for a in self._storage()
        )

    # ---- device ops -----------------------------------------------------

    def block_table(self, slot_ids: list[Optional[int]]) -> np.ndarray:
        """(B, max_pages_per_seq) int32; missing slots/pages -> scratch 0."""
        bt = np.zeros((len(slot_ids), self.max_pages_per_seq), np.int32)
        for b, s in enumerate(slot_ids):
            if s is None:
                continue
            pages = self._slots[s].pages
            bt[b, : len(pages)] = pages
        return bt

    def gather(self, slot_ids: list[Optional[int]]):
        """-> (k, v) each (L, B, max_pages_per_seq*page_size, KV, hd).

        int8 pools dequantize on the way out, so callers always see fp.
        """
        bt = jnp.asarray(self.block_table(slot_ids))
        if self.is_int8:
            return (
                _gather_q(self.k, self.k_scale, bt, self._fp_dtype),
                _gather_q(self.v, self.v_scale, bt, self._fp_dtype),
            )
        return _gather(self.k, bt), _gather(self.v, bt)

    def _addr(self, slot: Optional[int], pos: int) -> tuple[int, int]:
        if slot is None:
            return 0, 0  # scratch
        st = self._slots[slot]
        page = st.pages[pos // self.page_size]
        return page, pos % self.page_size

    def addresses(
        self, slot_ids: list[Optional[int]], positions: list[int]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Physical (pages, offsets) int32 for one token per lane; ``None``
        lanes resolve to the scratch page.  Feeds the fused decode dispatch
        (adapter scatters in place) — pair with :meth:`note_written`."""
        pages = np.zeros(len(slot_ids), np.int32)
        offs = np.zeros(len(slot_ids), np.int32)
        for b, (s, p) in enumerate(zip(slot_ids, positions)):
            pages[b], offs[b] = self._addr(s, p)
        return pages, offs

    def note_written(
        self, slot_ids: list[Optional[int]], positions: list[int]
    ) -> None:
        """Host-side length accounting for tokens a fused device step
        already scattered into the pool."""
        for s, p in zip(slot_ids, positions):
            if s is not None:
                self._slots[s].length = max(self._slots[s].length, p + 1)

    def _scatter_kv(self, pages: np.ndarray, offs: np.ndarray,
                    k_new: jax.Array, v_new: jax.Array) -> None:
        pages, offs = jnp.asarray(pages), jnp.asarray(offs)
        if self.is_int8:
            self.k, self.k_scale = _scatter_q(
                self.k, self.k_scale, pages, offs, k_new
            )
            self.v, self.v_scale = _scatter_q(
                self.v, self.v_scale, pages, offs, v_new
            )
        else:
            self.k = _scatter(self.k, pages, offs, k_new)
            self.v = _scatter(self.v, pages, offs, v_new)

    def write(
        self,
        slot_ids: list[Optional[int]],
        positions: list[int],
        k_new: jax.Array,
        v_new: jax.Array,
    ) -> None:
        """Scatter one token per lane: k_new/v_new (L, B, KV, hd).

        Lane b writes at absolute position ``positions[b]`` of slot
        ``slot_ids[b]``; ``None`` lanes go to the scratch page.  Also
        advances each written slot's valid length to ``positions[b]+1``.
        """
        pages, offs = self.addresses(slot_ids, positions)
        self._scatter_kv(pages, offs, k_new, v_new)
        self.note_written(slot_ids, positions)

    def write_span(
        self, slot: int, start: int, n_valid: int, k_new: jax.Array,
        v_new: jax.Array,
    ) -> None:
        """Scatter a prefill chunk: k_new/v_new (L, T, KV, hd); the first
        ``n_valid`` tokens land at positions start..start+n_valid-1, the
        padded tail goes to the scratch page."""
        T = k_new.shape[1]
        pages = np.zeros(T, np.int32)
        offs = np.zeros(T, np.int32)
        for t in range(n_valid):
            pages[t], offs[t] = self._addr(slot, start + t)
        self._scatter_kv(pages, offs, k_new, v_new)
        self._slots[slot].length = max(self._slots[slot].length, start + n_valid)
