"""Per-architecture smoke tests (deliverable f) + model-level equivalences.

Each assigned arch instantiates a REDUCED same-family config and runs one
forward/train-like step on CPU, asserting output shapes and no NaNs.  The
FULL configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, shapes_for
from repro.models import build_model
from repro.models import ssm


def _batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + ["llama2-70b"])
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    h, aux = model.forward(params, batch)
    assert h.shape == (2, 16, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    # one SGD-like step moves the loss (gradient sanity)
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2)) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)
    prefix = dict(batch)
    prefix["tokens"] = batch["tokens"][:, : S - 1]
    if cfg.family == "encdec":
        prefix["frames"] = batch["frames"]
    logits_pre, _ = model.prefill(params, prefix)
    h, _ = model.forward(params, batch)
    logits_full = model.logits(params, h)[:, S - 2]
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits_full), atol=2e-4
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    """prefill(S-1) + decode(token S-1) == forward(S) logits at S-1."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)
    prefix = dict(batch)
    prefix["tokens"] = batch["tokens"][:, : S - 1]
    _, cache = model.prefill(params, prefix, max_len=S)
    logits_dec, _ = model.decode_step(
        params, batch["tokens"][:, S - 1 : S], cache, jnp.int32(S - 1)
    )
    h, _ = model.forward(params, batch)
    logits_full = model.logits(params, h)[:, S - 1]
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), atol=3e-4
    )


def test_mamba2_chunked_matches_scan_oracle():
    cfg = get_smoke_config("zamba2-7b")
    p = ssm.init_mamba2(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.5
    for chunk in (3, 4, 12):
        y = ssm.mamba2_forward(p, u, cfg, chunk=chunk)
        y_ref = ssm.mamba2_scan_ref(p, u, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_rwkv6_chunked_matches_scan_oracle():
    cfg = get_smoke_config("rwkv6-1.6b")
    p = ssm.init_rwkv6(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.5
    for chunk in (3, 4, 12):
        y = ssm.rwkv6_time_mix(p, x, cfg, chunk=chunk)
        y_ref = ssm.rwkv6_scan_ref(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_int8_kv_cache_close_to_bf16():
    """Beyond-paper int8 KV: decode logits stay close to fp-cache logits."""
    cfg = get_smoke_config("mistral-large-123b")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init(rng)
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S)
    prefix = dict(batch)
    prefix["tokens"] = batch["tokens"][:, : S - 1]
    _, cache_fp = model.prefill(params, prefix, max_len=S)
    _, cache_i8 = model.prefill(params, prefix, kv_dtype=jnp.int8, max_len=S)
    tok = batch["tokens"][:, S - 1 : S]
    lg_fp, _ = model.decode_step(params, tok, cache_fp, jnp.int32(S - 1))
    lg_i8, _ = model.decode_step(params, tok, cache_i8, jnp.int32(S - 1))
    # int8 KV is a lossy but tight approximation
    err = float(jnp.max(jnp.abs(lg_fp - lg_i8)))
    scale = float(jnp.max(jnp.abs(lg_fp))) + 1e-6
    assert err / scale < 0.05, err / scale


def test_shapes_for_family_gating():
    assert [s.name for s in shapes_for(get_config("mistral-large-123b"))] == [
        "train_4k", "prefill_32k", "decode_32k",
    ]
    assert "long_500k" in [s.name for s in shapes_for(get_config("rwkv6-1.6b"))]
    assert "long_500k" in [s.name for s in shapes_for(get_config("zamba2-7b"))]


def test_param_counts_in_expected_range():
    """Config param_count approximations land near the advertised sizes."""
    expect = {
        "mistral-large-123b": (100e9, 140e9),
        "qwen2-72b": (60e9, 85e9),
        "starcoder2-15b": (12e9, 19e9),
        "llama2-70b": (55e9, 80e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
