#!/usr/bin/env bash
# CI: unit tests + the end-to-end quantize -> artifact -> serve path.
#
#   scripts/ci.sh          # full run (installs hypothesis if a network is up)
#   CI_FAST=1 scripts/ci.sh  # skip the slow-marked driver tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# hypothesis is optional (property sweeps skip without it); best-effort install
python -c 'import hypothesis' 2>/dev/null \
  || python -m pip install -q hypothesis \
  || echo "[ci] hypothesis unavailable (offline?) — property sweeps will skip"

if [ "${CI_FAST:-0}" = "1" ]; then
  python -m pytest -q -m "not slow"
else
  python -m pytest -q
fi

# end-to-end serving: fp engine, in-process quantize, and the persistent
# artifact path (quantize once -> serve without re-quantizing)
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --check

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --quantize --bits 4 --check

python -m repro.launch.quantize --arch qwen3-14b --smoke --bits 2 \
  --calib-segments 4 --calib-len 32 --out-dir "$tmp/artifact"

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --load-quantized "$tmp/artifact" --check

PYTHONPATH=src python benchmarks/serving_load.py --smoke --requests 8

echo "[ci] OK"
