"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP —
hf:Snowflake/snowflake-arctic-base."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    n_experts=128,
    top_k=2,
    dense_residual=True,
    mlp="swiglu",
    rope_theta=1e6,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab=256,
        n_experts=8,
        top_k=2,
        dense_residual=True,
        capacity_factor=4.0,  # no-drop headroom for smoke equivalence tests
        mlp="swiglu",
        dtype="float32",
        microbatch=2,
        remat="none",
    )
