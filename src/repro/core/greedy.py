"""Greedy local search (QuIP Sec. 4.2 / Supplement B.2, Algorithm 4).

Coordinate descent on the proxy loss restricted to the quantization grid.
Stand-alone it is adaptive rounding with linear feedback
``U = (H ⊙ M) diag(H)^{-1}``; as a post-pass after LDLQ it additionally
carries the initial guess through ``V = W - (Wtil - W)(H ⊙ M^T) diag(H)^{-1}``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ldlq import quantize_nearest

__all__ = ["greedy_pass", "greedy"]


@functools.partial(jax.jit, static_argnames=("maxq",))
def greedy_pass(
    W: jax.Array,
    H: jax.Array,
    Wtil: jax.Array,
    maxq: int,
) -> jax.Array:
    """One pass of Algorithm 4 (columns in LDLQ order).

    W: (m, n) target weights on the grid domain; Wtil: initial guess
    (= W for stand-alone use).  Returns the updated quantized guess.
    """
    n = H.shape[0]
    dinv = 1.0 / jnp.diagonal(H)
    mask_u = jnp.triu(jnp.ones((n, n), H.dtype), k=1)  # strictly upper M
    U = (H * mask_u) * dinv[None, :]
    # V = W - (Wtil - W) (H ⊙ M^T) diag(H)^-1
    V = W - (Wtil - W) @ ((H * mask_u.T) * dinv[None, :])

    def body(k, What):
        corr = (W - What) @ U[:, k]
        val = V[:, k] + corr
        return What.at[:, k].set(quantize_nearest(val, maxq))

    return jax.lax.fori_loop(0, n, body, Wtil)


def greedy(
    W: jax.Array,
    H: jax.Array,
    maxq: int,
    *,
    passes: int = 10,
    init: Optional[jax.Array] = None,
) -> jax.Array:
    """Multi-pass greedy updates (paper: 10 passes; 5 for 30B/66B).

    ``init=None`` runs stand-alone greedy (first pass from Wtil = W, which is
    *not* a descent step — the initial point is off-grid); otherwise
    post-processes ``init`` (each pass is then a descent step).
    """
    What = W if init is None else init
    for _ in range(passes):
        What = greedy_pass(W, H, What, maxq)
    return What
