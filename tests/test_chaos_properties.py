"""Hypothesis pool-leak audit (ISSUE 7): random interleavings of pool
ops must leave page counts and prefix-trie refcounts exactly consistent.

Deterministic chaos tests live in test_chaos.py; this module holds only
the property sweep and skips wholesale without hypothesis (repo idiom —
scripts/ci.sh best-effort installs it)."""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.serve import PagedKVPool


def _audit(pool):
    """Recompute every page's refcount from first principles (slot block
    tables + trie nodes) and require exact agreement with the
    incremental accounting, including the free list."""
    refs = np.zeros(pool.n_pages, np.int64)
    for slot_state in pool._slots.values():
        for pg in slot_state.pages:
            refs[pg] += 1
    for nid, node in pool._nodes.items():
        if nid != 0:
            refs[node.page] += 1
    np.testing.assert_array_equal(refs[1:], pool._page_ref[1:])
    free = set(pool._free_pages)
    assert len(free) == len(pool._free_pages)  # no double-free
    for p in range(1, pool.n_pages):
        assert (p in free) == (refs[p] == 0)
    assert pool.pages_in_use == pool.n_pages - 1 - len(free)


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.data())
def test_pool_refcount_leak_audit(data):
    """Random interleavings of admit / extend / truncate / COW /
    register_prefix / release — the pool-level faces of submit, evict,
    speculative rollback, cache fork, cancel, and finish — keep the
    refcount invariants at every step, and a final drain returns every
    non-cached page to the free list."""
    cfg = get_smoke_config("qwen3-14b")
    pool = PagedKVPool(cfg, n_pages=10, page_size=4, n_slots=4,
                       max_pages_per_seq=6, prefix_cache=True)
    # tiny alphabet so prompts collide -> real trie sharing and COW
    tok = st.lists(st.integers(0, 2), min_size=1, max_size=20)
    tokens_of: dict[int, np.ndarray] = {}
    for _ in range(data.draw(st.integers(5, 30), label="n_ops")):
        op = data.draw(st.sampled_from(
            ["admit", "extend", "truncate", "cow", "register", "release"]
        ), label="op")
        slots = sorted(pool._slots)
        if op == "admit":
            tokens = np.asarray(data.draw(tok, label="tokens"), np.int32)
            slot = pool.admit(len(tokens), tokens=tokens)
            if slot is not None:
                tokens_of[slot] = tokens
        elif not slots:
            continue
        else:
            slot = data.draw(st.sampled_from(slots), label="slot")
            slot_state = pool._slots[slot]
            if op == "extend":
                pool.extend(slot, data.draw(
                    st.integers(1, pool.seq_capacity_tokens()),
                    label="new_len"))
            elif op == "truncate":
                pool.truncate(slot, data.draw(
                    st.integers(0, slot_state.length), label="trunc_len"))
            elif op == "cow" and slot_state.pages and pool._free_pages:
                pool._ensure_private(slot, data.draw(
                    st.integers(0, len(slot_state.pages) - 1),
                    label="page"))
            elif op == "register":
                pool.register_prefix(
                    slot, tokens_of.get(slot, np.zeros(0, np.int32)))
            elif op == "release":
                pool.release(slot)
                tokens_of.pop(slot, None)
        _audit(pool)
    for slot in sorted(pool._slots):
        pool.release(slot)
    _audit(pool)
    assert pool.pages_in_use == pool.cached_pages
