"""Quickstart: QuIP-quantize one linear layer in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import QuipConfig, quantize_layer, mu_weight

# a layer with outliers (the thing incoherence processing fixes)
key = jax.random.PRNGKey(0)
W = 0.02 * jax.random.normal(key, (256, 512))
W = W.at[7, 13].set(2.5).at[100, 400].set(-3.0)

# proxy Hessian from calibration activations H = E[x x^T]
X = jax.random.normal(jax.random.PRNGKey(1), (4096, 512))
H = X.T @ X / 4096

for incoherence in (False, True):
    cfg = QuipConfig(bits=2, method="ldlq", incoherence=incoherence)
    layer, stats = quantize_layer(W, H, cfg, seed=0)
    print(
        f"2-bit {'QuIP (LDLQ+IncP)' if incoherence else 'LDLQ baseline ':22s}"
        f" proxy loss = {stats['proxy_loss']:10.4f}"
        f"   rel frobenius err = {stats['frob_rel_err']:.3f}"
    )

# the quantized layer is callable (packed 2-bit weights + seeded transforms)
x = jax.random.normal(jax.random.PRNGKey(2), (4, 512))
y = layer(x)
print("quantized layer output:", y.shape, "µ_W of original:", float(mu_weight(W)))
