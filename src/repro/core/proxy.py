"""Adaptive-rounding proxy objective (Eq. 1) and related diagnostics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["proxy_loss", "trD_trH"]


def proxy_loss(What: jax.Array, W: jax.Array, H: jax.Array) -> jax.Array:
    """ℓ(What) = tr((What - W) H (What - W)^T)."""
    E = (What - W).astype(jnp.float32)
    return jnp.einsum("ij,jk,ik->", E, H.astype(jnp.float32), E)


def trD_trH(H: jax.Array) -> jax.Array:
    """tr(D)/tr(H) for the LDL decomposition of H (Table 6 statistic)."""
    from repro.core.ldlq import ldl_decomposition

    _, D = ldl_decomposition(H)
    return jnp.sum(D) / jnp.trace(H)
