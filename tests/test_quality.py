"""Quantization-quality observability tests (DESIGN.md §13).

Pins the three layers of serve/quality.py:

  * quantize time — the per-layer quality report carries the paper's
    incoherence/proxy-loss numbers and incoherence processing helps (in
    expectation) on random SPD Hessians;
  * artifact time — the quality section round-trips through the manifest,
    baseline comparison flags regressions, and pre-quality-manifest
    artifacts warn instead of failing;
  * serve time — the online canary NLL gauge equals the offline
    teacher-forced value bit-for-bit (fp AND quantized), and shadow
    drift sampling reports exactly zero token flips when the serving
    path IS the oracle path.
"""
from __future__ import annotations

import numpy as np
import pytest
from conftest import make_hessian, make_weights

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.quantizer import QuipConfig, quantize_layer
from repro.data import make_calibration
from repro.models import build_model
from repro.serve import CachedDecoder, Engine, EngineConfig
from repro.serve.quality import (
    ShadowSampler,
    build_quality_section,
    check_artifact_quality,
    load_baseline,
    teacher_forced_nll,
    write_baseline,
)


def _smoke_cfg():
    return get_smoke_config("qwen3-14b")


@pytest.fixture(scope="module")
def fp_adapter():
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, CachedDecoder.from_model(model, params)


# ---------------------------------------------------------------------------
# quantize-time quality reports
# ---------------------------------------------------------------------------


def test_quality_report_fields_sane(small_wh):
    W, H = small_wh
    _, st = quantize_layer(W, H, QuipConfig(bits=2, method="ldlq"), seed=0)
    for key in ("proxy_loss", "proxy_rel", "frob_rel_err", "max_abs_err",
                "mu_w_pre", "mu_w_post", "mu_h_pre", "mu_h_post",
                "h_lambda_min", "h_lambda_max", "h_cond", "wall_s"):
        assert key in st, key
        assert np.isfinite(st[key]), key
    assert st["proxy_loss"] > 0
    assert 0 < st["proxy_rel"] < 1  # 2-bit LDLQ beats quantize-to-zero
    assert st["h_lambda_max"] >= st["h_lambda_min"] > 0  # SPD after damping?
    assert st["h_cond"] == pytest.approx(
        st["h_lambda_max"] / st["h_lambda_min"], rel=1e-6
    )
    # µ lower bound: µ(W) >= 1 for any nonzero matrix, µ(H) >= 1 always
    assert st["mu_w_pre"] >= 1.0 and st["mu_w_post"] >= 1.0
    assert st["mu_h_pre"] >= 1.0 and st["mu_h_post"] >= 1.0
    assert st["wall_s"] > 0
    assert (st["m"], st["n"]) == W.shape
    assert st["bits"] == 2 and "ldlq" in st["method"]


def test_incoherence_improves_proxy_in_expectation():
    """QuIP's central claim at quality-report granularity: incoherence
    preprocessing does not hurt the proxy loss in expectation.  Mean over
    seeds — the guarantee is distributional, not per-instance."""
    deltas = []
    for seed in range(5):
        W = make_weights(16, 16, seed=seed)
        H = make_hessian(16, seed=seed, tokens=256)
        cfg_on = QuipConfig(bits=2, method="ldlq", incoherence=True)
        cfg_off = QuipConfig(bits=2, method="ldlq", incoherence=False)
        _, st_on = quantize_layer(W, H, cfg_on, seed=seed)
        _, st_off = quantize_layer(W, H, cfg_off, seed=seed)
        deltas.append(st_off["proxy_loss"] - st_on["proxy_loss"])
        # the report must also SHOW the incoherence working: µ(W) post
        # is bounded for random orthogonal conjugation
        assert st_on["mu_w_post"] < 100
    assert np.mean(deltas) > 0, (
        f"incoherence-on proxy loss should beat incoherence-off on "
        f"average; deltas={deltas}"
    )


# ---------------------------------------------------------------------------
# quality manifest + baselines
# ---------------------------------------------------------------------------


def _fake_stats(n_blocks=2, ploss=1.0):
    st = {
        "proxy_loss": ploss, "proxy_rel": 0.1, "frob_rel_err": 0.5,
        "max_abs_err": 0.2, "s": 1.0, "mu_w_pre": 4.0, "mu_w_post": 3.5,
        "mu_h_pre": 4.0, "mu_h_post": 3.6, "h_lambda_min": 1e-3,
        "h_lambda_max": 10.0, "h_cond": 1e4, "m": 8, "n": 8, "bits": 2,
        "method": "ldlq+incp@2b", "wall_s": 0.1,
    }
    return [{"attn.wq": dict(st), "mlp.wi": dict(st)}
            for _ in range(n_blocks)]


def test_quality_section_and_baseline_roundtrip(tmp_path):
    quality = build_quality_section(_fake_stats())
    assert quality["format"] == 1
    assert set(quality["layers"]) == {
        "0/attn.wq", "0/mlp.wi", "1/attn.wq", "1/mlp.wi"
    }
    agg = quality["aggregate"]
    assert agg["n_layers"] == 4
    assert agg["total_proxy_loss"] == pytest.approx(4.0)

    path = tmp_path / "base.json"
    write_baseline(path, quality, source="test")
    base = load_baseline(path)
    assert base["kind"] == "quip_quality_baseline"
    assert base["proxy_loss"]["0/attn.wq"] == pytest.approx(1.0)

    # identical artifact: clean
    assert check_artifact_quality(quality, base, threshold=1.2) == []
    # regressed artifact: the 1.2x threshold flags exactly the bad layer
    worse = build_quality_section(_fake_stats())
    worse["layers"]["1/mlp.wi"]["proxy_loss"] = 1.5
    regs = check_artifact_quality(worse, base, threshold=1.2)
    assert [r["layer"] for r in regs] == ["1/mlp.wi"]
    assert regs[0]["reason"] == "proxy_loss"
    assert regs[0]["ratio"] == pytest.approx(1.5)
    # a layer the baseline knows but the artifact lost is a regression too
    partial = build_quality_section(_fake_stats())
    del partial["layers"]["0/attn.wq"]
    regs = check_artifact_quality(partial, base)
    assert [r["reason"] for r in regs] == ["missing_layer"]


def test_pre_quality_manifest_warns_and_compares_clean(tmp_path):
    quality = build_quality_section(_fake_stats())
    path = tmp_path / "base.json"
    write_baseline(path, quality)
    base = load_baseline(path)
    for legacy in (None, {}):  # artifacts saved before quality manifests
        with pytest.warns(UserWarning, match="no quality section"):
            assert check_artifact_quality(legacy, base) == []


def test_load_baseline_rejects_wrong_kind(tmp_path):
    path = tmp_path / "not_base.json"
    path.write_text('{"kind": "something_else"}')
    with pytest.raises(ValueError, match="not a quality baseline"):
        load_baseline(path)


def test_artifact_manifest_carries_quality_section(tmp_path):
    """launch/quantize.py --out-dir folds the quality section into the
    saved manifest and quality_report.py reads it back."""
    from repro.launch.quality_report import load_manifest
    from repro.launch.quantize import quantize_dense_model
    from repro.serve.artifacts import save_quantized

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = make_calibration(cfg.vocab, n_segments=2, seg_len=16, seed=7)
    qcfg = QuipConfig(bits=2, method="ldlq", use_kernel=False)
    qm = quantize_dense_model(params, cfg, qcfg, calib.tokens, seed=0,
                              verbose=False)
    quality = build_quality_section(qm.stats)
    save_quantized(tmp_path / "art", qm, qcfg,
                   extra_meta={"quality": quality})
    meta = load_manifest(tmp_path / "art")
    assert meta["quality"]["aggregate"]["n_layers"] == len(quality["layers"])
    assert meta["quality"] == quality  # JSON round-trip is exact


# ---------------------------------------------------------------------------
# serve-time canaries
# ---------------------------------------------------------------------------


def _canary_engine(adapter, prompts, gen, **kw):
    ecfg = EngineConfig(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8, **kw,
    )
    return Engine(adapter, ecfg)


def test_canary_gauge_equals_offline_nll_fp(fp_adapter):
    """The online canary NLL gauge IS the offline teacher-forced value —
    equality, not tolerance (one jitted probe graph serves both)."""
    cfg, adapter = fp_adapter
    canary = make_calibration(cfg.vocab, n_segments=2, seg_len=12,
                              seed=99).tokens
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=3).tokens
    engine = _canary_engine(adapter, prompts, 4, canary_every=1e-4)
    engine.attach_canary(canary)
    for p in prompts:
        engine.submit(np.asarray(p), max_new=4)
    engine.run()
    s = engine.summary()
    assert s["canary_runs"] >= 1
    assert s["canary_nll"] == teacher_forced_nll(adapter, canary)
    # activation probe published per-layer gauges for every block edge
    assert s["act_absmax"] > 0
    assert 0.0 <= s["act_sat"] <= 1.0
    for i in range(cfg.n_layers + 1):
        assert f"act_absmax:{i}" in s


def test_canary_gauge_equals_offline_nll_quantized():
    from repro.launch.quantize import quantize_dense_model

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = make_calibration(cfg.vocab, n_segments=2, seg_len=16, seed=7)
    qm = quantize_dense_model(
        params, cfg, QuipConfig(bits=2, method="ldlq", use_kernel=False),
        calib.tokens, seed=0, verbose=False,
    )
    adapter = CachedDecoder.from_quantized(qm)
    canary = make_calibration(cfg.vocab, n_segments=2, seg_len=12,
                              seed=99).tokens
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=8,
                               seed=3).tokens
    engine = _canary_engine(adapter, prompts, 3, canary_every=1e-4)
    engine.attach_canary(canary)
    for p in prompts:
        engine.submit(np.asarray(p), max_new=3)
    engine.run()
    # bit-for-bit: a FRESH adapter over the same quantized model computes
    # the identical gauge value offline
    offline = teacher_forced_nll(CachedDecoder.from_quantized(qm), canary)
    assert engine.summary()["canary_nll"] == offline


def test_canary_is_out_of_band(fp_adapter):
    """Canaries must not perturb traffic: tokens with canaries on equal
    tokens with canaries off, and the pool sees no canary pages."""
    cfg, adapter = fp_adapter
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=3).tokens
    outs = []
    for canary_every in (None, 1e-4):
        engine = _canary_engine(adapter, prompts, 5,
                                canary_every=canary_every)
        if canary_every is not None:
            engine.attach_canary(make_calibration(
                cfg.vocab, n_segments=2, seg_len=12, seed=99).tokens)
        reqs = [engine.submit(np.asarray(p), max_new=5) for p in prompts]
        engine.run()
        assert engine.pool.pages_in_use == 0  # probes never touch the pool
        outs.append([tuple(r.out_tokens) for r in reqs])
    assert outs[0] == outs[1]


def test_canary_requires_attach_and_validates():
    cfg = _smoke_cfg()
    model = build_model(cfg)
    adapter = CachedDecoder.from_model(model, model.init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="canary_every"):
        _canary_engine(adapter, np.zeros((1, 8), np.int32), 2,
                       canary_every=-1.0)
    engine = _canary_engine(adapter, np.zeros((1, 8), np.int32), 2)
    with pytest.raises(ValueError, match="canary set"):
        engine.attach_canary(np.zeros((2, 1), np.int32))  # S < 2


# ---------------------------------------------------------------------------
# shadow drift sampling
# ---------------------------------------------------------------------------


def test_shadow_zero_flips_fp_engine(fp_adapter):
    """Gather-dense fp engine: the serving forward IS the oracle trunk,
    so drift sampling at rate 1.0 must see exactly zero token flips."""
    cfg, adapter = fp_adapter
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=3).tokens
    engine = _canary_engine(adapter, prompts, 5, shadow_rate=1.0)
    reqs = [engine.submit(np.asarray(p), max_new=5) for p in prompts]
    engine.run()
    s = engine.summary()
    assert all(r.shadow for r in reqs)
    assert s["shadow_samples"] == len(reqs)
    assert s["shadow_tokens"] == sum(len(r.out_tokens) for r in reqs)
    assert s["shadow_token_flips"] == 0
    assert s["shadow_flip_rate_p99"] == 0.0
    # non-shadow runs don't keep logits; shadow runs only for their reqs
    assert all(len(r.step_logits) == len(r.out_tokens) for r in reqs)


def test_shadow_selection_deterministic_and_rate_shaped():
    sampler = ShadowSampler(None, 0.25, seed=3)
    picks = [sampler.selects(rid) for rid in range(2000)]
    assert picks == [sampler.selects(rid) for rid in range(2000)]
    assert 0.15 < np.mean(picks) < 0.35  # crc32 is uniform enough
    assert not any(ShadowSampler(None, 0.0).selects(r) for r in range(50))
    all_in = ShadowSampler(None, 1.0)
    assert all(all_in.selects(r) for r in range(50))
    with pytest.raises(ValueError, match="shadow rate"):
        ShadowSampler(None, 1.5)


def test_shadow_observe_skips_incomplete_logit_streams(fp_adapter):
    """A request whose emission logits are missing (e.g. replayed after
    eviction before shadow wiring existed) scores nothing rather than
    scoring a misaligned stream."""
    from repro.serve.scheduler import Request

    _, adapter = fp_adapter
    sampler = ShadowSampler(adapter, 1.0)
    req = Request(prompt=np.arange(4, dtype=np.int32), max_new=3)
    req.out_tokens = [1, 2, 3]
    req.step_logits = []  # nothing recorded
    assert sampler.observe(req) is None
