"""Scheduler fairness under multi-tenant admission (ISSUE 9).

Hypothesis property sweeps (gated on hypothesis being importable —
the deterministic tests below always run): (1) under mixed priority
classes with aging every submitted
request is eventually admitted — no starvation; (2) per-tenant token
buckets never admit beyond ``burst + rate * window``.  Deterministic
unit tests below cover the bucket math, priority ordering, the
all-class-0 FCFS fast path, and the rate-before-queue-bound ordering.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.serve.faults import AdmissionRejected
from repro.serve.scheduler import (
    Request,
    RequestState,
    TenantPolicy,
    TokenBucket,
    TokenBudgetFCFS,
)


class FakePool:
    """The minimal pool surface ``plan()`` touches: bounded slots,
    nothing cached.  Lets fairness sweeps run pure scheduling."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._live: set[int] = set()
        self._next = 0

    def admit(self, n_tokens: int, tokens=None):
        if len(self._live) >= self.n_slots:
            return None
        self._next += 1
        self._live.add(self._next)
        return self._next

    def length(self, slot: int) -> int:
        return 0

    def release(self, slot: int) -> None:
        self._live.remove(slot)


def _req(arrival=0.0, priority=None, tenant="default", n_prompt=4,
         max_new=4):
    return Request(prompt=np.arange(1, 1 + n_prompt, dtype=np.int32),
                   max_new=max_new, arrival=arrival, tenant=tenant,
                   priority=priority)


def _drive(sched, pool, *, dt=0.25, service_plans=2, max_t=400.0):
    """Simulate the engine loop over a fake pool: plan each step, give
    every running request ``service_plans`` planning rounds, then
    finish it (slot freed).  Returns the virtual time each request was
    admitted at."""
    running: list[Request] = []
    seen_plans: dict[int, int] = {}
    admitted_at: dict[int, float] = {}
    t = 0.0
    while (sched.pending or running) and t < max_t:
        sched.admit_arrivals(t)
        plan = sched.plan(running, pool, now=t)
        for r in list(running):
            if r.rid not in admitted_at:
                admitted_at[r.rid] = t
            seen_plans[r.rid] = seen_plans.get(r.rid, 0) + 1
            if seen_plans[r.rid] >= service_plans:
                pool.release(r.slot)
                running.remove(r)
                r.state = RequestState.FINISHED
        t += dt
    return admitted_at


# ---------------------------------------------------------------------------
# hypothesis property sweeps (the deterministic tests below must still
# run without hypothesis, so only THIS section is gated — repo CI
# best-effort installs hypothesis, the bare container lacks it)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - container without hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(
        st.tuples(st.integers(0, 3),  # priority class
                  st.floats(0.0, 4.0, allow_nan=False)),  # arrival
        min_size=1, max_size=16,
    ))
    def test_no_starvation_under_mixed_priorities(specs):
        """Every submitted request is admitted within a bounded wait,
        no matter how priorities and arrivals interleave: aging
        promotes any class to 0 after priority * aging_s seconds, and
        class 0 is strict FCFS — so the oldest request can be overtaken
        only finitely often."""
        sched = TokenBudgetFCFS(token_budget=8, prefill_chunk=4,
                                aging_s=0.5)
        pool = FakePool(n_slots=2)
        reqs = [_req(arrival=a, priority=p) for p, a in specs]
        for r in reqs:
            sched.submit(r)
        admitted_at = _drive(sched, pool)
        assert len(admitted_at) == len(reqs), "a request starved"
        assert not sched.pending
        for r in reqs:
            assert r.rid in admitted_at

    @settings(max_examples=60, deadline=None)
    @given(
        rate=st.floats(0.5, 10.0, allow_nan=False),
        burst=st.integers(1, 5),
        offsets=st.lists(st.floats(0.0, 8.0, allow_nan=False),
                         min_size=1, max_size=64),
    )
    def test_token_bucket_never_exceeds_rate(rate, burst, offsets):
        """Admissions over any window never exceed burst + rate*window."""
        bucket = TokenBucket(rate, burst)
        admitted = []
        for t in sorted(offsets):
            if bucket.try_take(t) is None:
                admitted.append(t)
        # the invariant holds within EVERY sub-window, not just
        # end-to-end
        for i, t0 in enumerate(admitted):
            for j in range(i, len(admitted)):
                assert (j - i + 1
                        <= burst + rate * (admitted[j] - t0) + 1e-6)

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.floats(0.0, 10.0, allow_nan=False),
                    min_size=1, max_size=32))
    def test_unlimited_tenant_never_rejected(times):
        sched = TokenBudgetFCFS(
            token_budget=8, prefill_chunk=4,
            tenants={"vip": TenantPolicy(rate=None)})
        for t in sorted(times):
            sched.submit(_req(arrival=t, tenant="vip"))
        assert sched.pending == len(times)
else:
    @pytest.mark.skip(reason="property sweeps need hypothesis")
    def test_no_starvation_under_mixed_priorities():
        pass

    @pytest.mark.skip(reason="property sweeps need hypothesis")
    def test_token_bucket_never_exceeds_rate():
        pass


# ---------------------------------------------------------------------------
# deterministic unit tests
# ---------------------------------------------------------------------------


def test_token_bucket_refill_math():
    b = TokenBucket(1.0, 2)
    assert b.try_take(0.0) is None
    assert b.try_take(0.0) is None  # burst of 2
    retry = b.try_take(0.0)
    assert retry == pytest.approx(1.0)  # one token refills in 1s
    assert b.try_take(0.5) is not None  # still short
    assert b.try_take(1.0) is None  # refilled
    # non-monotonic clocks never mint tokens
    assert b.try_take(0.0) is not None


def test_rate_limited_rejection_is_typed_and_retryable():
    sched = TokenBudgetFCFS(
        token_budget=8, prefill_chunk=4,
        tenants={"free": TenantPolicy(rate=0.5, burst=1)},
    )
    sched.submit(_req(arrival=0.0, tenant="free"))
    with pytest.raises(AdmissionRejected) as ei:
        sched.submit(_req(arrival=0.0, tenant="free"))
    e = ei.value
    assert e.reason == "rate_limited" and e.retryable
    assert e.tenant == "free" and e.retry_after_s == pytest.approx(2.0)
    assert e.http_status == 429


def test_rate_limit_charged_before_queue_bound():
    """A rate-limited tenant's excess must surface as rate_limited, not
    consume everyone's queue_full budget."""
    sched = TokenBudgetFCFS(
        token_budget=8, prefill_chunk=4, max_queue=1,
        tenants={"free": TenantPolicy(rate=0.001, burst=1)},
    )
    sched.submit(_req(arrival=0.0, tenant="free"))  # fills the queue
    with pytest.raises(AdmissionRejected) as ei:
        sched.submit(_req(arrival=0.0, tenant="free"))
    assert ei.value.reason == "rate_limited"
    with pytest.raises(AdmissionRejected) as ei:
        sched.submit(_req(arrival=0.0, tenant="other"))
    assert ei.value.reason == "queue_full"
    assert ei.value.pending == 1 and ei.value.limit == 1


def test_priority_orders_queue_fcfs_within_class():
    sched = TokenBudgetFCFS(token_budget=8, prefill_chunk=4)
    lo1 = _req(arrival=0.0, priority=2)
    hi = _req(arrival=0.2, priority=0)
    lo2 = _req(arrival=0.1, priority=2)
    for r in (lo1, hi, lo2):
        sched.submit(r)
    sched.admit_arrivals(0.5)
    assert [r.rid for r in sched.queue] == [hi.rid, lo1.rid, lo2.rid]


def test_all_class_zero_keeps_plain_fcfs_deque():
    """The fast path: no priorities anywhere -> queue is the original
    arrival-ordered deque, untouched by sorting."""
    sched = TokenBudgetFCFS(token_budget=8, prefill_chunk=4)
    reqs = [_req(arrival=0.1 * i) for i in range(5)]
    for r in reqs:
        sched.submit(r)
    sched.admit_arrivals(10.0)
    assert [r.rid for r in sched.queue] == [r.rid for r in reqs]


def test_aging_promotes_low_class_to_head():
    sched = TokenBudgetFCFS(token_budget=8, prefill_chunk=4, aging_s=1.0)
    old_lo = _req(arrival=0.0, priority=2)
    fresh_hi = _req(arrival=2.5, priority=0)
    sched.submit(old_lo)
    sched.submit(fresh_hi)
    sched.admit_arrivals(2.6)
    # at t=2.6 old_lo has waited 2.6s -> aged 2 classes -> class 0,
    # and within class 0 its earlier arrival wins the head
    assert sched.effective_priority(old_lo, 2.6) == 0
    assert [r.rid for r in sched.queue] == [old_lo.rid, fresh_hi.rid]


def test_shed_priority_is_lowest_configured_class_never_zero():
    assert TokenBudgetFCFS(token_budget=8, prefill_chunk=4
                           ).shed_priority() == 1
    sched = TokenBudgetFCFS(
        token_budget=8, prefill_chunk=4,
        tenants={"paid": TenantPolicy(priority=0),
                 "batch": TenantPolicy(priority=3)},
    )
    assert sched.shed_priority() == 3


def test_tenant_policy_resolves_default_priority():
    sched = TokenBudgetFCFS(
        token_budget=8, prefill_chunk=4,
        tenants={"free": TenantPolicy(priority=2)},
    )
    r = _req(tenant="free")
    sched.submit(r)
    assert r.priority == 2  # inherited from the policy
    pinned = _req(tenant="free", priority=0)
    sched.submit(pinned)
    assert pinned.priority == 0  # explicit pin wins
    with pytest.raises(ValueError):
        sched.submit(_req(priority=-1))


def test_tenant_policy_validation():
    with pytest.raises(ValueError):
        TenantPolicy(rate=0.0)
    with pytest.raises(ValueError):
        TenantPolicy(burst=0)
    with pytest.raises(ValueError):
        TenantPolicy(priority=-1)
