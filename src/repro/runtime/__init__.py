"""Distributed runtime: sharding rules, mesh context, HLO analysis, roofline."""
from repro.runtime.sharding import (
    MeshContext,
    constrain,
    current_mesh_context,
    default_rules,
    mesh_context,
)

__all__ = [
    "MeshContext",
    "constrain",
    "current_mesh_context",
    "default_rules",
    "mesh_context",
]
