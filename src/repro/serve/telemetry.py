"""Engine telemetry: span tracing, lifecycle metrics, Perfetto export.

Three pieces, each off by default and costing nothing when off:

  * :class:`Tracer` — a fixed-capacity ring buffer of *spans*.  Code
    brackets a phase with ``with tracer.span("prefill_batch", lanes=4):``
    and the tracer records (name, start, end, depth, attrs) — wall-clock
    host time by default.  Because JAX dispatch is asynchronous, host
    timers measure *enqueue* time, not device time; ``sync=True`` inserts
    a ``block_until_ready`` barrier at BOTH span edges (via the
    ``sync_fn`` the engine provides, which blocks on the KV pool buffers
    every fused dispatch donates and returns), so a synced span's
    duration is honest device-inclusive time.  ``annotate=True``
    additionally wraps each span in ``jax.profiler.TraceAnnotation`` so
    the same names show up inside XLA profiler traces and the two
    timelines can be lined up.  The buffer wraps: the newest ``capacity``
    spans survive, older ones are overwritten (telemetry never OOMs a
    long-running engine).

  * :class:`MetricsRegistry` — typed :class:`Counter` / :class:`Gauge` /
    :class:`Histogram` metrics behind one ``snapshot()``.  Counters are
    monotonic ints the engine bumps on the hot path; gauges are either
    set values or zero-argument callbacks evaluated at snapshot time
    (how the KV pool's occupancy/refcount/COW gauges are pulled in
    without the pool knowing about telemetry); histograms keep raw
    samples and compute percentiles with ``numpy.percentile`` — the ONE
    histogram implementation TTFT/ITL/queue-time all flow through, so
    in-engine percentiles match any external recomputation exactly.

  * Chrome/Perfetto export — :meth:`Tracer.chrome_events` renders the
    ring as trace-event-format complete events (``"ph": "X"``, µs
    timestamps) plus instant events for request-lifecycle marks;
    :meth:`Tracer.export_chrome_trace` writes the JSON object Perfetto
    (https://ui.perfetto.dev) and ``chrome://tracing`` load directly.
    :func:`validate_chrome_trace` is the schema gate CI runs on every
    emitted trace.

Span taxonomy (DESIGN.md §11): the engine's root span per tick is
``step``; its direct children are the phases ``schedule``, ``prefill``,
``decode``, ``verify`` and ``emit``; adapter-level dispatch spans
(``dispatch:prefill_paged``, ``dispatch:decode_paged``,
``dispatch:verify_paged``) nest inside their phase.  TP adapters tag
every span with the mesh geometry (``Tracer.tags``).
:func:`phase_breakdown` aggregates a trace back into per-phase totals
and a coverage ratio (phase time / step time) — the acceptance gate for
"spans cover the tick".
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from typing import Callable, Optional

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "phase_breakdown",
    "validate_chrome_trace",
]

try:  # optional: lines engine spans up with XLA profiler timelines
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - profiler API unavailable
    _TraceAnnotation = None


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Span:
    """One recorded interval.  ``t0``/``t1`` are tracer-clock seconds;
    ``depth`` is the nesting level at record time (0 = root);
    instant events (lifecycle marks) have ``t1 == t0``."""

    name: str
    t0: float
    t1: float
    depth: int
    attrs: Optional[dict] = None
    instant: bool = False  # lifecycle mark recorded via Tracer.event

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _SpanHandle:
    """Context manager for one live span; records into the ring on exit."""

    __slots__ = ("_tracer", "_name", "_attrs", "_t0", "_depth", "_ann")

    def __init__(self, tracer: "Tracer", name: str, attrs: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._ann = None

    def __enter__(self):
        tr = self._tracer
        if tr.sync:
            tr._sync()
        if tr.annotate and _TraceAnnotation is not None:
            self._ann = _TraceAnnotation(self._name)
            self._ann.__enter__()
        self._depth = tr._depth
        tr._depth += 1
        self._t0 = tr.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        tr = self._tracer
        if tr.sync:
            tr._sync()
        t1 = tr.clock()
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        tr._depth -= 1
        tr._record(Span(self._name, self._t0, t1, self._depth, self._attrs))
        return False


class _NullSpan:
    """Shared no-op context manager: the entire cost of a disabled
    tracer is one method call returning this singleton."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Ring-buffer span tracer (see module docstring).

    Parameters:
      capacity   — max retained spans; older spans are overwritten.
      sync       — insert a device barrier (``sync_fn``) at span edges so
                   durations include device time, not just dispatch.
      sync_fn    — zero-arg barrier; the engine wires one that blocks on
                   the KV pool buffers every fused dispatch returns.
      annotate   — wrap spans in ``jax.profiler.TraceAnnotation``.
      clock      — monotonic seconds; defaults to ``time.perf_counter``.
                   The engine passes its OWN clock (``Engine.now``) so
                   span times share the request-arrival epoch.
      tags       — dict merged into every exported event's args (TP
                   adapters put mesh geometry here).
    """

    enabled = True

    def __init__(
        self,
        capacity: int = 1 << 16,
        *,
        sync: bool = False,
        sync_fn: Optional[Callable[[], None]] = None,
        annotate: bool = False,
        clock: Callable[[], float] = time.perf_counter,
        tags: Optional[dict] = None,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.sync = sync
        self.sync_fn = sync_fn
        self.annotate = annotate
        self.clock = clock
        self.tags = dict(tags or {})
        self._ring: list = [None] * capacity
        self._n = 0  # total spans ever recorded (ring index = _n % capacity)
        self._depth = 0
        self.dropped = 0  # spans overwritten by wraparound

    # ---- recording ------------------------------------------------------

    def span(self, name: str, **attrs) -> _SpanHandle:
        return _SpanHandle(self, name, attrs or None)

    def event(self, name: str, **attrs) -> None:
        """Record an instant event (a lifecycle mark) at the current time."""
        t = self.clock()
        self._record(Span(name, t, t, self._depth, attrs or None, instant=True))

    def _record(self, span: Span) -> None:
        if self._n >= self.capacity:
            self.dropped += 1
        self._ring[self._n % self.capacity] = span
        self._n += 1

    def _sync(self) -> None:
        if self.sync_fn is not None:
            self.sync_fn()

    # ---- reading --------------------------------------------------------

    @property
    def spans(self) -> list:
        """Retained spans, oldest first (wraparound already resolved)."""
        if self._n <= self.capacity:
            return [s for s in self._ring[: self._n]]
        i = self._n % self.capacity
        return self._ring[i:] + self._ring[:i]

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def clear(self) -> None:
        self._ring = [None] * self.capacity
        self._n = 0
        self.dropped = 0

    # ---- export ---------------------------------------------------------

    def chrome_events(self) -> list:
        """Trace-event-format events: complete ("X") for spans, instant
        ("i") for zero-duration lifecycle marks.  Timestamps in µs."""
        events = []
        for s in self.spans:
            args = dict(self.tags)
            if s.attrs:
                args.update(s.attrs)
            ev = {
                "name": s.name,
                "cat": "engine",
                "ts": s.t0 * 1e6,
                "pid": 0,
                "tid": 0,
                "args": args,
            }
            if s.instant:
                ev["ph"] = "i"
                ev["s"] = "t"  # instant scope: thread
            else:
                ev["ph"] = "X"
                ev["dur"] = s.dur * 1e6
            events.append(ev)
        return events

    def export_chrome_trace(self, path) -> dict:
        """Write the trace as Chrome/Perfetto trace-event JSON; returns
        the written object.  Open in https://ui.perfetto.dev or
        ``chrome://tracing``."""
        obj = {
            "traceEvents": [
                {  # name the single engine row
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 0,
                    "tid": 0,
                    "args": {"name": "engine"},
                },
                *self.chrome_events(),
            ],
            "displayTimeUnit": "ms",
            "otherData": {
                "tracer": "repro.serve.telemetry",
                "sync": self.sync,
                "dropped_spans": self.dropped,
                **{str(k): str(v) for k, v in self.tags.items()},
            },
        }
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj


class _NullTracer(Tracer):
    """Disabled tracer: ``span()`` hands back one shared no-op context
    manager and nothing is ever recorded.  This is the engine default —
    the hot path's entire telemetry tax is the method call."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=1)

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def event(self, name: str, **attrs) -> None:
        pass

    def _record(self, span: Span) -> None:  # pragma: no cover - unreachable
        pass


NULL_TRACER = _NullTracer()


# ---------------------------------------------------------------------------
# trace analysis + schema validation
# ---------------------------------------------------------------------------


def phase_breakdown(spans, root: str = "step") -> dict:
    """Aggregate spans into per-phase totals and a coverage ratio.

    A *phase* is any span recorded at ``depth == root_depth + 1`` inside
    the root spans (the engine's ``schedule``/``prefill``/``decode``/
    ``verify``/``emit``).  Returns::

        {"root_s": total root time, "root_count": n,
         "phases": {name: {"time_s", "count", "share"}},
         "coverage": phase time / root time}

    ``share`` is each phase's fraction of total root time — the per-tick
    time attribution the benchmarks record.  Coverage is the acceptance
    gate: phases must account for (nearly) all of a tick.
    """
    roots = [s for s in spans if s.name == root]
    root_s = sum(s.dur for s in roots)
    depth = roots[0].depth + 1 if roots else 1
    phases: dict = {}
    for s in spans:
        if s.depth != depth or s.instant or s.name == root:
            continue
        p = phases.setdefault(s.name, {"time_s": 0.0, "count": 0})
        p["time_s"] += s.dur
        p["count"] += 1
    covered = sum(p["time_s"] for p in phases.values())
    for p in phases.values():
        p["share"] = p["time_s"] / root_s if root_s > 0 else 0.0
    return {
        "root_s": root_s,
        "root_count": len(roots),
        "phases": phases,
        "coverage": covered / root_s if root_s > 0 else 0.0,
    }


def validate_chrome_trace(obj) -> int:
    """Validate a trace-event JSON object (the schema gate CI runs).

    Checks the envelope and every event: required keys, known phase
    types, numeric non-negative timestamps, ``dur`` present exactly on
    complete events.  Returns the number of non-metadata events; raises
    ``ValueError`` on the first violation.
    """
    if not isinstance(obj, dict):
        raise ValueError(f"trace must be a JSON object, got {type(obj).__name__}")
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace object missing 'traceEvents' list")
    n = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        ph = ev.get("ph")
        if ph not in ("X", "i", "M", "C", "B", "E"):
            raise ValueError(f"traceEvents[{i}] bad phase {ph!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"traceEvents[{i}] missing name")
        if ph == "M":
            continue  # metadata events carry no timestamp
        n += 1
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"traceEvents[{i}] bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"traceEvents[{i}] bad dur {dur!r}")
        elif "dur" in ev:
            raise ValueError(f"traceEvents[{i}] ph={ph!r} must not carry dur")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                raise ValueError(f"traceEvents[{i}] bad {key}")
    if n == 0:
        raise ValueError("trace contains no events")
    return n


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic integer counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def peak(self, v) -> None:
        """Track a high-water mark (e.g. widest prefill batch seen)."""
        if v > self.value:
            self.value = v

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Point-in-time value: either set explicitly or pulled from a
    zero-argument callback at snapshot time (how pool/scheduler state is
    surfaced without those objects knowing about telemetry)."""

    __slots__ = ("name", "_value", "fn")

    def __init__(self, name: str, fn: Optional[Callable] = None):
        self.name = name
        self._value = 0
        self.fn = fn

    def set(self, v) -> None:
        self._value = v

    @property
    def value(self):
        return self.fn() if self.fn is not None else self._value

    def reset(self) -> None:
        self._value = 0


class Histogram:
    """Raw-sample histogram; percentiles via ``numpy.percentile``.

    This is the single latency-percentile implementation: TTFT, ITL and
    queue-time all observe into one of these, and any external consumer
    recomputing percentiles from the same samples with numpy gets
    bit-identical answers.
    """

    __slots__ = ("name", "samples")

    def __init__(self, name: str):
        self.name = name
        self.samples: list = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(np.sum(self.samples)) if self.samples else 0.0

    def percentile(self, q: float) -> Optional[float]:
        """q-th percentile, or None when empty (a 1-token request has no
        inter-token gaps — empty must serialize as JSON null, not NaN)."""
        if not self.samples:
            return None
        return float(np.percentile(np.asarray(self.samples), q))

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.sum / self.count if self.samples else None,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
        }

    def reset(self) -> None:
        self.samples = []


class MetricsRegistry:
    """Named counters/gauges/histograms with one ``snapshot()``.

    ``counter``/``gauge``/``histogram`` create-or-return (idempotent), so
    call sites can grab metrics by name without wiring.  ``snapshot()``
    returns a flat dict: counters and gauges by name, histograms
    expanded to ``<name>_count`` / ``<name>_mean`` / ``<name>_p50`` /
    ``<name>_p99``.  ``reset()`` zeroes counters/set-gauges and clears
    histogram samples (callback gauges re-evaluate live state, so they
    are left alone) — pairs with ``Engine.reset_clock`` after a warm-up.
    """

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str, fn: Optional[Callable] = None) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, fn)
        elif fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name)
        return h

    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def snapshot(self) -> dict:
        out: dict = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, h in self._histograms.items():
            for k, v in h.summary().items():
                out[f"{name}_{k}"] = v
        return out

    def reset(self) -> None:
        for c in self._counters.values():
            c.reset()
        for g in self._gauges.values():
            g.reset()
        for h in self._histograms.values():
            h.reset()


def format_metrics_line(snapshot: dict, *, t: Optional[float] = None,
                        keys: Optional[list] = None) -> str:
    """One-line stderr rendering of a snapshot (``--metrics-every``)."""
    head = f"[metrics t={t:.1f}s]" if t is not None else "[metrics]"
    items = []
    for k in keys if keys is not None else snapshot:
        v = snapshot.get(k)
        if v is None:
            continue
        items.append(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}")
    return " ".join([head, *items])


def emit_metrics_line(snapshot: dict, *, t: Optional[float] = None,
                      keys: Optional[list] = None, file=None) -> None:
    print(format_metrics_line(snapshot, t=t, keys=keys),
          file=file or sys.stderr, flush=True)
