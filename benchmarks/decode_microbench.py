"""Decode-step micro-benchmark: paged in-place attention vs the gather-dense
oracle vs the quantized XLA-unpack fallback.

    PYTHONPATH=src python benchmarks/decode_microbench.py --smoke

Three sweeps, emitted as ``BENCH_decode.json``:

  * ``sweep_alloc`` — fixed context, growing per-sequence page *allocation*
    (``max_pages_per_seq``).  The gather-dense path copies the whole
    allocated window ``(L, B, Pmax*ps, KV, hd)`` every step, so its step
    time grows with allocation; the paged path buckets its block table to
    the attended prefix and must stay ~flat — "no per-step full-context
    copy": step time sublinear in allocated-but-unused pages.
  * ``sweep_ctx`` — fixed allocation, growing live context: both paths grow,
    paged from a far lower intercept.
  * ``quant_matvec`` — the QuantizedLinear decode matvec through the
    ``quant_matmul`` kernel dispatch (Pallas on TPU, jnp oracle here) vs
    the XLA unpack fallback that materializes the dequantized matrix.

CPU smoke-scale numbers: trends are what matter, not absolutes.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_smoke_config
from repro.serve import CachedDecoder, PagedKVPool
from repro.serve.kv_cache import page_bucket, pages_needed


def _time(fn, reps: int) -> float:
    fn()  # warm (jit compile)
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3  # ms


def bench_step(adapter, cfg, *, ctx: int, alloc_pages: int, page_size: int,
               reps: int) -> dict:
    """One decode lane with ``ctx`` live tokens in an ``alloc_pages``-page
    allocation; time the gather-dense step vs the paged in-place step."""
    pool = PagedKVPool(
        cfg, n_pages=alloc_pages + 2, page_size=page_size, n_slots=1,
        max_pages_per_seq=alloc_pages,
    )
    slot = pool.admit(ctx)
    assert slot is not None
    assert pool.extend(slot, ctx + 1)  # page for the decoded token
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    kv = jax.random.normal(
        jax.random.PRNGKey(0), (L, ctx, KV, hd), pool.k.dtype
    )
    pool.write_span(slot, 0, ctx, kv, kv)

    tokens = np.ones((1, 1), np.int32)
    positions = np.full((1, 1), ctx, np.int32)
    ctx_len = np.full((1,), ctx, np.int32)

    def dense_step():
        ctx_k, ctx_v = pool.gather([slot])
        logits, k_new, v_new = adapter(
            jnp.asarray(tokens), jnp.asarray(positions), ctx_k, ctx_v,
            jnp.asarray(ctx_len),
        )
        # mirror the engine: scatter the new token back into the pool
        pool.write([slot], [ctx], k_new[:, :, 0], v_new[:, :, 0])
        pool._slots[slot].length = ctx  # keep the step stationary
        return logits.block_until_ready()

    bt = pool.block_table([slot])
    nb = page_bucket(pages_needed(ctx, page_size), alloc_pages)
    pages, offs = pool.addresses([slot], [ctx])

    def paged_step():
        logits = adapter.decode_paged(
            tokens, positions, bt[:, :nb], ctx_len, pages, offs, pool
        )
        return logits.block_until_ready()

    return {
        "ctx": ctx,
        "alloc_pages": alloc_pages,
        "attended_pages": nb,
        "dense_ms": round(_time(dense_step, reps), 3),
        "paged_ms": round(_time(paged_step, reps), 3),
    }


def bench_quant_matvec(reps: int, *, m: int = 256, n: int = 256,
                       seed: int = 0) -> list[dict]:
    """QuantizedLinear decode matvec: XLA unpack fallback vs the
    quant_matmul kernel dispatch (jnp oracle off-TPU, Pallas on TPU)."""
    from repro.core.quantizer import QuipConfig, quantize_layer

    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    W = 0.02 * jax.random.normal(k1, (m, n))
    X = jax.random.normal(k2, (1024, n))
    H = X.T @ X / X.shape[0] + 1e-3 * jnp.eye(n)
    layer, _ = quantize_layer(
        W, H, QuipConfig(bits=2, method="ldlq"), seed=seed,
        collect_stats=False,
    )
    rows = []
    for B in (1, 8, 32):
        x = jax.random.normal(jax.random.PRNGKey(B), (B, n))
        fall = jax.jit(lambda z: layer(z, use_kernel=False))
        kern = jax.jit(lambda z: layer(z, use_kernel=True))
        rows.append({
            "batch": B, "m": m, "n": n, "bits": 2,
            "xla_unpack_ms": round(
                _time(lambda: fall(x).block_until_ready(), reps), 4
            ),
            "quant_matmul_ms": round(
                _time(lambda: kern(x).block_until_ready(), reps), 4
            ),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--ctx", type=int, default=32,
                    help="live context tokens for the allocation sweep")
    ap.add_argument("--alloc-sweep", type=int, nargs="+",
                    default=[4, 32, 256, 1024])
    ap.add_argument("--ctx-sweep", type=int, nargs="+",
                    default=[16, 64, 256, 1024])
    ap.add_argument("--reps", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args(argv)

    from repro.models import build_model

    cfg = get_smoke_config(args.arch)
    if not args.smoke:
        print("[decode_microbench] full-scale arch on CPU is impractical; "
              "using the smoke config (pass --smoke to silence this)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    adapter = CachedDecoder.from_model(model, params)

    need = pages_needed(args.ctx + 1, args.page_size)
    allocs = [a for a in args.alloc_sweep if a >= need]
    if not allocs:
        raise SystemExit(
            f"--ctx {args.ctx} needs {need} pages of {args.page_size}; "
            f"every --alloc-sweep value {args.alloc_sweep} is smaller"
        )
    if allocs != args.alloc_sweep:
        print(f"[decode_microbench] dropping allocations < {need} pages "
              f"(ctx {args.ctx} + 1 decoded token @ {args.page_size}/page)")
    sweep_alloc = [
        bench_step(adapter, cfg, ctx=args.ctx, alloc_pages=a,
                   page_size=args.page_size, reps=args.reps)
        for a in allocs
    ]
    max_ctx = max(args.ctx_sweep)
    alloc = max(2, pages_needed(max_ctx + 1, args.page_size))
    sweep_ctx = [
        bench_step(adapter, cfg, ctx=c, alloc_pages=alloc,
                   page_size=args.page_size, reps=args.reps)
        for c in args.ctx_sweep
    ]
    quant = bench_quant_matvec(args.reps, seed=args.seed)

    lo, hi = sweep_alloc[0], sweep_alloc[-1]
    rec = {
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "page_size": args.page_size,
        "sweep_alloc": sweep_alloc,
        "sweep_ctx": sweep_ctx,
        "quant_matvec": quant,
        # allocation grew hi/lo x with context fixed; how did step time move?
        "alloc_growth": {
            "pages_x": round(hi["alloc_pages"] / lo["alloc_pages"], 1),
            "dense_time_x": round(hi["dense_ms"] / max(lo["dense_ms"], 1e-9), 2),
            "paged_time_x": round(hi["paged_ms"] / max(lo["paged_ms"], 1e-9), 2),
        },
    }
    print(json.dumps(rec, indent=1))
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    g = rec["alloc_growth"]
    print(
        f"[decode_microbench] allocation x{g['pages_x']}: dense step time "
        f"x{g['dense_time_x']}, paged step time x{g['paged_time_x']} "
        f"(paged must stay ~flat: no per-step full-allocation copy)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
