"""Slot-based paged KV-cache pool (vLLM-style, pure JAX).

Physical storage is one tensor per K/V of shape

    (n_layers, n_pages, page_size, n_kv_heads, head_dim)

and each admitted sequence owns a *slot*: a row of a block table mapping
logical page index -> physical page.  Pages are claimed lazily as the
sequence grows (``extend``) and returned on ``release``, so the pool can
overcommit: ``n_slots * max_pages_per_seq`` may exceed ``n_pages``.  The
engine resolves page exhaustion by evicting a victim sequence.

Physical page 0 is reserved as a scratch page: padded batch lanes and
padded prefill tokens scatter their (ignored) writes there, which keeps
every device op shape-static — one compile for gather, one for scatter.

Keys are stored post-RoPE, matching ``models.layers.cache_store``.

Pages may be stored int8 (``dtype=jnp.int8``): values are quantized
per-(token, head) on scatter (symmetric, scale = max|x|/127, matching
``models.layers._quantize_kv``) with fp32 scales in parallel
``(L, P, ps, KV)`` tensors.  ``gather`` dequantizes; the paged-attention
kernel reads the int8 pages + scales directly (1 byte/elem of KV traffic).

Prefix caching (``prefix_cache=True``): every physical page carries a
refcount, and a hash trie over FULL pages of prompt tokens maps token
blocks to pages already holding their K/V.  ``admit(tokens=...)`` walks
the trie and maps matched pages into the new slot (refcount + 1) instead
of claiming fresh ones, so identical prompt prefixes (system prompts,
few-shot headers) are never recomputed; the engine starts prefill at
``length(slot)``.  Shared pages are immutable: any write resolving into a
page with refcount > 1 copies it first (copy-on-write), and a full-prefix
hit maps a private copy of its last page at admission so the engine can
recompute the final prompt token (its logits seed generation) in place.
The trie holds its own refcount on cached pages, so they survive the
owner's release and are reclaimed LRU-first only under page pressure
(DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.serve.faults import NO_FAULTS

__all__ = ["PagedKVPool", "pages_needed", "quantize_kv_int8"]


def pages_needed(n_tokens: int, page_size: int) -> int:
    return -(-n_tokens // page_size)


def page_bucket(n_pages: int, cap: int) -> int:
    """Round a page count up to a power of two, clamped to ``cap``.

    The paged decode dispatch is shape-static per block-table width; both
    the engine and the decode micro-benchmark bucket through here so the
    benchmark always measures the dispatch shape production uses.
    """
    b = 1
    while b < max(1, n_pages):
        b *= 2
    return min(b, cap)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter(phys: jax.Array, pages: jax.Array, offs: jax.Array,
             vals: jax.Array) -> jax.Array:
    """phys (L, P, ps, KV, hd); pages/offs (T,); vals (L, T, KV, hd)."""
    return phys.at[:, pages, offs].set(vals.astype(phys.dtype))


def quantize_kv_int8(vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 for (..., hd) values.

    Shared by the pool's scatter and the adapter's fused paged-decode step,
    so both write bit-identical pages; delegates to the dense cache path's
    quantizer so the two KV representations can never drift apart.
    """
    from repro.models.layers import _quantize_kv

    return _quantize_kv(vals)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_q(phys: jax.Array, scales: jax.Array, pages: jax.Array,
               offs: jax.Array, vals: jax.Array):
    """int8 variant: quantize vals (L, T, KV, hd), store values + scales."""
    q, sc = quantize_kv_int8(vals)
    return phys.at[:, pages, offs].set(q), scales.at[:, pages, offs].set(sc)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page(phys: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Copy one physical page across all layers: phys (L, P, ...)."""
    return phys.at[:, dst].set(phys[:, src])


@jax.jit
def _gather(phys: jax.Array, block_tables: jax.Array) -> jax.Array:
    """phys (L, P, ps, KV, hd), block_tables (B, Pmax) ->
    (L, B, Pmax*ps, KV, hd) contiguous per-sequence windows."""
    g = phys[:, block_tables]  # (L, B, Pmax, ps, KV, hd)
    L, B = g.shape[0], g.shape[1]
    return g.reshape(L, B, -1, *phys.shape[-2:])


@functools.partial(jax.jit, static_argnames=("dtype",))
def _gather_q(phys: jax.Array, scales: jax.Array, block_tables: jax.Array,
              dtype) -> jax.Array:
    """int8 variant of :func:`_gather`: dequantize to ``dtype``."""
    g = phys[:, block_tables].astype(jnp.float32)
    s = scales[:, block_tables]
    L, B = g.shape[0], g.shape[1]
    return (g * s[..., None]).astype(dtype).reshape(
        L, B, -1, *phys.shape[-2:]
    )


@dataclasses.dataclass
class _Slot:
    pages: list  # physical page ids, logical order
    length: int  # valid tokens written


@dataclasses.dataclass
class _PrefixNode:
    """One full page of cached prompt tokens in the prefix trie."""

    key: tuple  # (parent node id, token-block bytes) — the trie dict key
    page: int  # physical page holding this block's K/V
    parent: int  # parent node id (0 = root)
    children: set = dataclasses.field(default_factory=set)  # child node ids


class PagedKVPool:
    """Page accounting (host) + paged K/V storage (device).

    ``admit(n_tokens)`` -> slot id or None (not enough free pages/slots);
    ``extend(slot, new_len)`` -> bool (claims pages to cover ``new_len``);
    ``release(slot)`` returns all pages.  ``gather``/``write`` move data.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        n_pages: int,
        page_size: int,
        n_slots: int,
        max_pages_per_seq: int,
        dtype=None,
        prefix_cache: bool = False,
    ):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved scratch)")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_pages_per_seq = max_pages_per_seq
        dt = jnp.dtype(dtype) if dtype is not None else jnp.dtype(cfg.dtype)
        # fp dtype handed out by gather (and used for dequantized int8 reads)
        self._fp_dtype = jnp.dtype(cfg.dtype) if dt == jnp.int8 else dt
        shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        if dt == jnp.int8:
            self.k_scale = jnp.zeros(shape[:-1], jnp.float32)
            self.v_scale = jnp.zeros(shape[:-1], jnp.float32)
        else:
            self.k_scale = self.v_scale = None
        self._free_pages = list(range(n_pages - 1, 0, -1))  # pop() -> low ids
        self._free_slots = list(range(n_slots - 1, -1, -1))
        self._slots: dict[int, _Slot] = {}
        self.peak_pages_in_use = 0
        # ---- prefix cache state (inert when prefix_cache is False) ----
        self.prefix_cache = bool(prefix_cache)
        self._page_ref = np.zeros(n_pages, np.int32)  # 0 = free/scratch
        self._trie: OrderedDict[tuple, int] = OrderedDict()  # key -> node id
        self._nodes: dict[int, _PrefixNode] = {
            0: _PrefixNode(key=(), page=0, parent=0)  # root (no page)
        }
        self._next_node = 1
        self.cow_copies = 0  # pages copied before a write (COW)
        self.prefix_hit_pages = 0  # pages mapped from the trie at admit
        # fault-injection hooks (serve/faults.py); the engine points this
        # at its plan — the inert default iterates an empty rule list
        self.faults = NO_FAULTS

    # ---- accounting -----------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free_pages)

    @property
    def occupancy(self) -> float:
        return self.pages_in_use / (self.n_pages - 1)

    def seq_capacity_tokens(self) -> int:
        return self.max_pages_per_seq * self.page_size

    def fits(self, n_tokens: int) -> bool:
        """Whether a sequence of n_tokens can EVER be resident."""
        return (
            n_tokens <= self.seq_capacity_tokens()
            and pages_needed(n_tokens, self.page_size) <= self.n_pages - 1
        )

    def _claim(self) -> int:
        page = self._free_pages.pop()
        self._page_ref[page] = 1
        return page

    def _decref(self, page: int) -> None:
        self._page_ref[page] -= 1
        if self._page_ref[page] == 0:
            self._free_pages.append(page)

    def _available(self, need: int) -> bool:
        """Whether ``need`` pages can be produced, reclaiming cache-only
        pages (LRU-first) if the free list alone cannot cover it."""
        if need <= len(self._free_pages):
            return True
        return self._reclaim(need - len(self._free_pages))

    def admit(self, n_tokens: int, tokens=None) -> Optional[int]:
        """Claim a slot + pages for a sequence of ``n_tokens``.

        With the prefix cache enabled and ``tokens`` (the request's prompt
        prefix, int32) provided, full leading pages found in the trie are
        mapped shared (refcount + 1) instead of claimed, and the slot's
        ``length`` starts at the cached token count — the caller resumes
        prefill there.  A hit covering the WHOLE sequence maps a private
        copy of its last page and caps ``length`` at ``n_tokens - 1``: the
        engine must still compute (and rewrite, in place of the copy) the
        final token, whose logits seed generation.
        """
        need_total = max(1, pages_needed(n_tokens, self.page_size))
        if not self._free_slots or need_total > self.max_pages_per_seq:
            return None
        if self.faults.rules and self.faults.fire("pool_exhausted"):
            return None  # injected transient exhaustion (admission defers)
        shared: list[int] = []
        if self.prefix_cache and tokens is not None:
            shared = [
                self._nodes[nid].page
                for nid in self._prefix_lookup(np.asarray(tokens, np.int32))
            ]
            shared = shared[:need_total]
        full_hit = len(shared) * self.page_size >= n_tokens
        fresh = need_total - len(shared) + (1 if full_hit else 0)
        pages = []
        for pg in shared:  # pin BEFORE any reclaim can free cache-only pages
            self._page_ref[pg] += 1
            pages.append(pg)
        if not self._available(fresh):
            for pg in shared:
                self._decref(pg)  # trie still holds one ref -> never frees
            return None
        slot = self._free_slots.pop()
        if full_hit:
            # copy-on-admit: the engine will rewrite this page's final
            # token, and shared pages are immutable
            last = pages.pop()
            pages.append(self._copy_into_fresh(last))
            self._page_ref[last] -= 1
        while len(pages) < need_total:
            pages.append(self._claim())
        cached_len = min(len(shared) * self.page_size, n_tokens - 1)
        self._slots[slot] = _Slot(pages=pages, length=cached_len)
        self.prefix_hit_pages += len(shared)
        self.peak_pages_in_use = max(self.peak_pages_in_use, self.pages_in_use)
        return slot

    def extend(self, slot: int, new_len: int) -> bool:
        """Claim pages so the slot can hold ``new_len`` tokens."""
        st = self._slots[slot]
        need = pages_needed(new_len, self.page_size) - len(st.pages)
        if need <= 0:
            return True
        if self.faults.rules and self.faults.fire("pool_exhausted"):
            return False  # injected transient exhaustion (evict/requeue path)
        if len(st.pages) + need > self.max_pages_per_seq:
            return False
        if not self._available(need):
            return False
        for _ in range(need):
            st.pages.append(self._claim())
        self.peak_pages_in_use = max(self.peak_pages_in_use, self.pages_in_use)
        return True

    def release(self, slot: int) -> None:
        st = self._slots.pop(slot)
        for page in st.pages:
            self._decref(page)
        self._free_slots.append(slot)

    def truncate(self, slot: int, new_len: int) -> int:
        """Roll back a slot to ``new_len`` valid tokens, un-writing the
        tail: wholly-invalid trailing pages are unmapped (refcount
        decrement — a page the prefix trie or a COW sibling still holds
        survives for them) and the slot's valid length drops.  Page
        CONTENTS are never mutated here: a partially-valid tail page keeps
        its stale K/V above ``new_len``, which every reader masks via
        ``ctx_len`` and the next write overwrites.  Writes into shared
        pages were already copy-on-write resolved by the address paths, so
        rollback can only ever drop this slot's private view, never damage
        a cached page.  Speculative decode uses this to un-write rejected
        draft tokens.  Returns the number of pages unmapped."""
        st = self._slots[slot]
        if new_len < 0 or new_len > st.length:
            raise ValueError(
                f"truncate to {new_len} outside [0, {st.length}] "
                f"(slot {slot})"
            )
        keep = max(1, pages_needed(new_len, self.page_size))
        dropped = 0
        while len(st.pages) > keep:
            self._decref(st.pages.pop())
            dropped += 1
        st.length = new_len
        return dropped

    def length(self, slot: int) -> int:
        return self._slots[slot].length

    # ---- prefix cache ---------------------------------------------------

    def _page_key(self, parent: int, tokens: np.ndarray, i: int) -> tuple:
        ps = self.page_size
        return (parent, tokens[i * ps : (i + 1) * ps].tobytes())

    def cached_prefix_pages(self, tokens) -> int:
        """How many full leading pages of ``tokens`` the trie holds right
        now — the pages an admission would map shared instead of claiming.
        Admission-capacity estimates use this so a cached prompt is not
        rejected for pages it will never claim.  (Walking the trie
        refreshes the chain's LRU position, which is what we want: a
        prompt being sized up for admission is about to be served.)"""
        if not self.prefix_cache:
            return 0
        return len(self._prefix_lookup(np.asarray(tokens, np.int32)))

    def _prefix_lookup(self, tokens: np.ndarray) -> list[int]:
        """Longest chain of cached full pages matching ``tokens``; returns
        trie node ids (root excluded) and refreshes their LRU position."""
        out: list[int] = []
        parent = 0
        for i in range(len(tokens) // self.page_size):
            key = self._page_key(parent, tokens, i)
            nid = self._trie.get(key)
            if nid is None:
                break
            self._trie.move_to_end(key)
            out.append(nid)
            parent = nid
        return out

    def register_prefix(self, slot: int, tokens) -> None:
        """Insert the slot's fully-written leading pages of ``tokens`` into
        the trie.  Each inserted node takes its own refcount on the page,
        so cached K/V outlives the owning sequence; re-registering an
        already-cached chain is a cheap no-op walk."""
        if not self.prefix_cache:
            return
        tokens = np.asarray(tokens, np.int32)
        st = self._slots[slot]
        parent = 0
        for i in range(min(len(tokens), st.length) // self.page_size):
            key = self._page_key(parent, tokens, i)
            nid = self._trie.get(key)
            if nid is None:
                page = st.pages[i]
                nid = self._next_node
                self._next_node += 1
                self._trie[key] = nid
                self._nodes[nid] = _PrefixNode(
                    key=key, page=page, parent=parent
                )
                self._nodes[parent].children.add(nid)
                self._page_ref[page] += 1
            parent = nid

    def _remove_node(self, nid: int) -> None:
        node = self._nodes.pop(nid)
        del self._trie[node.key]
        self._nodes[node.parent].children.discard(nid)
        self._decref(node.page)

    def _reclaim(self, need: int) -> bool:
        """Free ``need`` pages by dropping cache-only trie leaves —
        entries whose page no live slot maps (refcount 1) and that have no
        children — oldest (LRU) first.  Dropping a leaf may expose its
        parent; loop until satisfied or stuck."""
        if not self.prefix_cache or need <= 0:
            return need <= 0
        freed = 0
        progress = True
        while freed < need and progress:
            progress = False
            for key, nid in list(self._trie.items()):
                node = self._nodes[nid]
                if node.children or self._page_ref[node.page] != 1:
                    continue
                self._remove_node(nid)
                freed += 1
                progress = True
                if freed >= need:
                    break
        return freed >= need

    def _copy_into_fresh(self, src: int) -> int:
        """Claim a free page and device-copy ``src`` into it (all layers)."""
        dst = self._claim()
        s, d = jnp.int32(src), jnp.int32(dst)
        self.k = _copy_page(self.k, s, d)
        self.v = _copy_page(self.v, s, d)
        if self.is_int8:
            self.k_scale = _copy_page(self.k_scale, s, d)
            self.v_scale = _copy_page(self.v_scale, s, d)
        self.cow_copies += 1
        return dst

    def _ensure_private(self, slot: int, logical_page: int) -> int:
        """Copy-on-write guard: writes never mutate a shared page.  If the
        slot's logical page is mapped by anyone else (refcount > 1), swap
        in a private copy first."""
        st = self._slots[slot]
        page = st.pages[logical_page]
        if self._page_ref[page] <= 1:
            return page
        if not self._available(1):
            raise RuntimeError(
                "copy-on-write needs a free page but the pool is exhausted "
                "(evict a sequence or grow n_pages)"
            )
        dst = self._copy_into_fresh(page)
        st.pages[logical_page] = dst
        self._page_ref[page] -= 1
        return dst

    # ---- gauges ---------------------------------------------------------

    @property
    def shared_pages(self) -> int:
        """Physical pages currently mapped by more than one owner."""
        return int(np.sum(self._page_ref > 1))

    @property
    def cached_pages(self) -> int:
        """Full prompt pages resident in the prefix trie."""
        return len(self._trie)

    @property
    def max_page_ref(self) -> int:
        return int(self._page_ref.max())

    @property
    def is_int8(self) -> bool:
        return self.k_scale is not None

    def metrics_gauges(self) -> dict:
        """Name -> zero-arg callback for every pool gauge, in the form
        :class:`repro.serve.telemetry.MetricsRegistry` registers (callback
        gauges are evaluated at snapshot time, so the registry always
        reports live pool state without the pool knowing about telemetry).
        The engine merges these into its registry; ``summary()`` and the
        periodic ``--metrics-every`` snapshots read them from there."""
        return {
            "pages_in_use": lambda: self.pages_in_use,
            "peak_pages_in_use": lambda: self.peak_pages_in_use,
            "occupancy": lambda: self.occupancy,
            "peak_occupancy": (
                lambda: self.peak_pages_in_use / max(1, self.n_pages - 1)
            ),
            "shared_pages": lambda: self.shared_pages,
            "cached_pages": lambda: self.cached_pages,
            "max_page_ref": lambda: self.max_page_ref,
            "cow_copies": lambda: self.cow_copies,
            "prefix_hit_pages": lambda: self.prefix_hit_pages,
        }

    def _storage(self) -> list:
        arrs = [self.k, self.v]
        if self.is_int8:
            arrs += [self.k_scale, self.v_scale]
        return arrs

    def total_bytes(self) -> int:
        """Logical bytes of KV page storage (all shards together)."""
        return sum(a.nbytes for a in self._storage())

    def device_bytes(self) -> int:
        """Bytes of KV page storage resident on ONE device.  For a pool
        sharded over kv heads this is ~1/mp of :meth:`total_bytes`."""
        return sum(
            a.addressable_shards[0].data.nbytes for a in self._storage()
        )

    # ---- device ops -----------------------------------------------------

    def block_table(self, slot_ids: list[Optional[int]]) -> np.ndarray:
        """(B, max_pages_per_seq) int32; missing slots/pages -> scratch 0."""
        bt = np.zeros((len(slot_ids), self.max_pages_per_seq), np.int32)
        for b, s in enumerate(slot_ids):
            if s is None:
                continue
            pages = self._slots[s].pages
            bt[b, : len(pages)] = pages
        return bt

    def gather(self, slot_ids: list[Optional[int]]):
        """-> (k, v) each (L, B, max_pages_per_seq*page_size, KV, hd).

        int8 pools dequantize on the way out, so callers always see fp.
        """
        bt = jnp.asarray(self.block_table(slot_ids))
        if self.is_int8:
            return (
                _gather_q(self.k, self.k_scale, bt, self._fp_dtype),
                _gather_q(self.v, self.v_scale, bt, self._fp_dtype),
            )
        return _gather(self.k, bt), _gather(self.v, bt)

    def _addr(self, slot: Optional[int], pos: int) -> tuple[int, int]:
        if slot is None:
            return 0, 0  # scratch
        st = self._slots[slot]
        page = st.pages[pos // self.page_size]
        return page, pos % self.page_size

    def addresses(
        self, slot_ids: list[Optional[int]], positions: list[int]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Physical (pages, offsets) int32 for one token per lane; ``None``
        lanes resolve to the scratch page.  Feeds the fused decode dispatch
        (adapter scatters in place) — pair with :meth:`note_written`.
        Write-intent: shared target pages are copy-on-write resolved."""
        pages = np.zeros(len(slot_ids), np.int32)
        offs = np.zeros(len(slot_ids), np.int32)
        for b, (s, p) in enumerate(zip(slot_ids, positions)):
            if s is not None:
                self._ensure_private(s, p // self.page_size)
            pages[b], offs[b] = self._addr(s, p)
        return pages, offs

    def span_addresses(
        self,
        slot_ids: list[Optional[int]],
        starts: list[int],
        n_valids: list[int],
        width: int,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Physical (pages, offsets), each (B, width) int32, for one prefill
        chunk per lane: lane b's tokens land at absolute positions
        ``starts[b] .. starts[b] + n_valids[b] - 1``; the padded tail (and
        ``None`` lanes) resolves to the scratch page.  Feeds the fused
        batched-prefill dispatch — pair with :meth:`note_span_written`.
        Write-intent: shared target pages are copy-on-write resolved."""
        B = len(slot_ids)
        pages = np.zeros((B, width), np.int32)
        offs = np.zeros((B, width), np.int32)
        for b, (s, start, n) in enumerate(zip(slot_ids, starts, n_valids)):
            if s is None or n <= 0:
                continue
            for lp in range(
                start // self.page_size, (start + n - 1) // self.page_size + 1
            ):
                self._ensure_private(s, lp)
            for t in range(n):
                pages[b, t], offs[b, t] = self._addr(s, start + t)
        return pages, offs

    def note_span_written(
        self, slot_ids: list[Optional[int]], starts: list[int],
        n_valids: list[int],
    ) -> None:
        """Host-side length accounting for prefill chunks a fused device
        step already scattered into the pool."""
        for s, start, n in zip(slot_ids, starts, n_valids):
            if s is not None and n > 0:
                st = self._slots[s]
                st.length = max(st.length, start + n)

    def note_written(
        self, slot_ids: list[Optional[int]], positions: list[int]
    ) -> None:
        """Host-side length accounting for tokens a fused device step
        already scattered into the pool."""
        for s, p in zip(slot_ids, positions):
            if s is not None:
                self._slots[s].length = max(self._slots[s].length, p + 1)

    def _scatter_kv(self, pages: np.ndarray, offs: np.ndarray,
                    k_new: jax.Array, v_new: jax.Array) -> None:
        pages, offs = jnp.asarray(pages), jnp.asarray(offs)
        if self.is_int8:
            self.k, self.k_scale = _scatter_q(
                self.k, self.k_scale, pages, offs, k_new
            )
            self.v, self.v_scale = _scatter_q(
                self.v, self.v_scale, pages, offs, v_new
            )
        else:
            self.k = _scatter(self.k, pages, offs, k_new)
            self.v = _scatter(self.v, pages, offs, v_new)

    def write(
        self,
        slot_ids: list[Optional[int]],
        positions: list[int],
        k_new: jax.Array,
        v_new: jax.Array,
    ) -> None:
        """Scatter one token per lane: k_new/v_new (L, B, KV, hd).

        Lane b writes at absolute position ``positions[b]`` of slot
        ``slot_ids[b]``; ``None`` lanes go to the scratch page.  Also
        advances each written slot's valid length to ``positions[b]+1``.
        """
        pages, offs = self.addresses(slot_ids, positions)
        self._scatter_kv(pages, offs, k_new, v_new)
        self.note_written(slot_ids, positions)

    def write_span(
        self, slot: int, start: int, n_valid: int, k_new: jax.Array,
        v_new: jax.Array,
    ) -> None:
        """Scatter a prefill chunk: k_new/v_new (L, T, KV, hd); the first
        ``n_valid`` tokens land at positions start..start+n_valid-1, the
        padded tail goes to the scratch page.  Shared target pages are
        copy-on-write resolved."""
        T = k_new.shape[1]
        pages, offs = self.span_addresses([slot], [start], [n_valid], T)
        self._scatter_kv(pages[0], offs[0], k_new, v_new)
        self._slots[slot].length = max(self._slots[slot].length, start + n_valid)
