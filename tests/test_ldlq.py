"""LDLQ unit tests: decomposition, OPTQ equivalence (Thm 6), optimality
(Thm 1 empirics), the finite-grid counterexample (Sec. 5.2), and the blocked
schedule equivalence used by the production path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_hessian, make_weights

from repro.core.ldlq import (
    ldl_decomposition,
    ldlq,
    ldlq_blocked,
    optq_reference,
    quantize_nearest,
    quantize_stoch,
)
from repro.core.proxy import proxy_loss


def test_ldl_decomposition_reconstructs():
    H = make_hessian(96, seed=0)
    Udot, D = ldl_decomposition(H)
    n = H.shape[0]
    rec = (Udot + jnp.eye(n)) @ jnp.diag(D) @ (Udot + jnp.eye(n)).T
    np.testing.assert_allclose(np.asarray(rec), np.asarray(H), rtol=1e-4, atol=1e-5)
    # strictly upper triangular
    assert float(jnp.max(jnp.abs(jnp.tril(Udot)))) == 0.0
    assert float(jnp.min(D)) > 0.0


def test_trD_less_than_trH():
    """tr(D) < tr(H) for non-diagonal H (the LDLQ-vs-near optimality gap)."""
    H = make_hessian(128, seed=1)
    _, D = ldl_decomposition(H)
    assert float(jnp.sum(D)) < float(jnp.trace(H))


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_optq_equals_ldlq_bit_exact(bits):
    """Theorem 6: OPTQ's iterative algorithm == LDLQ, exactly.

    Mirrors the paper's Supplement C.2 empirical verification with
    W ~ Unif[0,1] (scaled to the grid).  Run in float64: the two
    implementations are algebraically identical but follow different fp op
    orders, so fp32 can flip ties at a rounding boundary and the feedback
    then legitimately amplifies the flip downstream (measured below)."""
    from jax.experimental import enable_x64

    maxq = 2**bits - 1
    with enable_x64():
        W = (
            jax.random.uniform(jax.random.PRNGKey(0), (100, 100)) * maxq
        ).astype(jnp.float64)
        H = make_hessian(100, seed=2).astype(jnp.float64)
        Udot, _ = ldl_decomposition(H)
        a = ldlq(W, Udot, maxq)
        b = optq_reference(W, H, maxq)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_optq_equals_ldlq_fp32_tie_noise_bounded():
    """At fp32 the two equivalent paths may flip rare rounding ties; the
    disagreement must stay a small fraction of entries."""
    maxq = 3
    W = jax.random.uniform(jax.random.PRNGKey(0), (100, 100)) * maxq
    H = make_hessian(100, seed=2)
    Udot, _ = ldl_decomposition(H)
    a = ldlq(W, Udot, maxq)
    b = optq_reference(W, H, maxq)
    frac = float(jnp.mean((a != b).astype(jnp.float32)))
    assert frac < 0.02, f"fp32 tie disagreement too large: {frac}"


@pytest.mark.parametrize("block", [16, 32, 100])
def test_blocked_ldlq_matches_sequential(block):
    n = 100 if block == 100 else 128
    W = jax.random.uniform(jax.random.PRNGKey(1), (48, n)) * 3
    H = make_hessian(n, seed=4)
    Udot, _ = ldl_decomposition(H)
    a = ldlq(W, Udot, 3)
    b = ldlq_blocked(W, Udot, 3, block=block)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ldlq_beats_nearest_on_proxy():
    """Theorem 1 consequence: LDLQ <= Near on the proxy loss (integers)."""
    W = make_weights(64, 128, seed=5)
    H = make_hessian(128, seed=5)
    Udot, _ = ldl_decomposition(H)
    # generous grid so clamping never binds (the Thm-1 setting)
    scale = 50.0
    Wg = W * scale + 128
    maxq = 255
    l_ldlq = proxy_loss(ldlq(Wg, Udot, maxq) / scale, Wg / scale, H)
    l_near = proxy_loss(quantize_nearest(Wg, maxq) / scale, Wg / scale, H)
    assert float(l_ldlq) <= float(l_near) * 1.001


def test_stochastic_rounding_unbiased():
    z = jnp.full((20000,), 0.3)
    keys = jax.random.PRNGKey(11)
    q = quantize_stoch(z, 7, keys)
    assert abs(float(jnp.mean(q)) - 0.3) < 0.02
    assert set(np.unique(np.asarray(q))) <= {0.0, 1.0}


def test_finite_grid_counterexample():
    """Sec. 5.2 / Supplement C.3: clamped LDLQ can lose to nearest on a
    crafted (W, H) — the reason Theorem 7's Algorithm 5 exists."""
    n, d, c = 64, 16, 0.01
    H = np.ones((n, n)) + np.eye(n)
    H[n - 1, n - 1] = 1.0
    H[0, 1 : n - 1] += 2 * c
    H[1 : n - 1, 0] += 2 * c
    H[0, n - 1] += c
    H[n - 1, 0] += c
    H[0, 0] += 4 * c + n * c**2
    W = 0.499 * np.ones((d, n)) + 0.002 * (np.arange(n) % 2)
    H = jnp.asarray(H, jnp.float32)
    # W stays near 0.5 on the [0, 15] grid: the construction relies on the
    # grid boundary clamping LDLQ's large accumulated correction (Fig. 4)
    Wg = jnp.asarray(W, jnp.float32)
    Udot, _ = ldl_decomposition(H)
    l_ldlq = proxy_loss(ldlq(Wg, Udot, 15), Wg, H)
    l_near = proxy_loss(quantize_nearest(Wg, 15), Wg, H)
    assert float(l_ldlq) > float(l_near), (
        "counterexample should make clamped LDLQ worse than nearest"
    )


def test_ldlq_worst_case_identity_hessian():
    """With H = I the feedback vanishes: LDLQ == nearest rounding."""
    W = jax.random.uniform(jax.random.PRNGKey(3), (32, 64)) * 7
    H = jnp.eye(64)
    Udot, D = ldl_decomposition(H)
    assert float(jnp.max(jnp.abs(Udot))) == 0.0
    np.testing.assert_array_equal(
        np.asarray(ldlq(W, Udot, 7)), np.asarray(quantize_nearest(W, 7))
    )
