"""Bit-packing round-trip and layout-contract tests (+ hypothesis sweep)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import packing


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("shape", [(4, 32), (7, 33), (128, 130), (1, 1)])
def test_pack_unpack_roundtrip(bits, shape):
    m, n = shape
    maxq = 2**bits - 1
    Wq = jax.random.randint(jax.random.PRNGKey(bits), (m, n), 0, maxq + 1)
    packed = packing.pack(Wq, bits)
    assert packed.shape == (packing.packed_rows(n, bits), m)
    assert packed.dtype == jnp.int32
    out = packing.unpack(packed, bits, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(Wq))


@pytest.mark.parametrize("bits,vals", [(2, 16), (3, 10), (4, 8), (8, 4)])
def test_vals_per_word(bits, vals):
    assert packing.vals_per_word(bits) == vals


def test_layout_contract():
    """Value j of word i holds Wq[:, i*vals+j] in bits [b*j, b*(j+1))."""
    bits, m, n = 2, 3, 32
    Wq = jnp.arange(m * n).reshape(m, n) % 4
    packed = np.asarray(packing.pack(Wq, bits)).astype(np.uint32)
    vals = 32 // bits
    for col in range(m):
        for k in range(n):
            word = packed[k // vals, col]
            got = (word >> (bits * (k % vals))) & 3
            assert got == int(Wq[col, k])


def test_unsupported_bits():
    with pytest.raises(ValueError):
        packing.vals_per_word(5)


@settings(max_examples=30, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    m=st.integers(1, 40),
    n=st.integers(1, 90),
    seed=st.integers(0, 999),
)
def test_property_roundtrip(bits, m, n, seed):
    maxq = 2**bits - 1
    Wq = jax.random.randint(jax.random.PRNGKey(seed), (m, n), 0, maxq + 1)
    out = packing.unpack(packing.pack(Wq, bits), bits, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(Wq))
