"""Prefill micro-benchmark: batched-paged prefill vs the B=1 gather-dense
loop, with and without prefix-cache hits, over fp and int8 pages.

    PYTHONPATH=src python benchmarks/prefill_microbench.py --smoke

Each scenario prefills the same workload — ``--requests`` prompts of
``--prompt-len`` tokens, chunk width ``--prefill-chunk`` — through a real
:class:`repro.serve.Engine` and times prefill-only wall clock (``--gen 1``
keeps decode negligible):

  * ``dense``  — the oracle path: one ``(1, C)`` chunk per request per
    tick, each re-gathering its whole allocated page window;
  * ``paged``  — one fused cross-request ``(B, C)`` dispatch per tick
    reading prior context in place from the pool;
  * ``paged+prefix`` — same, with the prefix cache on and every prompt
    sharing a ``--prefix-frac`` common header: after the first request
    seeds the cache, later admissions map the shared pages and skip the
    recompute entirely (``prefix_hit_tokens`` in the record).

The record lands in ``BENCH_prefill.json``.  CPU smoke-scale numbers:
trends are what matter, not absolutes.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import CachedDecoder, Engine, EngineConfig


def _make_prompts(vocab: int, n: int, length: int, prefix_frac: float,
                  seed: int) -> np.ndarray:
    """n prompts of ``length`` sharing a common leading header of
    ``prefix_frac * length`` tokens (the system-prompt workload)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, int(length * prefix_frac))
    out = np.empty((n, length), np.int32)
    for i in range(n):
        tail = rng.integers(0, vocab, length - len(shared))
        out[i] = np.concatenate([shared, tail])
    return out


def run_workload(adapter, prompts, *, page_size, prefill_chunk, paged,
                 prefix_cache, kv_int8, reps, header=None) -> dict:
    """Time prefill of the whole prompt batch; returns medians + stats.

    With ``prefix_cache`` a seeder request carrying just the shared
    ``header`` runs first (outside the timer), so the measured batch hits
    a warm cache — the steady state of a system-prompt workload."""
    n, S = prompts.shape
    ecfg = EngineConfig(
        max_seq_len=S + 1,
        n_slots=n + 1,  # +1: the cache-seeder request
        page_size=page_size,
        token_budget=max(64, n * prefill_chunk),
        prefill_chunk=prefill_chunk,
        paged_decode=paged,
        paged_prefill=paged,
        prefix_cache=prefix_cache,
        kv_int8=kv_int8,
    )
    times, summary = [], {}
    for _ in range(reps + 1):  # first rep warms the jit caches
        engine = Engine(adapter, ecfg)
        if prefix_cache and header is not None and len(header):
            engine.submit(np.asarray(header), max_new=1)
            engine.run()
            engine.reset_stats()
        for p in prompts:
            engine.submit(np.asarray(p), max_new=1)
        t0 = time.perf_counter()
        engine.run()
        times.append(time.perf_counter() - t0)
        summary = engine.summary()
    return {
        "wall_ms": round(float(np.median(times[1:])) * 1e3, 2),
        "prefill_tokens": summary["prefill_tokens"],
        "prefix_hit_tokens": summary["prefix_hit_tokens"],
        "prefill_batch_size": summary["prefill_batch_size"],
        "cached_pages": summary["cached_pages"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--prefix-frac", type=float, default=0.5,
                    help="fraction of every prompt that is a shared header")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_prefill.json")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if not args.smoke:
        print("[prefill_microbench] full-scale arch on CPU is impractical; "
              "using the smoke config (pass --smoke to silence this)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    adapter = CachedDecoder.from_model(model, params)

    rows = []
    for n in args.requests:
        prompts = _make_prompts(
            cfg.vocab, n, args.prompt_len, args.prefix_frac, args.seed
        )
        header = prompts[0, : int(args.prompt_len * args.prefix_frac)]
        for kv_int8 in (False, True):
            kw = dict(
                page_size=args.page_size, prefill_chunk=args.prefill_chunk,
                kv_int8=kv_int8, reps=args.reps,
            )
            dense = run_workload(
                adapter, prompts, paged=False, prefix_cache=False, **kw
            )
            paged = run_workload(
                adapter, prompts, paged=True, prefix_cache=False, **kw
            )
            prefix = run_workload(
                adapter, prompts, paged=True, prefix_cache=True,
                header=header, **kw
            )
            rows.append({
                "requests": n,
                "prompt_len": args.prompt_len,
                "kv_pages": "int8" if kv_int8 else "fp",
                "dense_ms": dense["wall_ms"],
                "paged_ms": paged["wall_ms"],
                "paged_prefix_ms": prefix["wall_ms"],
                "paged_speedup": round(
                    dense["wall_ms"] / max(paged["wall_ms"], 1e-9), 2
                ),
                "prefill_batch_size": paged["prefill_batch_size"],
                # with the cache warm, every later request's shared header
                # is mapped, not recomputed:
                "prefix_hit_tokens": prefix["prefix_hit_tokens"],
                "prefill_tokens_cold": paged["prefill_tokens"],
                "prefill_tokens_prefix": prefix["prefill_tokens"],
                "cached_pages": prefix["cached_pages"],
            })
            r = rows[-1]
            print(f"[prefill_microbench] B={n} {r['kv_pages']}: dense "
                  f"{r['dense_ms']}ms, paged {r['paged_ms']}ms "
                  f"(x{r['paged_speedup']}), +prefix {r['paged_prefix_ms']}ms "
                  f"({r['prefix_hit_tokens']} tokens skipped)")

    rec = {
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "page_size": args.page_size,
        "prefill_chunk": args.prefill_chunk,
        "prefix_frac": args.prefix_frac,
        "sweep": rows,
    }
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items() if k != "sweep"}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
