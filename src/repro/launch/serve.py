"""Serving driver: batched prefill + decode with optional QuIP weights.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--quantize --bits 2]

The full-precision path exercises Model.prefill/decode_step (the functions
the decode_32k / long_500k dry-run cells lower); --quantize swaps in the
block-by-block QuIP model from launch/quantize.py (dense family) and
greedy-decodes with packed 2-bit weights through the structured
D^-1 -> V -> quant_matmul -> U^T inference path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.quantizer import QuipConfig
from repro.data import make_calibration
from repro.models import build_model


def greedy_generate(model, params, prompt, gen: int, kv_dtype=None):
    B, S = prompt.shape
    logits, cache = model.prefill(
        params, {"tokens": prompt}, kv_dtype=kv_dtype, max_len=S + gen
    )
    toks = [jnp.argmax(logits, -1)[:, None]]
    decode = jax.jit(model.decode_step)
    for i in range(gen - 1):
        logits, cache = decode(params, toks[-1], cache, jnp.int32(S + i))
        toks.append(jnp.argmax(logits, -1)[:, None])
    return jnp.concatenate(toks, axis=1)


def quantized_generate(qm, prompt, gen: int):
    """Greedy decode through the QuantizedModel (recompute path — the
    quantized forward is what we're exercising, not cache plumbing)."""
    toks = prompt
    for _ in range(gen):
        logits = qm.logits(toks)[:, -1]
        toks = jnp.concatenate([toks, jnp.argmax(logits, -1)[:, None]], axis=1)
    return toks[:, prompt.shape[1]:]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompt = make_calibration(
        cfg.vocab, n_segments=args.batch, seg_len=args.prompt_len,
        seed=args.seed + 3,
    ).tokens

    kd = jnp.int8 if args.kv_dtype == "int8" else None
    t0 = time.time()
    out_fp = greedy_generate(model, params, prompt, args.gen, kv_dtype=kd)
    t_fp = time.time() - t0
    print(f"[serve] fp {cfg.name}: {args.batch}x{args.gen} tokens "
          f"in {t_fp:.2f}s ({args.batch*args.gen/t_fp:.1f} tok/s)")

    if args.quantize:
        from repro.launch.quantize import quantize_dense_model

        calib = make_calibration(cfg.vocab, n_segments=8, seg_len=64,
                                 seed=args.seed + 7)
        qcfg = QuipConfig(bits=args.bits, method="ldlq", use_kernel=False)
        qm = quantize_dense_model(params, cfg, qcfg, calib.tokens,
                                  seed=args.seed, verbose=False)
        t0 = time.time()
        out_q = quantized_generate(qm, prompt, args.gen)
        t_q = time.time() - t0
        agree = float(jnp.mean((out_fp == out_q).astype(jnp.float32)))
        print(f"[serve] quip-{args.bits}bit: {t_q:.2f}s; "
              f"token agreement with fp: {agree:.2%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
