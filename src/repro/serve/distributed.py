"""Tensor-parallel distributed serving runtime (DESIGN.md §8).

Layers the single-device continuous-batching engine over a
``(data, model)`` device mesh.  Three things shard over the model axis:

  * **packed quantized weights** — each ``QuantizedLinear``'s 2-bit codes
    get a :class:`NamedSharding` resolved through the rule machinery in
    ``runtime/sharding.py``: column-parallel for QKV/up projections (the
    packed ``(packed_rows(n), m)`` tensor splits on its output dim ``m``)
    and row-parallel for O/down (splits on the packed reduction rows), so
    per-device HBM holds ~1/mp of every block's codes.  The small
    data-dependent factors (``s``, the diagonal rescale ``D``) replicate;
    the orthogonal incoherence transforms regenerate from seeds and ride
    along as replicated jit constants.  GSPMD partitions the projection
    matmuls accordingly — the cross-device reduction for a block lands as
    one psum after each row-parallel matmul (the Kronecker ``Uᵀ`` factor
    that follows mixes the output dim, so the sum cannot be deferred past
    it; see DESIGN.md §8);
  * **the physical KV page pool** — ``(L, P, ps, KV, hd)`` splits on the
    KV-head axis, NEVER on pages: every device owns the full page range
    for its local heads, so block-table indexing resolves locally and
    decode attention moves zero cross-device KV bytes;
  * **the paged-attention dispatches** — decode, batched chunked
    prefill, AND the speculative verify tick all run under ``shard_map``
    over the model axis: each device attends its local KV-head slice of
    the pool with its local query-head group, and the donated in-place
    K/V scatters in the same jitted steps write only local pages.  The
    verify/sampling dispatches are re-jitted with pinned
    ``out_shardings`` in :meth:`DistributedCachedDecoder.make_pool`,
    exactly like decode/prefill, so speculative TP serving moves zero
    cross-device KV bytes.

Everything degrades gracefully: a 1-wide model axis, or an architecture
whose KV-head count does not divide it, falls back to the replicated
single-device math (the divisibility fallback in ``logical_to_pspec``),
so the same engine code serves any mesh.

CPU testing: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
provides a multi-device host mesh; tests assert token-identical output
vs the single-device engine (tests/test_distributed.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.quantizer import QuantizedLinear
from repro.kernels.paged_attention.ops import (
    paged_gqa_decode,
    paged_gqa_prefill,
    paged_gqa_verify,
)
from repro.runtime.sharding import MeshContext, serving_rules
from repro.serve.adapter import CachedDecoder
from repro.serve.kv_cache import PagedKVPool

__all__ = [
    "DistributedCachedDecoder",
    "make_serving_mesh",
    "shard_quantized_model",
    "PACKED_AXES",
    "POOL_AXES",
]

# Logical axes of each QuantizedLinear's packed codes, shaped
# (packed_rows(n), m): axis 0 walks the packed reduction rows (the
# layer's INPUT dim), axis 1 the output features.  Column-parallel
# projections shard the output dim; row-parallel shard the reduction —
# the classic Megatron split, expressed through the same rule table the
# training mesh uses (heads/kv_heads/ff -> 'model' under serving_rules).
PACKED_AXES: dict[str, tuple] = {
    "attn.wq": (None, "heads"),
    "attn.wk": (None, "kv_heads"),
    "attn.wv": (None, "kv_heads"),
    "attn.wo": ("heads", None),
    "mlp.wi": (None, "ff"),
    "mlp.wg": (None, "ff"),
    "mlp.wo": ("ff", None),
}

# Physical page pool (L, P, ps, KV, hd): shard KV heads, never pages.
POOL_AXES: tuple = ("layers", "pages", None, "kv_heads", None)


def make_serving_mesh(dp: int, mp: int) -> Mesh:
    """A (data, model) serving mesh; validates against visible devices."""
    need = dp * mp
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh {dp}x{mp} needs {need} devices but only {have} are "
            f"visible (on CPU: XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={need})"
        )
    return jax.make_mesh((dp, mp), ("data", "model"))


def _serving_ctx(
    mesh: Mesh, rules: Optional[dict] = None
) -> MeshContext:
    return MeshContext(mesh=mesh, rules=dict(rules or serving_rules()))


def shard_quantized_linear(
    layer: QuantizedLinear, ctx: MeshContext, name: str
) -> QuantizedLinear:
    """Place one linear's packed codes sharded on the model axis.

    The divisibility fallback applies per array: a dim the mesh does not
    divide stays replicated, so odd head counts degrade instead of fail.
    """
    spec = ctx.pspec(PACKED_AXES[name], layer.packed.shape)
    packed = jax.device_put(layer.packed, NamedSharding(ctx.mesh, spec))
    rep = ctx.replicated()
    st = dataclasses.replace(
        layer.state,
        s=jax.device_put(layer.state.s, rep),
        D=(
            None if layer.state.D is None
            else jax.device_put(layer.state.D, rep)
        ),
    )
    return dataclasses.replace(layer, packed=packed, state=st)


def _put_tree(tree, sharding):
    return jax.tree.map(lambda a: jax.device_put(a, sharding), tree)


def shard_quantized_model(qm, ctx: MeshContext):
    """Re-place a ``QuantizedModel``'s arrays onto the mesh: packed codes
    sharded per :data:`PACKED_AXES`, everything else (embed, norms, the
    per-layer factors) replicated.  Returns a new model; the input is
    untouched (tests compare against it)."""
    rep = ctx.replicated()
    blocks = []
    for blk in qm.blocks:
        out = {}
        for name, val in blk.items():
            if isinstance(val, QuantizedLinear):
                out[name] = shard_quantized_linear(val, ctx, name)
            else:
                out[name] = _put_tree(val, rep)
        blocks.append(out)
    return dataclasses.replace(
        qm,
        embed=_put_tree(qm.embed, rep),
        final_norm=_put_tree(qm.final_norm, rep),
        blocks=blocks,
    )


def artifact_placer(ctx: MeshContext):
    """A ``placer`` for ``artifacts.load_quantized``: commits every leaf
    straight from the checkpoint shard to its mesh placement — packed
    codes to their model-axis sharding, the rest replicated — so loading
    a large artifact never materializes an unsharded device copy."""
    rep = ctx.replicated()

    def place(key: str, arr):
        parts = key.split("/")
        if (
            len(parts) == 4
            and parts[0] == "blocks"
            and parts[3] == "packed"
            and parts[2] in PACKED_AXES
        ):
            spec = ctx.pspec(PACKED_AXES[parts[2]], arr.shape)
            return jax.device_put(arr, NamedSharding(ctx.mesh, spec))
        return jax.device_put(arr, rep)

    return place


@dataclasses.dataclass
class DistributedCachedDecoder(CachedDecoder):
    """Tensor-parallel :class:`CachedDecoder` over a (data, model) mesh.

    Drop-in for the engine: the adapter hooks (`make_pool`, `_place`,
    `_paged_attention`) and the jit wrapping carry the distribution, the
    engine's host-side scheduling is untouched.  Build via
    :meth:`from_quantized` / :meth:`from_model` / :meth:`load`.
    """

    ctx: Optional[MeshContext] = None
    # set by make_pool once the pool geometry (and thus the divisibility
    # fallback) is known: whether the KV-head axis actually sharded
    _pool_sharded: bool = dataclasses.field(default=False, repr=False)

    def __post_init__(self):
        if self.ctx is None:
            raise ValueError(
                "DistributedCachedDecoder needs a MeshContext; build via "
                "from_quantized/from_model/load(mesh=...)"
            )
        super().__post_init__()
        self._rep = self.ctx.replicated()

    # ---- constructors ---------------------------------------------------

    @classmethod
    def from_quantized(
        cls, qm, *, mesh: Mesh, rules: Optional[dict] = None, **kw
    ) -> "DistributedCachedDecoder":
        ctx = _serving_ctx(mesh, rules)
        return super().from_quantized(
            shard_quantized_model(qm, ctx), ctx=ctx, **kw
        )

    @classmethod
    def from_model(
        cls, model, params, *, mesh: Mesh, rules: Optional[dict] = None, **kw
    ) -> "DistributedCachedDecoder":
        from repro.models.transformer import decoder_axes
        from repro.runtime.sharding import shard_put

        ctx = _serving_ctx(mesh, rules)
        params = shard_put(ctx, params, decoder_axes(model.cfg))
        return super().from_model(model, params, ctx=ctx, **kw)

    @classmethod
    def load(
        cls,
        directory,
        *,
        mesh: Mesh,
        rules: Optional[dict] = None,
        verify: bool = True,
        load_faults=None,
        **kw,
    ) -> tuple["DistributedCachedDecoder", dict]:
        """Load a persistent quantized artifact directly onto the mesh
        (each checkpoint leaf is committed to its sharding as it streams
        out of the npz shards).  Returns (adapter, manifest meta).
        ``verify``/``load_faults`` pass through to
        :func:`artifacts.load_quantized` (shard-digest checking and the
        corrupt_shard injection hook)."""
        from repro.serve.artifacts import load_quantized

        ctx = _serving_ctx(mesh, rules)
        qm, meta = load_quantized(directory, placer=artifact_placer(ctx),
                                  verify=verify, faults=load_faults)
        adapter = super().from_quantized(qm, ctx=ctx, **kw)
        return adapter, meta

    # ---- engine hooks ----------------------------------------------------

    @property
    def mesh(self) -> Mesh:
        return self.ctx.mesh

    def trace_tags(self) -> dict:
        """Mesh geometry stamped on every span: distributed traces stay
        interpretable after export (which axes existed, was the pool
        sharded over KV heads)."""
        shape = dict(self.mesh.shape)
        return {
            "mesh_data": int(shape.get("data", 1)),
            "mesh_model": int(shape.get("model", 1)),
            "mesh_devices": int(self.mesh.size),
            "pool_sharded": bool(self._pool_sharded),
        }

    def make_pool(self, **kw) -> PagedKVPool:
        """Pool with physical pages sharded over KV heads.

        Also (re)wraps the fused decode AND batched-prefill steps with
        pinned ``out_shardings`` so the donated pool buffers come back
        with the same placement every step — the scatters can never
        silently drift the pool to a different layout between steps.
        """
        pool = PagedKVPool(self.cfg, **kw)
        spec = self.ctx.pspec(POOL_AXES, pool.k.shape)
        kv_sh = NamedSharding(self.mesh, spec)
        pool.k = jax.device_put(pool.k, kv_sh)
        pool.v = jax.device_put(pool.v, kv_sh)
        rep = self._rep
        out_paged = (rep, kv_sh, kv_sh)
        out_sample = (rep, rep, kv_sh, kv_sh)  # (sel, logits, k, v)
        out_verify = (rep, rep, rep, kv_sh, kv_sh)  # (+ n_acc)
        if pool.is_int8:
            sc_sh = NamedSharding(self.mesh, P(*spec[:4]))
            pool.k_scale = jax.device_put(pool.k_scale, sc_sh)
            pool.v_scale = jax.device_put(pool.v_scale, sc_sh)
            self._fwd_paged_q = jax.jit(
                self._forward_paged_q,
                donate_argnums=(6, 7, 8, 9),
                out_shardings=(*out_paged, sc_sh, sc_sh),
            )
            self._fwd_prefill_q = jax.jit(
                self._forward_prefill_paged_q,
                donate_argnums=(6, 7, 8, 9),
                out_shardings=(*out_paged, sc_sh, sc_sh),
            )
            self._fwd_paged_sq = jax.jit(
                self._forward_paged_sample_q,
                donate_argnums=(10, 11, 12, 13), static_argnums=(14,),
                out_shardings=(*out_sample, sc_sh, sc_sh),
            )
            self._fwd_verify_q = jax.jit(
                self._forward_verify_q,
                donate_argnums=(12, 13, 14, 15), static_argnums=(16,),
                out_shardings=(*out_verify, sc_sh, sc_sh),
            )
        self._fwd_paged = jax.jit(
            self._forward_paged, donate_argnums=(6, 7),
            out_shardings=out_paged,
        )
        self._fwd_prefill = jax.jit(
            self._forward_prefill_paged, donate_argnums=(6, 7),
            out_shardings=out_paged,
        )
        self._fwd_paged_s = jax.jit(
            self._forward_paged_sample, donate_argnums=(10, 11),
            static_argnums=(12,), out_shardings=out_sample,
        )
        self._fwd_verify = jax.jit(
            self._forward_verify, donate_argnums=(12, 13),
            static_argnums=(14,), out_shardings=out_verify,
        )
        self._pool_sharded = spec[3] is not None
        return pool

    def _place(self, x, dtype=None):
        """Small per-step host arrays commit replicated on the mesh."""
        return jax.device_put(jnp.asarray(x, dtype), self._rep)

    def _place_tree(self, arrays: tuple):
        """One batched device_put of a step's host arrays, replicated."""
        return jax.device_put(arrays, self._rep)

    # ---- SPMD paged attention -------------------------------------------

    def _paged_attention(self, q, k_new, v_new, pool_k, pool_v, k_scale,
                         v_scale, block_tables, ctx_len, *, layer):
        """Decode attention under ``shard_map``: each model-axis shard
        attends only its local KV-head slice of the page pool (q rides
        the matching query-head group), so decode moves no KV bytes
        across devices.  Falls back to the replicated path when the pool
        could not shard (1-wide axis / indivisible KV heads)."""
        if not self._pool_sharded:
            return super()._paged_attention(
                q, k_new, v_new, pool_k, pool_v, k_scale, v_scale,
                block_tables, ctx_len, layer=layer,
            )
        h_spec = P(None, "model", None)
        kv_spec = P(None, None, None, "model", None)
        interpret = self.paged_interpret

        if k_scale is None:
            def local(q, kn, vn, kp, vp, bt, cl):
                return paged_gqa_decode(
                    q, kn, vn, kp, vp, bt, cl, layer=layer,
                    interpret=interpret,
                )

            f = shard_map(
                local, mesh=self.mesh,
                in_specs=(h_spec, h_spec, h_spec, kv_spec, kv_spec, P(), P()),
                out_specs=h_spec, check_rep=False,
            )
            return f(q, k_new, v_new, pool_k, pool_v, block_tables, ctx_len)

        sc_spec = P(None, None, None, "model")

        def local_q(q, kn, vn, kp, vp, ks, vs, bt, cl):
            return paged_gqa_decode(
                q, kn, vn, kp, vp, bt, cl, layer=layer, k_scale=ks,
                v_scale=vs, interpret=interpret,
            )

        f = shard_map(
            local_q, mesh=self.mesh,
            in_specs=(h_spec, h_spec, h_spec, kv_spec, kv_spec, sc_spec,
                      sc_spec, P(), P()),
            out_specs=h_spec, check_rep=False,
        )
        return f(q, k_new, v_new, pool_k, pool_v, k_scale, v_scale,
                 block_tables, ctx_len)

    def _paged_prefill_attention(self, q, k_new, v_new, pool_k, pool_v,
                                 k_scale, v_scale, block_tables, ctx_len,
                                 *, layer, verify=False, k_self=None,
                                 v_self=None):
        """Chunk-batch prefill attention under ``shard_map``: per shard it
        is the single-device prefill kernel over the local KV-head page
        slice (local chunk queries/K/V ride the matching head group), so
        batched prefill moves no KV bytes across devices — and the
        speculative verifier (``verify=True``, the same kernel, plus its
        int8-exactness diagonal override ``k/v_self``) inherits the exact
        sharding, so a TP verify tick also moves zero cross-device KV
        bytes.  Falls back to the replicated path when the pool could not
        shard."""
        if not self._pool_sharded:
            return super()._paged_prefill_attention(
                q, k_new, v_new, pool_k, pool_v, k_scale, v_scale,
                block_tables, ctx_len, layer=layer, verify=verify,
                k_self=k_self, v_self=v_self,
            )
        op = paged_gqa_verify if verify else paged_gqa_prefill
        h_spec = P(None, None, "model", None)  # (B, C, heads, hd)
        kv_spec = P(None, None, None, "model", None)
        interpret = self.paged_interpret

        if k_scale is None:
            def local(q, kn, vn, kp, vp, bt, cl):
                return op(
                    q, kn, vn, kp, vp, bt, cl, layer=layer,
                    interpret=interpret,
                )

            f = shard_map(
                local, mesh=self.mesh,
                in_specs=(h_spec, h_spec, h_spec, kv_spec, kv_spec, P(), P()),
                out_specs=h_spec, check_rep=False,
            )
            return f(q, k_new, v_new, pool_k, pool_v, block_tables, ctx_len)

        sc_spec = P(None, None, None, "model")

        if k_self is not None:
            # the self override shards like the chunk K/V (KV heads)
            def local_qs(q, kn, vn, kp, vp, ks, vs, ksf, vsf, bt, cl):
                return op(
                    q, kn, vn, kp, vp, bt, cl, layer=layer, k_scale=ks,
                    v_scale=vs, k_self=ksf, v_self=vsf,
                    interpret=interpret,
                )

            f = shard_map(
                local_qs, mesh=self.mesh,
                in_specs=(h_spec, h_spec, h_spec, kv_spec, kv_spec, sc_spec,
                          sc_spec, h_spec, h_spec, P(), P()),
                out_specs=h_spec, check_rep=False,
            )
            return f(q, k_new, v_new, pool_k, pool_v, k_scale, v_scale,
                     k_self, v_self, block_tables, ctx_len)

        def local_q(q, kn, vn, kp, vp, ks, vs, bt, cl):
            return op(
                q, kn, vn, kp, vp, bt, cl, layer=layer, k_scale=ks,
                v_scale=vs, interpret=interpret,
            )

        f = shard_map(
            local_q, mesh=self.mesh,
            in_specs=(h_spec, h_spec, h_spec, kv_spec, kv_spec, sc_spec,
                      sc_spec, P(), P()),
            out_specs=h_spec, check_rep=False,
        )
        return f(q, k_new, v_new, pool_k, pool_v, k_scale, v_scale,
                 block_tables, ctx_len)
