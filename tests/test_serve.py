"""Serving subsystem tests: paged KV pool invariants, continuous-batching
engine equivalence with the recompute/dense-cache reference paths (fp and
quantized), eviction-under-pressure recovery, and quantized-artifact
save/load round-trips."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_hessian, make_weights

from repro.configs import get_smoke_config
from repro.core.quantizer import (
    QuipConfig,
    linear_from_arrays,
    linear_to_arrays,
    quantize_layer,
)
from repro.data import make_calibration
from repro.models import build_model
from repro.serve import CachedDecoder, Engine, EngineConfig, PagedKVPool
from repro.serve.artifacts import load_quantized, save_quantized


def _smoke_cfg():
    return get_smoke_config("qwen3-14b")


# ---------------------------------------------------------------------------
# PagedKVPool invariants
# ---------------------------------------------------------------------------


def _pool(n_pages=9, page_size=4, n_slots=3, max_pages=4):
    return PagedKVPool(
        _smoke_cfg(), n_pages=n_pages, page_size=page_size, n_slots=n_slots,
        max_pages_per_seq=max_pages,
    )


def test_pool_admit_extend_release_accounting():
    pool = _pool()  # 8 usable pages
    assert pool.pages_in_use == 0
    a = pool.admit(5)  # 2 pages
    b = pool.admit(4)  # 1 page
    assert a is not None and b is not None and a != b
    assert pool.pages_in_use == 3
    assert pool.extend(a, 8)  # no new page needed
    assert pool.pages_in_use == 3
    assert pool.extend(a, 9)  # 3rd page
    assert pool.pages_in_use == 4
    pool.release(a)
    assert pool.pages_in_use == 1
    pool.release(b)
    assert pool.pages_in_use == 0
    assert pool.peak_pages_in_use == 4


def test_pool_admit_exhaustion_and_slot_limits():
    pool = _pool(n_pages=5, n_slots=2)  # 4 usable pages
    a = pool.admit(16)  # 4 pages: everything
    assert a is not None
    assert pool.admit(1) is None  # no pages left
    pool.release(a)
    a = pool.admit(1)
    b = pool.admit(1)
    assert a is not None and b is not None
    assert pool.admit(1) is None  # no slots left
    assert not pool.extend(a, 17)  # over max_pages_per_seq
    assert pool.fits(16) and not pool.fits(17)


def test_pool_extend_fails_without_free_pages():
    pool = _pool(n_pages=4, n_slots=2)  # 3 usable
    a = pool.admit(8)  # 2 pages
    b = pool.admit(4)  # 1 page
    assert not pool.extend(a, 9)  # would need a 3rd page
    pool.release(b)
    assert pool.extend(a, 9)


def test_pool_write_gather_roundtrip():
    cfg = _smoke_cfg()
    pool = _pool(page_size=4, max_pages=2)
    slot = pool.admit(6)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jnp.arange(L * 6 * KV * hd, dtype=jnp.float32).reshape(L, 6, KV, hd)
    pool.write_span(slot, 0, 6, k, -k)
    assert pool.length(slot) == 6
    gk, gv = pool.gather([slot, None])
    assert gk.shape == (L, 2, 8, KV, hd)
    np.testing.assert_array_equal(np.asarray(gk[:, 0, :6]), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(gv[:, 0, :6]), np.asarray(-k))
    # single-token write at position 6 (second page)
    tok_k = jnp.full((L, 1, KV, hd), 7.0)
    pool.write([slot], [6], tok_k, tok_k)
    gk, _ = pool.gather([slot])
    np.testing.assert_array_equal(np.asarray(gk[:, 0, 6]), np.asarray(tok_k[:, 0]))
    assert pool.length(slot) == 7


# ---------------------------------------------------------------------------
# Engine equivalence vs reference decode paths
# ---------------------------------------------------------------------------


def _run_engine(adapter, prompts, gen, *, arrival_gap=0.0, **ecfg_kw):
    kw = dict(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8, record_logits=True,
    )
    kw.update(ecfg_kw)
    engine = Engine(adapter, EngineConfig(**kw))
    reqs = [
        engine.submit(np.asarray(p), max_new=gen, arrival=i * arrival_gap)
        for i, p in enumerate(prompts)
    ]
    engine.run()
    return engine, reqs


def test_engine_fp_matches_dense_cache_path():
    """Engine (paged cache, continuous batching, chunked prefill) must
    reproduce Model.prefill/decode_step logits and greedy tokens."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10, seed=3).tokens
    gen = 6
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.01,
    )
    ref_toks = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        assert len(r.out_tokens) == gen
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref_toks[i])
    # logits equivalence (cached engine decode vs dense-cache decode),
    # recompute-free reference: full forward over prompt+generated
    full = np.concatenate([np.asarray(prompts), ref_toks], axis=1)
    hidden, _ = model.forward(params, {"tokens": jnp.asarray(full)})
    ref_logits = np.asarray(model.logits(params, hidden))
    S = prompts.shape[1]
    for i, r in enumerate(reqs):
        got = np.stack(r.step_logits)  # (gen, V)
        want = ref_logits[i, S - 1 : S - 1 + gen]
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.fixture(scope="module")
def quantized_smoke():
    from repro.launch.quantize import quantize_dense_model

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = make_calibration(cfg.vocab, n_segments=4, seg_len=32, seed=7)
    qcfg = QuipConfig(bits=2, method="ldlq", use_kernel=False)
    qm = quantize_dense_model(params, cfg, qcfg, calib.tokens, seed=0,
                              verbose=False)
    return cfg, qm, qcfg


def test_engine_quantized_matches_recompute(quantized_smoke):
    """Cached decode through the packed D^-1 -> V -> quant_matmul -> U^T
    path == the old per-token full-recompute, token-for-token."""
    from repro.launch.serve import quantized_generate

    cfg, qm, _ = quantized_smoke
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=12, seed=5).tokens
    gen = 5
    _, reqs = _run_engine(
        CachedDecoder.from_quantized(qm), prompts, gen, arrival_gap=0.01,
    )
    ref = np.asarray(quantized_generate(qm, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])
    # logits along the way must match the recompute oracle too
    for i, r in enumerate(reqs):
        seq = jnp.asarray(
            np.concatenate([np.asarray(prompts[i]), ref[i][:-1]])[None]
        )
        want = np.asarray(qm.logits(seq))[0, prompts.shape[1] - 1 :]
        np.testing.assert_allclose(
            np.stack(r.step_logits), want, rtol=2e-3, atol=2e-3
        )


# ---------------------------------------------------------------------------
# Paged fast path (in-place pool attention) vs the gather-dense oracle
# ---------------------------------------------------------------------------


def test_engine_paged_fp_matches_reference():
    """--check-style equivalence for the paged fast path: decode through
    the paged-attention dispatch (no per-step dense KV gather) must emit
    the exact greedy tokens of the dense-cache reference, logits included."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10, seed=3).tokens
    gen = 6
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.01, paged_decode=True,
    )
    ref_toks = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref_toks[i])
    full = np.concatenate([np.asarray(prompts), ref_toks], axis=1)
    hidden, _ = model.forward(params, {"tokens": jnp.asarray(full)})
    ref_logits = np.asarray(model.logits(params, hidden))
    S = prompts.shape[1]
    for i, r in enumerate(reqs):
        got = np.stack(r.step_logits)
        want = ref_logits[i, S - 1 : S - 1 + gen]
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_engine_paged_quantized_matches_recompute(quantized_smoke):
    """Paged decode with QuantizedLinear projections routed through the
    quant_matmul kernel dispatch == the per-token recompute oracle."""
    from repro.launch.serve import quantized_generate

    cfg, qm, _ = quantized_smoke
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=12, seed=5).tokens
    gen = 5
    _, reqs = _run_engine(
        CachedDecoder.from_quantized(qm), prompts, gen, arrival_gap=0.01,
        paged_decode=True,
    )
    ref = np.asarray(quantized_generate(qm, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_paged_int8_matches_gather_int8():
    """int8 pages: the paged kernel path dequantizes the same stored pages
    as the gather-dense oracle — token streams must agree exactly."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=9, seed=8).tokens
    gen = 5
    runs = []
    for paged in (False, True):
        _, reqs = _run_engine(
            CachedDecoder.from_model(model, params), prompts, gen,
            paged_decode=paged, kv_int8=True,
        )
        runs.append([np.asarray(r.out_tokens) for r in reqs])
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)


def test_engine_paged_eviction_under_page_pressure():
    """Eviction/requeue still reproduces exact tokens when decode runs the
    paged fast path (re-prefill after eviction goes through the oracle
    prefill into the same pool the kernel then reads)."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=10, paged_decode=True,
    )
    assert engine.stats["evictions"] > 0
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_paged_interpret_kernel_end_to_end():
    """The actual Pallas kernel (interpret mode) inside the fused decode
    dispatch — not just the jnp fallback — agrees with the reference."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=1, seg_len=10, seed=3).tokens
    gen = 3
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params, paged_interpret=True),
        prompts, gen, n_slots=2, paged_decode=True,
    )
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    np.testing.assert_array_equal(np.asarray(reqs[0].out_tokens), ref[0])


# ---------------------------------------------------------------------------
# Batched paged prefill (one fused cross-request dispatch per tick)
# ---------------------------------------------------------------------------


def test_engine_batched_prefill_fp_matches_reference():
    """Cross-request batched paged prefill must emit the exact greedy
    tokens AND logits of the dense-cache reference."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10, seed=3).tokens
    gen = 6
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.01, paged_decode=True, paged_prefill=True,
    )
    assert engine.stats["prefill_batches"] > 0
    ref_toks = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref_toks[i])
    full = np.concatenate([np.asarray(prompts), ref_toks], axis=1)
    hidden, _ = model.forward(params, {"tokens": jnp.asarray(full)})
    ref_logits = np.asarray(model.logits(params, hidden))
    S = prompts.shape[1]
    for i, r in enumerate(reqs):
        got = np.stack(r.step_logits)
        want = ref_logits[i, S - 1 : S - 1 + gen]
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_engine_batched_prefill_batches_multiple_lanes():
    """Co-arriving requests actually share one prefill dispatch (the
    scheduler's co-batchable group, not a B=1 loop)."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=8, seed=3).tokens
    engine, _ = _run_engine(
        CachedDecoder.from_model(model, params), prompts, 2,
        paged_decode=True, paged_prefill=True, token_budget=64,
    )
    assert engine.stats["prefill_batch_size"] >= 4


def test_engine_batched_prefill_quantized_matches_recompute(quantized_smoke):
    from repro.launch.serve import quantized_generate

    cfg, qm, _ = quantized_smoke
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=12, seed=5).tokens
    gen = 5
    _, reqs = _run_engine(
        CachedDecoder.from_quantized(qm), prompts, gen, arrival_gap=0.01,
        paged_decode=True, paged_prefill=True,
    )
    ref = np.asarray(quantized_generate(qm, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_batched_prefill_int8_matches_gather_int8():
    """int8 pages: the batched paged-prefill engine writes the same pages
    (shared quantizer) the gather-dense int8 engine reads — exact tokens."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=9, seed=8).tokens
    gen = 5
    runs = []
    for paged in (False, True):
        _, reqs = _run_engine(
            CachedDecoder.from_model(model, params), prompts, gen,
            paged_decode=paged, paged_prefill=paged, kv_int8=True,
        )
        runs.append([np.asarray(r.out_tokens) for r in reqs])
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)


def test_engine_batched_prefill_eviction_under_page_pressure():
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=10, paged_decode=True,
        paged_prefill=True,
    )
    assert engine.stats["evictions"] > 0
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_batched_prefill_interpret_kernel_end_to_end():
    """The actual chunked-prefill Pallas kernel (interpret mode) inside
    the fused dispatch — not just the jnp fallback — end to end."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=10, seed=3).tokens
    gen = 3
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params, paged_interpret=True),
        prompts, gen, n_slots=2, paged_decode=True, paged_prefill=True,
    )
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


# ---------------------------------------------------------------------------
# Prefix cache: trie hits, refcounts, copy-on-write, eviction
# ---------------------------------------------------------------------------


def test_engine_prefix_cache_skips_recompute_same_tokens():
    """Identical prompts: later admissions map cached pages (hit tokens
    counted, prefill work reduced) and still emit reference tokens."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = make_calibration(cfg.vocab, n_segments=1, seg_len=12, seed=3).tokens
    prompts = np.tile(np.asarray(base), (3, 1))
    gen = 5
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.2, paged_decode=True, paged_prefill=True,
        prefix_cache=True,
    )
    s = engine.summary()
    # 12-token prompts, 4-token pages: 2 later requests x >= 8 cached
    assert s["prefix_hit_tokens"] >= 16
    assert s["cached_pages"] >= 2
    assert s["prefill_tokens"] <= 3 * 12 - s["prefix_hit_tokens"]
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_prefix_cache_page_aligned_full_hit():
    """A prompt that is entirely cached full pages: admission maps a
    private COPY of the last page (copy-on-admit), recomputes only the
    final token, and emits the reference stream."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = make_calibration(cfg.vocab, n_segments=1, seg_len=8, seed=5).tokens
    prompts = np.tile(np.asarray(base), (2, 1))  # 8 tokens == 2 full pages
    gen = 4
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.2, paged_decode=True, paged_prefill=True,
        prefix_cache=True,
    )
    s = engine.summary()
    assert s["prefix_hit_tokens"] == 7  # capped at len(prompt) - 1
    assert s["cow_copies"] >= 1  # the copy-on-admit of the last page
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_prefix_cache_survives_eviction_pressure():
    """Prefix cache + overcommitted pool: cache-only pages are reclaimed
    under pressure, eviction/replay still reproduces exact tokens."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=10, paged_decode=True,
        paged_prefill=True, prefix_cache=True,
    )
    assert engine.stats["evictions"] > 0
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def _prefix_pool(**kw):
    args = dict(n_pages=13, page_size=4, n_slots=4, max_pages_per_seq=4,
                prefix_cache=True)
    args.update(kw)
    return PagedKVPool(_smoke_cfg(), **args)


def test_pool_prefix_trie_hit_and_refcounts():
    cfg = _smoke_cfg()
    pool = _prefix_pool()
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    toks = np.arange(10, dtype=np.int32)
    k = jnp.arange(L * 10 * KV * hd, dtype=jnp.float32).reshape(L, 10, KV, hd)
    a = pool.admit(10, tokens=toks)
    assert pool.length(a) == 0  # cold cache
    pool.write_span(a, 0, 10, k, -k)
    pool.register_prefix(a, toks)
    assert pool.cached_pages == 2  # two full 4-token pages of the prompt
    b = pool.admit(10, tokens=toks)
    assert pool.length(b) == 8  # both full pages mapped
    assert pool.shared_pages == 2 and pool.max_page_ref == 3
    gk, gv = pool.gather([b])
    np.testing.assert_array_equal(np.asarray(gk[:, 0, :8]), np.asarray(k[:, :8]))
    np.testing.assert_array_equal(np.asarray(gv[:, 0, :8]), np.asarray(-k[:, :8]))
    # different tokens past page 1 -> only one page matches
    toks2 = toks.copy()
    toks2[6] += 1
    c = pool.admit(10, tokens=toks2)
    assert pool.length(c) == 4
    # releasing the original keeps cached pages alive via the trie's refs
    pool.release(a)
    d = pool.admit(10, tokens=toks)
    assert pool.length(d) == 8


def test_pool_copy_on_write_divergence():
    """Writing into a shared page copies it first: the original owner's
    (and the cache's) view is untouched, the writer's view diverges."""
    cfg = _smoke_cfg()
    pool = _prefix_pool()
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    toks = np.arange(8, dtype=np.int32)
    k = jnp.arange(L * 8 * KV * hd, dtype=jnp.float32).reshape(L, 8, KV, hd)
    a = pool.admit(10, tokens=toks)
    pool.write_span(a, 0, 8, k, -k)
    pool.register_prefix(a, toks)
    b = pool.admit(10, tokens=toks)
    assert pool.length(b) == 8 and pool.shared_pages == 2
    assert pool.cow_copies == 0
    # b diverges INSIDE the shared prefix (e.g. a fork edited upstream)
    patch = jnp.full((L, 1, KV, hd), 99.0)
    pool.write_span(b, 5, 1, patch, patch)
    assert pool.cow_copies == 1
    ga, _ = pool.gather([a])
    np.testing.assert_array_equal(np.asarray(ga[:, 0, :8]), np.asarray(k))
    gb, _ = pool.gather([b])
    np.testing.assert_array_equal(np.asarray(gb[:, 0, 5]), np.asarray(patch[:, 0]))
    np.testing.assert_array_equal(np.asarray(gb[:, 0, 4]), np.asarray(k[:, 4]))
    # a fresh admit still sees the ORIGINAL cached content
    c = pool.admit(10, tokens=toks)
    gc_, _ = pool.gather([c])
    np.testing.assert_array_equal(np.asarray(gc_[:, 0, :8]), np.asarray(k))


def test_pool_prefix_cache_reclaimed_under_pressure():
    """Cache-only pages (refcount held solely by the trie) are reclaimed
    LRU-first when admit/extend would otherwise fail."""
    cfg = _smoke_cfg()
    pool = _prefix_pool()  # 12 usable pages
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    toks = np.arange(8, dtype=np.int32)
    k = jnp.zeros((L, 8, KV, hd), jnp.float32)
    a = pool.admit(8, tokens=toks)
    pool.write_span(a, 0, 8, k, k)
    pool.register_prefix(a, toks)
    pool.release(a)
    assert pool.cached_pages == 2 and pool.pages_in_use == 2
    # demand every page: the cached pages must be reclaimed, not block
    slots = [pool.admit(16) for _ in range(3)]
    assert all(s is not None for s in slots)
    assert pool.cached_pages == 0
    for s in slots:
        pool.release(s)
    assert pool.pages_in_use == 0


def test_pool_int8_write_gather_roundtrip():
    cfg = _smoke_cfg()
    pool = PagedKVPool(
        cfg, n_pages=9, page_size=4, n_slots=3, max_pages_per_seq=2,
        dtype=jnp.int8,
    )
    slot = pool.admit(6)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jax.random.normal(jax.random.PRNGKey(0), (L, 6, KV, hd), jnp.float32)
    pool.write_span(slot, 0, 6, k, -k)
    gk, gv = pool.gather([slot])
    assert gk.dtype == jnp.dtype(cfg.dtype)
    # int8 quantization error is bounded by scale/2 = max|x|/254 per head
    np.testing.assert_allclose(
        np.asarray(gk[:, 0, :6]), np.asarray(k), atol=0.03, rtol=0.02
    )
    np.testing.assert_allclose(
        np.asarray(gv[:, 0, :6]), np.asarray(-k), atol=0.03, rtol=0.02
    )


def test_engine_eviction_under_page_pressure():
    """Overcommitted pool: decode runs out of pages mid-stream, the newest
    sequence is evicted, requeued, and still finishes with exact tokens."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    # each seq needs 4 pages of 4; give the pool only 9 usable pages for 3
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=10,
    )
    assert engine.stats["evictions"] > 0
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_eviction_victim_can_be_asking_lane():
    """Regression: under hard pressure the victim must be the NEWEST
    running request — possibly the very lane asking for a page — never an
    older lane already granted pages this step (that used to leave a freed
    slot inside the decode batch -> KeyError)."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=16, seed=6).tokens
    gen = 16
    # 4 seqs x up to 8 pages of 4, but only 15 usable pages
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=4, page_size=4, n_pages=16, record_logits=False,
    )
    assert engine.stats["evictions"] > 0
    assert engine.pool.pages_in_use == 0  # everything released at drain
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_sampling_reproducible_and_greedy_default():
    """Non-greedy decode: same seed -> same stream regardless of batch
    composition; temperature 0 stays the exact greedy argmax path."""
    from repro.serve.scheduler import SamplingParams

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    adapter = CachedDecoder.from_model(model, params)
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=2).tokens
    gen = 6
    sp = SamplingParams(temperature=0.9, top_p=0.85, seed=42)

    def run(batch):
        engine = Engine(adapter, EngineConfig(
            max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
            token_budget=32, prefill_chunk=8,
        ))
        reqs = [
            engine.submit(np.asarray(prompts[i]), max_new=gen, sampling=sp)
            for i in batch
        ]
        engine.run()
        return {i: np.asarray(r.out_tokens) for i, r in zip(batch, reqs)}

    solo = run([0])
    batched = run([0, 1, 2])
    np.testing.assert_array_equal(solo[0], batched[0])
    # greedy (default SamplingParams) matches the reference generator
    from repro.launch.serve import greedy_generate

    engine = Engine(adapter, EngineConfig(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8,
    ))
    reqs = [engine.submit(np.asarray(p), max_new=gen) for p in prompts]
    engine.run()
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_sampling_param_validation():
    from repro.serve.scheduler import SamplingParams

    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_stop_token_finishes_request_early():
    """A request stops at its first stop-token emission (token included);
    the greedy stream up to that point is unchanged."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=8, seed=2).tokens
    gen = 8
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    stop = int(ref[0, 2])  # stop request 0 at its 3rd greedy token
    engine = Engine(
        CachedDecoder.from_model(model, params),
        EngineConfig(max_seq_len=prompts.shape[1] + gen, n_slots=4,
                     page_size=4, token_budget=32, prefill_chunk=8),
    )
    r0 = engine.submit(np.asarray(prompts[0]), max_new=gen,
                       stop_tokens=(stop,))
    r1 = engine.submit(np.asarray(prompts[1]), max_new=gen)
    engine.run()
    want = list(ref[0, : list(ref[0]).index(stop) + 1])
    np.testing.assert_array_equal(np.asarray(r0.out_tokens), want)
    assert len(r0.out_tokens) <= 3
    np.testing.assert_array_equal(np.asarray(r1.out_tokens), ref[1])
    assert engine.pool.pages_in_use == 0  # early finish released its pages


def test_engine_rejects_oversized_request():
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(
        CachedDecoder.from_model(model, params),
        EngineConfig(max_seq_len=16, n_slots=2, page_size=4),
    )
    with pytest.raises(ValueError):
        engine.submit(np.arange(10, dtype=np.int32), max_new=8)  # 18 > 16


# ---------------------------------------------------------------------------
# Quantized artifacts: save -> load round-trip
# ---------------------------------------------------------------------------


def test_linear_arrays_roundtrip(small_wh):
    W, H = small_wh
    qcfg = QuipConfig(bits=2, use_kernel=False)
    layer, _ = quantize_layer(W, H, qcfg, seed=11, collect_stats=False)
    arrays, meta = linear_to_arrays(layer)
    arrays = {k: np.asarray(v) for k, v in arrays.items()}  # simulate npz
    rebuilt = linear_from_arrays(arrays, meta)
    np.testing.assert_array_equal(np.asarray(rebuilt.packed), np.asarray(layer.packed))
    # transforms regenerate bit-identically from seeds
    np.testing.assert_array_equal(
        np.asarray(rebuilt.dequantize()), np.asarray(layer.dequantize())
    )
    x = make_weights(5, W.shape[1], seed=9)
    np.testing.assert_allclose(
        np.asarray(rebuilt(x)), np.asarray(layer(x)), rtol=0, atol=1e-6
    )


def test_artifact_save_load_identical_outputs(tmp_path, quantized_smoke):
    cfg, qm, qcfg = quantized_smoke
    save_quantized(tmp_path / "art", qm, qcfg, extra_meta={"stats": qm.stats})
    qm2, meta = load_quantized(tmp_path / "art")
    assert meta["quip_config"]["bits"] == 2
    assert qm2.cfg == cfg
    toks = make_calibration(cfg.vocab, n_segments=2, seg_len=16, seed=2).tokens
    np.testing.assert_allclose(
        np.asarray(qm2.logits(toks)), np.asarray(qm.logits(toks)),
        rtol=0, atol=1e-5,
    )
    # per-linear quant_matmul outputs are identical
    lin, lin2 = qm.blocks[0]["attn.wq"], qm2.blocks[0]["attn.wq"]
    x = make_weights(3, lin.n, seed=13)
    np.testing.assert_allclose(
        np.asarray(lin2(x)), np.asarray(lin(x)), rtol=0, atol=1e-6
    )


def test_artifact_rejects_non_artifact_dir(tmp_path):
    from repro.checkpoint import save_checkpoint

    save_checkpoint(tmp_path / "ckpt", 0, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_quantized(tmp_path / "ckpt")
