"""The fleet router: one stdlib-asyncio HTTP front in front of N
replica FrontDoors, with journal-backed in-flight failover
(DESIGN.md §15).

Routing is two-tier: sticky prefix affinity first (rendezvous hash of
the prompt header → the replica whose trie already holds those pages),
least-loaded fallback when the preferred replica is unavailable or
over pressure.  The router never touches an engine — it speaks the
replicas' own HTTP API and relays their SSE frames, so every admission
semantic (typed 429/413 rejections, Retry-After, drain 503s) passes
through unchanged.

Headline mechanism — **in-flight failover**: every relayed token is
journaled; when the upstream replica dies mid-stream (connection reset,
``kill -9``, wedge-kill) the router resubmits the ORIGINAL body plus
``resume_tokens`` to another healthy replica and splices the
continuation into the same client SSE stream.  The replacement engine
replays prompt+emitted (the same machinery eviction restore uses), so
the splice is token-identical by construction: greedy is argmax, and
device-side sampling keys on ``fold_in(seed, emission_index)`` — both
depend only on (weights, prompt, emitted-so-far), all of which the
journal reconstructs.  Host-side sampling (``--no-paged`` with
temperature) has no per-emission key and is outside the guarantee.
"""
from __future__ import annotations

import asyncio
import json
import signal
import threading
from typing import Optional

from repro.serve.fleet.affinity import prefix_key, rendezvous_rank
from repro.serve.fleet.journal import JournalEntry, RequestJournal
from repro.serve.fleet.supervisor import FleetReport, ReplicaHandle, Supervisor
from repro.serve.frontdoor.admission import parse_generate_body
from repro.serve.frontdoor.streaming import sse_event, sse_headers
from repro.serve.frontdoor.wire import (
    open_http,
    read_body,
    read_request,
    write_response,
)

__all__ = ["FleetRouter"]

_PASSTHROUGH_HEADERS = ("retry-after",)


async def _read_sse_frame(reader: asyncio.StreamReader, *,
                          timeout: float) -> Optional[tuple]:
    """Read one SSE frame off an upstream stream: ``(event, data)`` with
    data JSON-decoded, or None on EOF (including EOF mid-frame — a
    partial frame from a dying replica is dropped, never relayed; the
    journal makes the resume splice re-cover it)."""
    event, data = None, None
    while True:
        line = await asyncio.wait_for(reader.readline(), timeout)
        if not line:
            return None
        if not line.endswith(b"\n"):
            return None  # EOF mid-line: truncated frame
        if line in (b"\n", b"\r\n"):
            if event is not None and data is not None:
                return event, data
            continue  # stray blank line
        if line.startswith(b"event:"):
            event = line[len(b"event:"):].strip().decode()
        elif line.startswith(b"data:"):
            try:
                data = json.loads(line[len(b"data:"):].strip())
            except json.JSONDecodeError:
                return None  # truncated JSON: treat as dead upstream


class _UpstreamDead(Exception):
    """The current replica attempt failed mid-request (connection
    refused/reset, EOF before ``done``, stall past the idle budget) —
    the caller picks another replica and resumes."""


class FleetRouter:
    """Health-checked, affinity-sticky, failover-splicing HTTP router."""

    def __init__(self, supervisor: Supervisor, *,
                 host: str = "127.0.0.1", port: int = 0,
                 drain_timeout_s: float = 30.0,
                 max_failovers: int = 3,
                 over_pressure: float = 0.9,
                 affinity_header_len: int = 16,
                 connect_timeout_s: float = 5.0,
                 stream_idle_timeout_s: float = 60.0,
                 pick_wait_s: float = 2.0):
        self.sup = supervisor
        self.host = host
        self.port = port
        self.drain_timeout_s = drain_timeout_s
        self.max_failovers = max_failovers
        self.over_pressure = over_pressure
        self.affinity_header_len = affinity_header_len
        self.connect_timeout_s = connect_timeout_s
        self.stream_idle_timeout_s = stream_idle_timeout_s
        self.pick_wait_s = pick_wait_s
        self.journal = RequestJournal()
        self.counters = {
            "http_requests": 0, "routed": 0, "affinity_hits": 0,
            "affinity_fallbacks": 0, "failovers": 0,
            "failover_exhausted": 0, "rejections_passed": 0,
            "unavailable_503": 0, "aborted_streams": 0,
            "client_disconnects": 0,
        }
        self._draining = False
        self._drain_reason = "requested"
        self._drain_event: Optional[asyncio.Event] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._conn_tasks: set = set()
        self._started = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._thread_error: Optional[BaseException] = None
        self.report: Optional[FleetReport] = None

    # ---- lifecycle -------------------------------------------------------

    def request_drain(self, reason: str = "requested") -> None:
        """Begin fleet-wide graceful drain (idempotent): the router
        503s new work immediately, in-flight streams get
        ``drain_timeout_s`` to finish, then the supervisor coordinates
        per-replica drains and aggregates their leak gates."""
        if self._draining:
            return
        self._draining = True
        self._drain_reason = reason
        if self._drain_event is not None:
            self._drain_event.set()

    async def serve_forever(self, *, install_signals: bool = True,
                            start_fleet: bool = True) -> FleetReport:
        """Boot the fleet (unless the caller already did), serve until a
        drain completes, return the aggregated :class:`FleetReport`."""
        self._loop = asyncio.get_running_loop()
        self._drain_event = asyncio.Event()
        if self._draining:  # drain requested before boot
            self._drain_event.set()
        if start_fleet:
            await self.sup.start()
        server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = server.sockets[0].getsockname()[1]
        print(f"[router] listening on {self.host}:{self.port} "
              f"({len(self.sup.handles)} replicas)", flush=True)
        if install_signals:
            for sig, why in ((signal.SIGTERM, "sigterm"),
                             (signal.SIGINT, "sigint")):
                try:
                    self._loop.add_signal_handler(
                        sig, self.request_drain, why)
                except NotImplementedError:  # pragma: no cover - win32
                    pass
        probe_task = self._loop.create_task(self.sup.probe_loop())
        self._started.set()
        await self._drain_event.wait()
        t0 = self._loop.time()
        # stop admitting (already flipped), let live streams finish
        deadline = t0 + self.drain_timeout_s
        while self._conn_tasks and self._loop.time() < deadline:
            await asyncio.sleep(0.02)
        if self._conn_tasks:  # deadline: abort the stragglers
            self.counters["aborted_streams"] += len(self._conn_tasks)
            for task in list(self._conn_tasks):
                task.cancel()
            await asyncio.gather(*self._conn_tasks,
                                 return_exceptions=True)
        probe_task.cancel()
        try:
            await probe_task
        except asyncio.CancelledError:
            pass
        server.close()
        await server.wait_closed()
        await self.sup.drain()
        self.report = FleetReport(
            reason=self._drain_reason,
            duration_s=self._loop.time() - t0,
            routed=self.counters["routed"],
            completed=self.journal.completed,
            failed=self.journal.failed,
            failovers=self.counters["failovers"],
            aborted_streams=self.counters["aborted_streams"],
            replicas=[h.to_dict() for h in self.sup.handles],
        )
        for line in self.report.lines():
            print(f"[router] {line}", flush=True)
        return self.report

    # ---- thread hosting (tests / in-process clients) --------------------

    def start_in_thread(self) -> "FleetRouter":
        """Run the router loop on a daemon thread; returns once the
        socket is bound (``self.port`` is then real)."""
        self._thread = threading.Thread(
            target=self._thread_main, name="fleet-router", daemon=True)
        self._thread.start()
        if not self._started.wait(120):
            raise RuntimeError("fleet router failed to start")
        if self._thread_error is not None:
            raise self._thread_error
        return self

    def _thread_main(self) -> None:
        try:
            asyncio.run(self.serve_forever(install_signals=False))
        except BaseException as e:  # surfaced by drain_and_join
            self._thread_error = e
        finally:
            self._started.set()

    def drain_and_join(self, reason: str = "requested",
                       timeout: float = 120.0) -> FleetReport:
        """Threadsafe drain + join for a thread-hosted router."""
        self._loop.call_soon_threadsafe(self.request_drain, reason)
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise RuntimeError("fleet router did not drain in time")
        if self._thread_error is not None:
            raise self._thread_error
        return self.report

    # ---- HTTP ------------------------------------------------------------

    async def _handle_conn(self, reader, writer) -> None:
        task = asyncio.current_task()
        self._conn_tasks.add(task)
        try:
            parsed = await asyncio.wait_for(read_request(reader), 30.0)
            if parsed is None:
                return
            method, path, _headers, body = parsed
            await self._route(writer, method, path, body)
        except asyncio.CancelledError:
            # drain deadline: the client's stream is being aborted
            raise
        except (asyncio.TimeoutError, ConnectionError, OSError):
            pass
        except Exception as e:  # noqa: BLE001 - last-resort 500
            try:
                write_response(writer, 500, json.dumps(
                    {"error": "internal", "detail": str(e)}).encode())
            except Exception:
                pass
        finally:
            self._conn_tasks.discard(task)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _route(self, writer, method, path, body) -> None:
        path = path.split("?", 1)[0]
        if path == "/healthz" and method == "GET":
            write_response(writer, 200, json.dumps({
                "status": "ok",
                "replicas": {
                    h.state: sum(1 for x in self.sup.handles
                                 if x.state == h.state)
                    for h in self.sup.handles},
                "draining": self._draining,
            }).encode())
        elif path == "/readyz" and method == "GET":
            n_avail = sum(1 for h in self.sup.handles if h.available)
            ready = n_avail > 0 and not self._draining
            write_response(writer, 200 if ready else 503, json.dumps({
                "ready": ready, "available_replicas": n_avail,
                "draining": self._draining,
            }).encode())
        elif path == "/fleetz" and method == "GET":
            write_response(writer, 200, json.dumps({
                "replicas": [h.to_dict() for h in self.sup.handles],
                "router": dict(self.counters),
                "journal": {
                    "live": len(self.journal),
                    "opened": self.journal.opened,
                    "completed": self.journal.completed,
                    "failed": self.journal.failed,
                    "failovers": self.journal.failovers,
                },
            }).encode())
        elif path == "/metricsz" and method == "GET":
            write_response(writer, 200, json.dumps({
                "router": dict(self.counters),
                "replicas": [h.to_dict() for h in self.sup.handles],
            }).encode())
        elif path == "/v1/generate" and method == "POST":
            await self._handle_generate(writer, body)
        elif path in ("/healthz", "/readyz", "/metricsz", "/fleetz",
                      "/v1/generate"):
            write_response(writer, 405, json.dumps(
                {"error": "method_not_allowed"}).encode())
        else:
            write_response(writer, 404, json.dumps(
                {"error": "not_found"}).encode())
        await writer.drain()

    # ---- routing ---------------------------------------------------------

    def _pick(self, key: int,
              exclude: set) -> Optional[ReplicaHandle]:
        """Choose a replica for an affinity key: the rendezvous-preferred
        slot when it is healthy and under pressure, else the least-loaded
        available slot (ties broken by rendezvous rank, so fallback is
        deterministic too)."""
        handles = self.sup.handles
        ranked = rendezvous_rank(key, len(handles))
        avail = [h for h in handles
                 if h.available and h.index not in exclude]
        if not avail:
            return None
        preferred = handles[ranked[0]]
        if (preferred.available and preferred.index not in exclude
                and preferred.pressure < self.over_pressure):
            self.counters["affinity_hits"] += 1
            return preferred
        rank_pos = {idx: pos for pos, idx in enumerate(ranked)}
        self.counters["affinity_fallbacks"] += 1
        return min(avail, key=lambda h: (h.inflight, rank_pos[h.index]))

    async def _await_replica(self, key: int,
                             tried: set) -> Optional[ReplicaHandle]:
        loop = asyncio.get_running_loop()
        deadline = loop.time() + self.pick_wait_s
        while loop.time() < deadline and not self._draining:
            await asyncio.sleep(0.05)
            handle = self._pick(key, tried)
            if handle is not None:
                return handle
        return None

    # ---- generate proxy --------------------------------------------------

    async def _handle_generate(self, writer, raw: bytes) -> None:
        self.counters["http_requests"] += 1
        if self._draining:
            write_response(
                writer, 503,
                json.dumps({"error": "draining",
                            "retryable": True}).encode(),
                extra_headers=[("Retry-After", "1")])
            return
        # validate locally with the replicas' own parser: garbage fails
        # here with the identical 400 body a replica would produce, and
        # a valid body gives us the prompt (affinity) and stream flag
        try:
            p = parse_generate_body(raw)
            body = json.loads(raw.decode("utf-8"))
        except ValueError as e:
            write_response(writer, 400, json.dumps(
                {"error": "bad_request", "retryable": False,
                 "detail": str(e)}).encode())
            return
        key = prefix_key(p.prompt, self.affinity_header_len)
        entry = self.journal.open(body, p.stream)
        self.counters["routed"] += 1
        try:
            await self._proxy(writer, entry, key)
        finally:
            # safety net for exception paths (cancel at drain deadline,
            # internal errors): anything still journaled failed
            if entry.jid in self.journal._entries:
                self.journal.close(entry, finish_reason=None)

    async def _proxy(self, writer, entry: JournalEntry,
                     key: int) -> None:
        """Run one journaled request to completion across however many
        replica attempts it takes (bounded by ``max_failovers``)."""
        tried: set = set()  # replicas failed since last token progress
        while True:
            handle = self._pick(key, tried)
            if handle is None:
                # transient gap (a suspect awaiting its next probe, a
                # restart in flight): wait briefly before giving up —
                # aborting a live stream over a 100ms health blip would
                # be the worst possible trade
                handle = await self._await_replica(key, tried)
            if handle is None:
                self._no_replica(writer, entry)
                return
            entry.assign(handle.index)
            handle.routed += 1
            handle.inflight += 1
            mark = len(entry.tokens)
            try:
                done = await self._attempt(writer, entry, handle)
            except _UpstreamDead as e:
                # the replica failed us mid-request: flag it for the
                # supervisor (the probe loop confirms and restarts) and
                # fail over — unless the budget is spent
                if len(entry.tokens) > mark:
                    # progress was made: forget earlier failures so a
                    # since-restarted replica is eligible again
                    tried = {handle.index}
                else:
                    tried.add(handle.index)
                if handle.state == "healthy":
                    handle.state = "suspect"
                    handle.last_err = f"router: {e}"
                if entry.n_failovers >= self.max_failovers:
                    self.counters["failover_exhausted"] += 1
                    self._no_replica(writer, entry)
                    return
                self.counters["failovers"] += 1
                self.journal.note_failover(entry)
                continue
            finally:
                handle.inflight -= 1
            if done:
                handle.served += 1
            return

    def _no_replica(self, writer, entry: JournalEntry) -> None:
        """No replica can take (or continue) this request.  Before any
        bytes went out: a typed retryable 503.  Mid-stream: the only
        honest move is to abort the transport — a fabricated ``done``
        would masquerade as a completed generation."""
        self.counters["unavailable_503"] += 1
        self.journal.close(entry, finish_reason=None)
        if entry.head_sent:
            self.counters["aborted_streams"] += 1
            writer.transport.abort()
            return
        write_response(
            writer, 503,
            json.dumps({"error": "replica_unavailable",
                        "retryable": True}).encode(),
            extra_headers=[("Retry-After", "1")])

    async def _attempt(self, writer, entry: JournalEntry,
                       handle: ReplicaHandle) -> bool:
        """One upstream attempt.  Returns True when the request finished
        (done relayed / rejection passed through), False when the client
        vanished; raises :class:`_UpstreamDead` to request failover."""
        body = json.dumps(
            entry.resume_body() if entry.tokens else entry.body
        ).encode()
        try:
            status, headers, up_reader, up_writer = await open_http(
                handle.host, handle.port, "POST", "/v1/generate",
                body=body, timeout=self.connect_timeout_s)
        except (ConnectionError, OSError, asyncio.TimeoutError) as e:
            raise _UpstreamDead(f"connect failed: {e!r}") from None
        try:
            if status != 200:
                return await self._relay_error(
                    writer, entry, handle, status, headers, up_reader)
            if not entry.stream:
                return await self._relay_buffered(
                    writer, entry, headers, up_reader)
            return await self._relay_sse(writer, entry, up_reader)
        finally:
            try:
                up_writer.close()
                await up_writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _relay_error(self, writer, entry, handle, status,
                           headers, up_reader) -> bool:
        """Non-200 from a replica.  Drain 503s mean THAT replica is
        unavailable — retryable elsewhere, so fail over transparently.
        Everything else (429/413/400/500) is a verdict about the
        REQUEST: pass status, body, and Retry-After through unchanged
        (satellite: typed rejections survive the router)."""
        raw = await read_body(up_reader, headers,
                              timeout=self.connect_timeout_s)
        if status == 503:
            raise _UpstreamDead(f"replica {handle.index} unavailable "
                                f"(503)")
        if entry.head_sent:
            # a resumed request bounced (e.g. rejected at admission on
            # the new replica): the stream cannot continue honestly
            raise _UpstreamDead(
                f"resume rejected with {status} by replica "
                f"{handle.index}")
        self.counters["rejections_passed"] += 1
        extra = [(k.title(), v) for k, v in headers.items()
                 if k in _PASSTHROUGH_HEADERS]
        write_response(writer, status, raw, extra_headers=extra)
        self.journal.close(entry, finish_reason=f"rejected_{status}")
        return True

    async def _relay_buffered(self, writer, entry, headers,
                              up_reader) -> bool:
        """Buffered (non-stream) relay: nothing reaches the client until
        the full body is in hand, so replica death here is a clean full
        retry — no splice needed."""
        try:
            raw = await read_body(up_reader, headers,
                                  timeout=self.stream_idle_timeout_s)
            payload = json.loads(raw.decode("utf-8"))
        except (ConnectionError, OSError, asyncio.TimeoutError,
                asyncio.IncompleteReadError, UnicodeDecodeError,
                json.JSONDecodeError) as e:
            raise _UpstreamDead(f"buffered relay failed: {e!r}") \
                from None
        write_response(writer, 200, raw)
        await writer.drain()
        self.journal.close(
            entry, finish_reason=payload.get("finish_reason", "done"))
        return True

    async def _relay_sse(self, writer, entry: JournalEntry,
                         up_reader) -> bool:
        """Stream relay: forward token frames (journaling each), finish
        on the ``done`` frame.  EOF or stall before ``done`` raises for
        failover.  Frames are re-serialized (not byte-forwarded) so a
        torn frame from a dying replica can never reach the client."""
        if not entry.head_sent:
            head = ["HTTP/1.1 200 OK",
                    *(f"{k}: {v}" for k, v in sse_headers()),
                    "Connection: close"]
            writer.write(("\r\n".join(head) + "\r\n\r\n").encode())
            await writer.drain()
            entry.head_sent = True
        while True:
            try:
                frame = await _read_sse_frame(
                    up_reader, timeout=self.stream_idle_timeout_s)
            except (ConnectionError, OSError, asyncio.TimeoutError) as e:
                raise _UpstreamDead(f"stream broke: {e!r}") from None
            if frame is None:
                raise _UpstreamDead("EOF before done frame")
            event, data = frame
            if event == "token":
                # the frame is fully parsed before either side-effect,
                # so journal and relay can't diverge on upstream death;
                # a client-write failure abandons the request entirely
                entry.record(int(data["i"]), int(data["token"]))
                try:
                    writer.write(sse_event("token", data))
                    await writer.drain()
                except (ConnectionError, OSError):
                    self.counters["client_disconnects"] += 1
                    self.journal.close(entry, finish_reason=None)
                    return False
            elif event == "done":
                if (data.get("finish_reason") == "cancelled"
                        and not self._draining):
                    # the REPLICA gave up (its own drain/cancel_all),
                    # not the request: resume on a survivor.  During a
                    # coordinated fleet drain the cancel is honest and
                    # passes through.
                    raise _UpstreamDead("replica cancelled mid-stream")
                try:
                    writer.write(sse_event("done", data))
                    await writer.drain()
                except (ConnectionError, OSError):
                    self.counters["client_disconnects"] += 1
                    self.journal.close(entry, finish_reason=None)
                    return False
                self.journal.close(
                    entry,
                    finish_reason=data.get("finish_reason", "done"))
                return True
            # unknown events: relay-transparent no-op
