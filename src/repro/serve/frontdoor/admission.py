"""Front-door admission: request validation, tenant-spec parsing, and
the typed :class:`AdmissionRejected` → HTTP mapping.

The router never invents status codes: :attr:`AdmissionRejected.
http_status` owns the mapping (413 for the non-retryable
``over_capacity``, 429 for everything retryable — ``queue_full``,
``rate_limited``, ``shed``) and :meth:`AdmissionRejected.to_dict` owns
the body, so CLI errors and HTTP bodies carry the same actionable
detail (retryable flag, needed/available pages, retry-after hint).
"""
from __future__ import annotations

import json
import math
from typing import Optional

import numpy as np

from repro.serve.faults import AdmissionRejected
from repro.serve.scheduler import SamplingParams, TenantPolicy

__all__ = [
    "GenerateParams",
    "parse_generate_body",
    "parse_tenants",
    "rejection_response",
]

MAX_PROMPT_TOKENS = 1 << 20  # sanity bound on request size, not capacity


def parse_tenants(spec: str) -> dict:
    """Parse the ``--tenants`` flag: comma-separated
    ``name:rate:burst:priority`` entries, later fields optional.

    ``rate`` is requests/second for the tenant's token bucket (empty or
    ``inf`` = unlimited), ``burst`` the bucket depth (default 4), and
    ``priority`` the default class (0 = highest; default 0).  Example::

        paid:inf:4:0,free:2.0:4:1,batch:0.5:2:2
    """
    tenants: dict[str, TenantPolicy] = {}
    for entry in filter(None, (e.strip() for e in spec.split(","))):
        parts = entry.split(":")
        if not parts[0]:
            raise ValueError(f"tenant entry missing a name: {entry!r}")
        if len(parts) > 4:
            raise ValueError(
                f"tenant entry {entry!r}: expected name:rate:burst:priority"
            )
        name = parts[0]
        rate: Optional[float] = None
        if len(parts) > 1 and parts[1] and parts[1] != "inf":
            rate = float(parts[1])
        burst = int(parts[2]) if len(parts) > 2 and parts[2] else 4
        priority = int(parts[3]) if len(parts) > 3 and parts[3] else 0
        if name in tenants:
            raise ValueError(f"duplicate tenant {name!r}")
        tenants[name] = TenantPolicy(rate=rate, burst=burst,
                                     priority=priority)
    if not tenants:
        raise ValueError(f"no tenants in spec {spec!r}")
    return tenants


class GenerateParams:
    """Validated POST /v1/generate body (raises ValueError with a
    client-actionable message on anything malformed)."""

    __slots__ = ("prompt", "max_new", "tenant", "priority", "stream",
                 "sampling", "stop_tokens", "deadline_s", "resume_tokens")

    def __init__(self, prompt, max_new, tenant, priority, stream,
                 sampling, stop_tokens, deadline_s, resume_tokens=()):
        self.prompt = prompt
        self.max_new = max_new
        self.tenant = tenant
        self.priority = priority
        self.stream = stream
        self.sampling = sampling
        self.stop_tokens = stop_tokens
        self.deadline_s = deadline_s
        self.resume_tokens = resume_tokens


def _int_list(v, field: str) -> list:
    if (not isinstance(v, list) or
            not all(isinstance(t, int) and not isinstance(t, bool)
                    for t in v)):
        raise ValueError(f"{field!r} must be a list of integer token ids")
    return v


def parse_generate_body(raw: bytes) -> GenerateParams:
    """Parse and validate a generate request body.

    Schema: ``{"prompt": [int, ...], "max_new": int, "tenant"?: str,
    "priority"?: int, "stream"?: bool, "temperature"?: float,
    "top_p"?: float, "seed"?: int, "stop_tokens"?: [int, ...],
    "deadline_s"?: float, "resume_tokens"?: [int, ...]}``.

    ``resume_tokens`` is the fleet router's failover field (DESIGN.md
    §15): tokens a previous attempt already emitted.  The engine
    replays them (prefill covers prompt + resume) and the SSE stream
    continues at token index ``len(resume_tokens)`` — it never re-sends
    the resumed prefix.  ``max_new`` keeps its original total-budget
    meaning, so a resubmitted body differs from the original only by
    this one field.
    """
    try:
        body = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(f"body is not valid JSON: {e}") from None
    if not isinstance(body, dict):
        raise ValueError("body must be a JSON object")
    known = {"prompt", "max_new", "tenant", "priority", "stream",
             "temperature", "top_p", "seed", "stop_tokens", "deadline_s",
             "resume_tokens"}
    unknown = set(body) - known
    if unknown:
        raise ValueError(f"unknown fields: {sorted(unknown)}")
    if "prompt" not in body or "max_new" not in body:
        raise ValueError("'prompt' and 'max_new' are required")
    prompt = _int_list(body["prompt"], "prompt")
    if not 0 < len(prompt) <= MAX_PROMPT_TOKENS:
        raise ValueError(
            f"prompt must have 1..{MAX_PROMPT_TOKENS} tokens, "
            f"got {len(prompt)}"
        )
    max_new = body["max_new"]
    if not isinstance(max_new, int) or isinstance(max_new, bool) \
            or max_new < 1:
        raise ValueError("'max_new' must be a positive integer")
    tenant = body.get("tenant", "default")
    if not isinstance(tenant, str) or not tenant:
        raise ValueError("'tenant' must be a non-empty string")
    priority = body.get("priority")
    if priority is not None and (not isinstance(priority, int)
                                 or isinstance(priority, bool)
                                 or priority < 0):
        raise ValueError("'priority' must be an integer >= 0")
    stream = body.get("stream", True)
    if not isinstance(stream, bool):
        raise ValueError("'stream' must be a boolean")
    try:
        sampling = SamplingParams(
            temperature=float(body.get("temperature", 0.0)),
            top_p=float(body.get("top_p", 1.0)),
            seed=int(body.get("seed", 0)),
        )
    except (TypeError, ValueError) as e:
        raise ValueError(f"bad sampling params: {e}") from None
    stop_tokens = tuple(_int_list(body.get("stop_tokens", []),
                                  "stop_tokens"))
    deadline_s = body.get("deadline_s")
    if deadline_s is not None:
        deadline_s = float(deadline_s)
        if deadline_s <= 0:
            raise ValueError("'deadline_s' must be > 0")
    resume_tokens = tuple(_int_list(body.get("resume_tokens", []),
                                    "resume_tokens"))
    if len(resume_tokens) >= max_new:
        raise ValueError(
            f"'resume_tokens' ({len(resume_tokens)}) must leave room "
            f"under 'max_new' ({max_new})")
    return GenerateParams(
        prompt=np.asarray(prompt, np.int32), max_new=max_new,
        tenant=tenant, priority=priority, stream=stream,
        sampling=sampling, stop_tokens=stop_tokens, deadline_s=deadline_s,
        resume_tokens=resume_tokens,
    )


def rejection_response(exc: AdmissionRejected) -> tuple:
    """(status, extra_headers, body_bytes) for a typed admission
    rejection.  Retryable rejections carry ``Retry-After`` — the
    bucket's own hint when it has one, else 1 second."""
    headers = []
    if exc.retryable:
        after = exc.retry_after_s if exc.retry_after_s is not None else 1.0
        headers.append(("Retry-After", str(max(1, math.ceil(after)))))
    body = json.dumps(exc.to_dict()).encode()
    return exc.http_status, headers, body
