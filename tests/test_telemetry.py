"""Telemetry tests (serve/telemetry.py + engine wiring).

Two claims matter and both are tested here:

  * observing the engine never changes it — token streams with a tracer
    attached are identical to tracer-off runs (fp, quantized and
    speculative paths; the TP variant lives in test_distributed.py) and
    the disabled default (NULL_TRACER) costs at most a method call;
  * what it reports is honest — ring wraparound keeps the newest spans,
    exported traces pass the Chrome/Perfetto schema gate, step phases
    cover (nearly) all of step time, and the engine's own latency
    percentiles equal an external recomputation from raw timestamps.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.quantizer import QuipConfig
from repro.data import make_calibration
from repro.models import build_model
from repro.serve import CachedDecoder, Engine, EngineConfig
from repro.serve.telemetry import (
    NULL_TRACER,
    Histogram,
    MetricsRegistry,
    Span,
    Tracer,
    format_metrics_line,
    phase_breakdown,
    validate_chrome_trace,
)


# ---------------------------------------------------------------------------
# tracer unit tests (no model)
# ---------------------------------------------------------------------------


class _FakeClock:
    """Deterministic monotonic clock: one tick per call."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def test_ring_buffer_wraparound():
    tr = Tracer(capacity=4, clock=_FakeClock())
    for i in range(7):
        tr.event(f"e{i}")
    assert len(tr) == 4
    assert tr.dropped == 3
    got = [s.name for s in tr.spans]
    assert got == ["e3", "e4", "e5", "e6"]  # newest survive, oldest first
    t0s = [s.t0 for s in tr.spans]
    assert t0s == sorted(t0s)
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0 and tr.spans == []


def test_span_nesting_depth_and_attrs():
    tr = Tracer(clock=_FakeClock())
    with tr.span("step"):
        with tr.span("prefill", lanes=3):
            with tr.span("dispatch:prefill_paged"):
                pass
    by_name = {s.name: s for s in tr.spans}
    assert by_name["step"].depth == 0
    assert by_name["prefill"].depth == 1
    assert by_name["dispatch:prefill_paged"].depth == 2
    assert by_name["prefill"].attrs == {"lanes": 3}
    # spans record on exit: children land in the ring before parents
    assert [s.name for s in tr.spans] == [
        "dispatch:prefill_paged", "prefill", "step",
    ]
    for s in tr.spans:
        assert s.t1 > s.t0 and not s.instant


def test_sync_tracer_calls_barrier_at_both_edges():
    calls = []
    tr = Tracer(sync=True, sync_fn=lambda: calls.append(1),
                clock=_FakeClock())
    with tr.span("step"):
        pass
    assert len(calls) == 2  # entry + exit barrier
    # sync=True with no barrier wired is a silent no-op, not an error
    tr2 = Tracer(sync=True, clock=_FakeClock())
    with tr2.span("step"):
        pass
    assert len(tr2) == 1


def test_chrome_export_schema_and_tags(tmp_path):
    tr = Tracer(clock=_FakeClock(), tags={"mesh_model": 2})
    with tr.span("step"):
        with tr.span("decode", lanes=2):
            tr.event("first_token", rid=0)
    path = tmp_path / "trace.json"
    tr.export_chrome_trace(path)
    obj = json.load(open(path))  # round-trip through real JSON
    assert validate_chrome_trace(obj) == 3
    events = {e["name"]: e for e in obj["traceEvents"]}
    assert events["thread_name"]["ph"] == "M"
    assert events["step"]["ph"] == "X" and events["step"]["dur"] > 0
    inst = events["first_token"]
    assert inst["ph"] == "i" and inst["s"] == "t" and "dur" not in inst
    # tracer tags land on every event, merged with span attrs
    assert events["decode"]["args"] == {"mesh_model": 2, "lanes": 2}
    assert inst["args"] == {"mesh_model": 2, "rid": 0}
    assert obj["otherData"]["dropped_spans"] == 0


def test_validate_chrome_trace_rejects_malformed():
    ok = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0.0, "dur": 1.0,
                           "pid": 0, "tid": 0}]}
    assert validate_chrome_trace(ok) == 1
    bad = [
        [],  # not an object
        {},  # no traceEvents
        {"traceEvents": [{"name": "a", "ph": "Z", "ts": 0, "pid": 0,
                          "tid": 0}]},  # unknown phase
        {"traceEvents": [{"name": "", "ph": "X", "ts": 0, "dur": 1,
                          "pid": 0, "tid": 0}]},  # empty name
        {"traceEvents": [{"name": "a", "ph": "X", "ts": -1, "dur": 1,
                          "pid": 0, "tid": 0}]},  # negative ts
        {"traceEvents": [{"name": "a", "ph": "X", "ts": 0, "pid": 0,
                          "tid": 0}]},  # complete event without dur
        {"traceEvents": [{"name": "a", "ph": "i", "ts": 0, "dur": 1,
                          "pid": 0, "tid": 0}]},  # instant carrying dur
        {"traceEvents": [{"name": "m", "ph": "M", "pid": 0, "tid": 0}]},
        # metadata only -> no events
    ]
    for obj in bad:
        with pytest.raises(ValueError):
            validate_chrome_trace(obj)


def test_phase_breakdown_math():
    spans = [
        Span("step", 0.0, 10.0, 0),
        Span("prefill", 0.0, 4.0, 1),
        Span("decode", 4.0, 9.0, 1),
        Span("dispatch:decode_paged", 4.0, 8.0, 2),  # nested: not a phase
        Span("first_token", 5.0, 5.0, 1, instant=True),  # mark: excluded
    ]
    pb = phase_breakdown(spans)
    assert pb["root_s"] == 10.0 and pb["root_count"] == 1
    assert set(pb["phases"]) == {"prefill", "decode"}
    assert pb["phases"]["prefill"]["share"] == pytest.approx(0.4)
    assert pb["coverage"] == pytest.approx(0.9)
    assert phase_breakdown([])["coverage"] == 0.0


def test_null_tracer_records_nothing_and_is_cheap():
    h = NULL_TRACER.span("step", lanes=4)
    assert h is NULL_TRACER.span("decode")  # one shared no-op handle
    NULL_TRACER.event("first_token", rid=1)
    assert len(NULL_TRACER) == 0 and NULL_TRACER.spans == []
    assert not NULL_TRACER.enabled
    # overhead guardrail: a disabled span site must stay within a few µs
    # per hit (one method call + a no-op context manager)
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with NULL_TRACER.span("step"):
            pass
    per_hit = (time.perf_counter() - t0) / n
    assert per_hit < 5e-6, f"disabled span site costs {per_hit * 1e6:.2f}µs"


# ---------------------------------------------------------------------------
# metrics unit tests
# ---------------------------------------------------------------------------


def test_histogram_percentiles_match_numpy_and_empty_is_none():
    h = Histogram("ttft_s")
    assert h.percentile(50) is None and h.summary()["mean"] is None
    xs = [0.5, 0.1, 0.9, 0.3, 0.7]
    for x in xs:
        h.observe(x)
    assert h.count == 5 and h.sum == pytest.approx(2.5)
    for q in (50, 99):
        assert h.percentile(q) == float(np.percentile(np.asarray(xs), q))
    s = h.summary()
    assert s["count"] == 5 and s["p50"] == 0.5
    # None (not NaN) keeps the serialized record strict-JSON-parseable
    assert "null" in json.dumps(Histogram("itl_s").summary())


def test_metrics_registry_snapshot_and_reset():
    reg = MetricsRegistry()
    reg.inc("steps")
    reg.inc("decode_tokens", 5)
    reg.counter("prefill_batch_size").peak(3)
    reg.counter("prefill_batch_size").peak(2)  # high-water mark keeps 3
    reg.gauge("occupancy").set(0.5)
    live = {"v": 7}
    reg.gauge("pages_in_use", fn=lambda: live["v"])
    reg.histogram("ttft_s").observe(0.25)
    s = reg.snapshot()
    assert s["steps"] == 1 and s["decode_tokens"] == 5
    assert s["prefill_batch_size"] == 3
    assert s["occupancy"] == 0.5 and s["pages_in_use"] == 7
    assert s["ttft_s_count"] == 1 and s["ttft_s_p50"] == 0.25
    assert reg.counter("steps") is reg.counter("steps")  # idempotent
    reg.reset()
    live["v"] = 9
    s = reg.snapshot()
    assert s["steps"] == 0 and s["occupancy"] == 0
    assert s["pages_in_use"] == 9  # callback gauges track live state
    assert s["ttft_s_count"] == 0 and s["ttft_s_p50"] is None


def test_format_metrics_line_skips_empty_histograms():
    line = format_metrics_line(
        {"steps": 3, "occupancy": 0.25, "itl_s_p50": None},
        t=1.5, keys=["steps", "occupancy", "itl_s_p50", "missing"],
    )
    assert line == "[metrics t=1.5s] steps=3 occupancy=0.25"


# ---------------------------------------------------------------------------
# engine integration: tracing never changes tokens, and reports honestly
# ---------------------------------------------------------------------------


def _smoke_cfg():
    return get_smoke_config("qwen3-14b")


def _run(adapter, prompts, gen, *, tracer=None, **ecfg_kw):
    kw = dict(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8,
    )
    kw.update(ecfg_kw)
    engine = Engine(adapter, EngineConfig(**kw), tracer=tracer)
    reqs = [
        engine.submit(np.asarray(p), max_new=gen, arrival=0.01 * i)
        for i, p in enumerate(prompts)
    ]
    engine.run()
    return engine, reqs


@pytest.fixture(scope="module")
def fp_model():
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _parity(adapter_fn, prompts, gen, **ecfg_kw):
    """Token streams must be identical with and without a sync tracer."""
    _, base = _run(adapter_fn(), prompts, gen, **ecfg_kw)
    tr = Tracer(sync=True)
    engine, traced = _run(adapter_fn(), prompts, gen, tracer=tr, **ecfg_kw)
    for a, b in zip(base, traced):
        np.testing.assert_array_equal(
            np.asarray(a.out_tokens), np.asarray(b.out_tokens)
        )
    return engine, tr


def test_tracer_parity_fp_paged(fp_model):
    cfg, model, params = fp_model
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=3).tokens
    engine, tr = _parity(
        lambda: CachedDecoder.from_model(model, params), prompts, 5,
        paged_decode=True, paged_prefill=True,
    )
    names = {s.name for s in tr.spans}
    assert {"step", "schedule", "prefill", "decode",
            "dispatch:prefill_paged", "dispatch:decode_paged"} <= names


def test_tracer_parity_speculative(fp_model):
    cfg, model, params = fp_model
    # repetitive prompts so the ngram drafter actually proposes
    rng = np.random.default_rng(5)
    base = rng.integers(1, cfg.vocab, size=(3, 6)).astype(np.int32)
    prompts = np.concatenate([base, base], axis=1)
    engine, tr = _parity(
        lambda: CachedDecoder.from_model(model, params), prompts, 6,
        paged_decode=True, speculative_k=2, device_sample=True,
    )
    names = {s.name for s in tr.spans}
    assert {"verify", "draft", "dispatch:verify_paged"} <= names


@pytest.fixture(scope="module")
def quantized_smoke():
    from repro.launch.quantize import quantize_dense_model

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = make_calibration(cfg.vocab, n_segments=4, seg_len=32, seed=7)
    qcfg = QuipConfig(bits=2, method="ldlq", use_kernel=False)
    qm = quantize_dense_model(params, cfg, qcfg, calib.tokens, seed=0,
                              verbose=False)
    return cfg, qm


def test_tracer_parity_quantized(quantized_smoke):
    cfg, qm = quantized_smoke
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=5).tokens
    _parity(
        lambda: CachedDecoder.from_quantized(qm), prompts, 4,
        paged_decode=True,
    )


def test_engine_trace_coverage_lifecycle_and_schema(fp_model, tmp_path):
    cfg, model, params = fp_model
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10,
                               seed=4).tokens
    tr = Tracer(sync=True)
    engine, reqs = _run(
        CachedDecoder.from_model(model, params), prompts, 5, tracer=tr,
        paged_decode=True, paged_prefill=True,
    )
    # acceptance gate: phase spans account for >= 95% of step time
    pb = phase_breakdown(tr.spans)
    assert pb["root_count"] == engine.stats["steps"]
    assert pb["coverage"] >= 0.95
    # every request leaves a full lifecycle trail
    events = [s for s in tr.spans if s.instant]
    for kind in ("request_admitted", "first_token", "request_finished"):
        rids = {s.attrs["rid"] for s in events if s.name == kind}
        assert rids == {r.rid for r in reqs}, kind
    # exported JSON passes the same schema gate CI runs
    path = tmp_path / "engine_trace.json"
    tr.export_chrome_trace(path)
    assert validate_chrome_trace(json.load(open(path))) == len(tr)
    # span timestamps share the request-arrival epoch (Engine.now)
    admits = [s for s in events if s.name == "request_admitted"]
    assert all(s.t0 >= 0 for s in admits)
    assert all(s.attrs["queue_s"] >= 0 for s in admits)


def test_engine_native_percentiles_match_external(fp_model):
    cfg, model, params = fp_model
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=10,
                               seed=6).tokens
    engine, reqs = _run(
        CachedDecoder.from_model(model, params), prompts, 5,
        paged_decode=True,
    )
    s = engine.summary()
    done = [r for r in reqs if r.t_first is not None]
    ttft = [r.t_first - r.arrival for r in done]
    itl = [b - a for r in done
           for a, b in zip(r.token_times, r.token_times[1:])]
    e2e = [r.t_finish - r.arrival for r in done]
    for name, ext in (("ttft_s", ttft), ("itl_s", itl), ("e2e_s", e2e)):
        assert s[f"{name}_count"] == len(ext)
        for q in (50, 99):
            want = float(np.percentile(np.asarray(ext), q))
            assert s[f"{name}_p{q}"] == pytest.approx(want, abs=1e-12), name
    # summary() must serialize: empty histograms are null, never NaN
    json.dumps(s)


def test_engine_stats_property_and_clock(fp_model):
    cfg, model, params = fp_model
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=8,
                               seed=7).tokens
    engine, reqs = _run(
        CachedDecoder.from_model(model, params), prompts, 3,
        paged_decode=True,
    )
    # legacy dict view over the registry counters
    stats = engine.stats
    assert stats["steps"] > 0
    assert stats["decode_tokens"] + stats["prefill_tokens"] > 0
    # the clock starts at construction (no first-call skew): every
    # recorded timestamp is strictly positive engine-relative seconds
    assert all(t > 0 for r in reqs for t in r.token_times)
    before = engine.now()
    engine.reset_clock()
    assert engine.now() < before
    engine.reset_stats()
    assert engine.stats["steps"] == 0
    assert engine.summary()["ttft_s_count"] == 0


def test_engine_metrics_every_emits_snapshots(fp_model, capfd):
    cfg, model, params = fp_model
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=8,
                               seed=8).tokens
    adapter = CachedDecoder.from_model(model, params)
    engine = Engine(adapter, EngineConfig(
        max_seq_len=8 + 3, n_slots=4, page_size=4, token_budget=32,
        prefill_chunk=8, paged_decode=True,
    ))
    for i, p in enumerate(prompts):
        engine.submit(np.asarray(p), max_new=3, arrival=0.01 * i)
    engine.run(metrics_every=1e-6)
    err = capfd.readouterr().err
    assert "[metrics t=" in err and "steps=" in err
