"""Checkpoint store: atomic, sharded, elastic-restore friendly.

Layout per step::

    <dir>/step_000123.tmp-<nonce>/   (write everything, fsync)
        shard_00000.npz ... shard_NNNNN.npz
        manifest.json                (tree structure + leaf->shard map + meta)
    <dir>/step_000123/               (atomic rename when complete)

Properties that matter at 1000+ nodes:
  * LOGICAL (unsharded) layout: leaves are saved as full arrays, so restore
    works onto ANY mesh shape — this is what makes elastic re-mesh
    (runtime/elastic.py) a restore, not a resharding job;
  * atomic rename + manifest: a crashed writer never corrupts the latest
    checkpoint; readers only see directories with a manifest;
  * keep-k GC; auto-resume picks the newest complete step;
  * multi-host: every host writes only the shards it owns (here: one host
    owns all), and the manifest records the owner map.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import shutil
import time
import warnings
from typing import Any, Optional

import jax
import numpy as np

__all__ = [
    "ArtifactCorruption",
    "CheckpointManager",
    "save_checkpoint",
    "load_checkpoint",
    "load_arrays",
    "latest_step",
]

_MANIFEST = "manifest.json"


class ArtifactCorruption(ValueError):
    """A checkpoint shard's bytes do not match its manifest digest."""

    def __init__(self, shard: int, path, expected: str, actual: str):
        self.shard = shard
        self.path = str(path)
        self.expected = expected
        self.actual = actual
        super().__init__(
            f"checkpoint shard {shard} corrupt: {path} sha256 "
            f"{actual[:12]}… does not match manifest {expected[:12]}…")


def _sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path only exists from jax 0.4.38; go through
    # tree_util for compatibility with the pinned 0.4.x toolchain
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


def save_checkpoint(
    directory: str | os.PathLike,
    step: int,
    tree: Any,
    *,
    shard_mb: int = 512,
    extra_meta: Optional[dict] = None,
) -> pathlib.Path:
    """Write one checkpoint atomically; returns the final path."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{os.getpid()}-{int(time.time()*1e3)}"
    tmp.mkdir(parents=True)

    items, _ = _flatten_with_paths(tree)
    shard_bytes = shard_mb * 1024 * 1024
    shards: list[dict] = []
    cur: dict = {}
    cur_size = 0
    leaf_to_shard: dict = {}
    for key, leaf in items:
        arr = np.asarray(jax.device_get(leaf))
        if cur_size + arr.nbytes > shard_bytes and cur:
            shards.append(cur)
            cur, cur_size = {}, 0
        cur[key] = arr
        cur_size += arr.nbytes
        leaf_to_shard[key] = len(shards)
    if cur:
        shards.append(cur)

    for i, shard in enumerate(shards):
        # npz keys cannot contain '/': encode
        enc = {k.replace("/", "::"): v for k, v in shard.items()}
        path = tmp / f"shard_{i:05d}.npz"
        with open(path, "wb") as f:
            np.savez(f, **enc)
            f.flush()
            os.fsync(f.fileno())

    manifest = {
        "step": step,
        "format": 1,
        "n_shards": len(shards),
        "leaf_to_shard": leaf_to_shard,
        # per-shard content digests: load_arrays verifies these before
        # deserializing, so silent on-disk corruption fails loudly with
        # the shard named instead of NaN-ing the first forward pass
        "shard_digests": [
            _sha256(tmp / f"shard_{i:05d}.npz") for i in range(len(shards))
        ],
        "time": time.time(),
        "meta": extra_meta or {},
    }
    mpath = tmp / _MANIFEST
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and ".tmp" not in p.name:
            if (p / _MANIFEST).exists():  # complete checkpoints only
                steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def load_arrays(
    directory: str | os.PathLike,
    *,
    step: Optional[int] = None,
    placer: Optional[Any] = None,
    verify: bool = True,
    _corrupt_shards=(),
) -> tuple[dict[str, Any], int, dict]:
    """Load a checkpoint as a flat ``path -> array`` dict, no ``like`` tree.

    This is the structure-free restore used by consumers that rebuild
    their objects from manifest metadata (e.g. serve/artifacts.py, where
    the tree holds QuantizedLinear fields that are not plain pytrees).

    ``placer``: optional ``f(key, np_array) -> array`` applied to each
    leaf as it streams out of its npz shard — the distributed loader
    commits every leaf straight to its device sharding here, so a large
    artifact never exists as one unsharded host+device copy.

    ``verify``: check each shard's SHA-256 against the manifest and
    raise :class:`ArtifactCorruption` on mismatch.  Manifests written
    before digests existed load with a warning.  ``_corrupt_shards`` is
    the fault-injection hook: listed shard indices are treated as if
    their bytes had rotted (see serve/faults.py).
    Returns (arrays, step, meta).
    """
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / _MANIFEST).read_text())
    digests = manifest.get("shard_digests")
    if verify and digests is None:
        warnings.warn(
            f"{path} manifest predates shard checksums; loading unverified",
            stacklevel=2)
    arrays: dict[str, Any] = {}
    for i in range(manifest["n_shards"]):
        spath = path / f"shard_{i:05d}.npz"
        if verify and digests is not None:
            actual = _sha256(spath)
            if i in _corrupt_shards:
                actual = "0" * 64
            if actual != digests[i]:
                raise ArtifactCorruption(i, spath, digests[i], actual)
        with np.load(spath) as z:
            for k in z.files:
                key = k.replace("::", "/")
                arrays[key] = z[k] if placer is None else placer(key, z[k])
    return arrays, step, manifest.get("meta", {})


def load_checkpoint(
    directory: str | os.PathLike,
    like: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> tuple[Any, int, dict]:
    """Restore a pytree (structure given by ``like``).

    ``shardings``: optional same-structure tree of NamedShardings — leaves
    are placed directly onto the (possibly different) current mesh, which
    is the elastic-restore path.
    Returns (tree, step, meta).
    """
    arrays, step, _meta = load_arrays(directory, step=step)
    items, treedef = _flatten_with_paths(like)
    leaves = []
    sh_items = None
    if shardings is not None:
        sh_items, _ = _flatten_with_paths(shardings)
    for idx, (key, leaf) in enumerate(items):
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else arrays[key]
        if sh_items is not None:
            leaves.append(jax.device_put(arr, sh_items[idx][1]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves), step, _meta


@dataclasses.dataclass
class CheckpointManager:
    """keep-k policy + convenience wrapper used by the train driver."""

    directory: str
    keep: int = 3
    save_every: int = 50

    def maybe_save(self, step: int, tree: Any, **meta) -> Optional[pathlib.Path]:
        if step % self.save_every:
            return None
        p = save_checkpoint(self.directory, step, tree, extra_meta=meta)
        self.gc()
        return p

    def gc(self):
        d = pathlib.Path(self.directory)
        if not d.exists():
            return
        steps = sorted(
            int(p.name.split("_")[1])
            for p in d.iterdir()
            if p.is_dir() and p.name.startswith("step_") and ".tmp" not in p.name
            and (p / _MANIFEST).exists()
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(d / f"step_{s:08d}", ignore_errors=True)
        # clean stale tmp dirs from crashed writers
        for p in d.iterdir():
            if ".tmp-" in p.name:
                shutil.rmtree(p, ignore_errors=True)

    def restore_latest(self, like: Any, shardings=None):
        return load_checkpoint(self.directory, like, shardings=shardings)
