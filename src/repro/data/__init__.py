"""Data pipeline: deterministic synthetic LM streams + calibration sets."""
from repro.data.synthetic import (
    CalibrationSet,
    SyntheticLM,
    make_calibration,
    token_batches,
)

__all__ = ["SyntheticLM", "CalibrationSet", "make_calibration", "token_batches"]
