"""Step functions: gradient-accumulated train step, prefill, decode.

``make_train_step`` returns a pure (params, opt_state, batch, step) ->
(params, opt_state, metrics) function:

  * microbatch grad accumulation via `lax.scan` (keeps the train_4k logits
    and activations inside the HBM budget; the full-batch gradient
    all-reduce is deferred to one fused collective at step end, which XLA's
    latency-hiding scheduler overlaps with the last microbatch's backward);
  * remat policy comes from the model config (wrapped around the per-layer
    scan bodies in the model code);
  * gradients accumulate in ``accum_dtype`` (fp32 default; bf16 is the
    §Perf collective/memory knob).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.lm import Model
from repro.optim.optimizers import Optimizer

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step", "split_microbatches"]


def split_microbatches(batch: dict, n_micro: int) -> dict:
    """Reshape every leaf (Bg, ...) -> (n_micro, Bg/n_micro, ...)."""
    def f(x):
        B = x.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        return x.reshape(n_micro, B // n_micro, *x.shape[1:])

    return jax.tree.map(f, batch)


def make_train_step(
    model: Model,
    optimizer: Optimizer,
    *,
    n_micro: Optional[int] = None,
    accum_dtype=jnp.float32,
    aux_coef: float = 0.01,
):
    cfg = model.cfg

    def loss_fn(params, micro):
        loss, metrics = model.loss(params, micro, aux_coef=aux_coef)
        return loss, metrics

    grad_fn = jax.grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        Bg = batch["tokens"].shape[0]
        nm = n_micro or max(1, Bg // max(cfg.microbatch, 1))
        micro = split_microbatches(batch, nm)

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params
        )

        def accum(carry, mb):
            g_acc, loss_acc = carry
            g, metrics = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(accum_dtype), g_acc, g
            )
            return (g_acc, loss_acc + metrics["ce"]), None

        (g_sum, loss_sum), _ = jax.lax.scan(
            accum, (g0, jnp.float32(0.0)), micro
        )
        grads = jax.tree.map(lambda g: g / nm, g_sum)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params, step
        )
        metrics = {"loss": loss_sum / nm, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model, kv_dtype=None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, kv_dtype=kv_dtype)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)

    return decode_step
