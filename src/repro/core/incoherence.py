"""Incoherence processing (QuIP Sec. 4): Algorithms 1 and 2.

Pre-processing conjugates (W, H) by seeded random orthogonal matrices built
as Kronecker products of two small factors (Lemma 5), with a random
permutation folded in (Table 5 ablation), after an optional diagonal rescale
(Sec. B.1).  Post-processing reverts everything.  The quantization range is
spectrum-based: ``s = rho * ||W||_F / sqrt(mn)`` (Sec. 4.2), not max-abs.

A "transform" here is a pair of structured orthogonal operators (one for the
m side, one for the n side) that are never materialized as dense matrices:
multiplication is O(n(p+q)) for the Kronecker family and O(n log n) for the
randomized-Hadamard family (beyond-paper option, cf. DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "kron_factors",
    "random_orthogonal",
    "OrthogonalTransform",
    "make_transform",
    "apply_transform",
    "diag_rescale",
    "quant_range",
    "to_grid",
    "from_grid",
    "incoherence_preprocess",
    "incoherence_postprocess",
    "mu_weight",
    "mu_hessian",
    "PreprocessState",
]

TransformKind = Literal["kronecker", "hadamard", "none"]


def kron_factors(n: int) -> tuple[int, int]:
    """Factor n = p*q with p <= q and p the largest divisor <= sqrt(n)."""
    p = 1
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            p = d
    return p, n // p


def random_orthogonal(key: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    """Haar-distributed random orthogonal matrix (QR with sign fix)."""
    g = jax.random.normal(key, (n, n), dtype=jnp.float32)
    q, r = jnp.linalg.qr(g)
    q = q * jnp.sign(jnp.diagonal(r))[None, :]
    return q.astype(dtype)


def _pow2_split(n: int) -> tuple[int, int]:
    """n = odd * 2^k; returns (odd, 2^k)."""
    k = 0
    while n % 2 == 0:
        n //= 2
        k += 1
    return n, 1 << k


@dataclasses.dataclass(frozen=True)
class OrthogonalTransform:
    """A seeded structured orthogonal operator on R^n.

    kind = "kronecker": y = (A ⊗ B) P x  (A: p×p, B: q×q Haar factors,
        P a random permutation — the Table-5 heuristic).
    kind = "hadamard":  y = (Q_odd ⊗ H_{2^k} S) P x with S random signs and
        H the normalized Walsh–Hadamard matrix (beyond-paper; QuIP#-style).
    kind = "none":      identity.

    Only factors/signs/permutation are stored — O(p² + q² + n), regenerable
    from ``seed`` alone, which is what makes shipping quantized checkpoints
    nearly free (Sec. 4.1).
    """

    kind: TransformKind
    n: int
    seed: int
    A: Optional[jax.Array]  # (p, p) or None
    B: Optional[jax.Array]  # (q, q) or None
    signs: Optional[jax.Array]  # (q,) ±1 for hadamard
    perm: Optional[jax.Array]  # (n,) int32
    inv_perm: Optional[jax.Array]

    @property
    def p(self) -> int:
        return 1 if self.A is None else self.A.shape[0]

    @property
    def q(self) -> int:
        return self.n // self.p


def make_transform(
    kind: TransformKind,
    n: int,
    seed: int,
    *,
    permute: bool = True,
    dtype=jnp.float32,
) -> OrthogonalTransform:
    if kind == "none":
        return OrthogonalTransform(kind, n, seed, None, None, None, None, None)
    key = jax.random.PRNGKey(seed)
    k_a, k_b, k_p, k_s = jax.random.split(key, 4)
    perm = jax.random.permutation(k_p, n) if permute else None
    inv_perm = jnp.argsort(perm) if permute else None
    if kind == "kronecker":
        p, q = kron_factors(n)
        A = random_orthogonal(k_a, p, dtype) if p > 1 else None
        B = random_orthogonal(k_b, q, dtype)
        return OrthogonalTransform(kind, n, seed, A, B, None, perm, inv_perm)
    if kind == "hadamard":
        odd, pow2 = _pow2_split(n)
        if pow2 == 1:
            raise ValueError(f"hadamard transform needs an even dim, got {n}")
        A = random_orthogonal(k_a, odd, dtype) if odd > 1 else None
        signs = (
            jax.random.rademacher(k_s, (pow2,), dtype=dtype)
            if hasattr(jax.random, "rademacher")
            else jnp.sign(jax.random.normal(k_s, (pow2,), dtype=dtype))
        )
        return OrthogonalTransform(kind, n, seed, A, None, signs, perm, inv_perm)
    raise ValueError(f"unknown transform kind: {kind}")


def _fwht(x: jax.Array) -> jax.Array:
    """Normalized fast Walsh–Hadamard transform along the last axis (pow2)."""
    n = x.shape[-1]
    stages = n.bit_length() - 1
    shape = x.shape
    y = x.reshape(-1, n)
    for _ in range(stages):
        y = y.reshape(y.shape[0], -1, 2)
        a, b = y[..., 0], y[..., 1]
        y = jnp.concatenate([a + b, a - b], axis=-1)
    return (y * (n ** -0.5)).reshape(shape)


def apply_transform(
    t: OrthogonalTransform, x: jax.Array, *, inverse: bool = False
) -> jax.Array:
    """Apply y = T x (or T^T x) along the last axis of ``x``.

    ``inverse=True`` applies the transpose (= inverse, T is orthogonal).
    """
    if t.kind == "none":
        return x
    if t.kind == "kronecker":
        p, q = t.p, t.q
        if not inverse:
            if t.perm is not None:
                x = jnp.take(x, t.perm, axis=-1)
            xm = x.reshape(*x.shape[:-1], p, q)
            if t.A is not None:
                xm = jnp.einsum("ij,...jq->...iq", t.A, xm)
            xm = jnp.einsum("...pq,kq->...pk", xm, t.B)
            return xm.reshape(*x.shape[:-1], t.n)
        xm = x.reshape(*x.shape[:-1], p, q)
        if t.A is not None:
            xm = jnp.einsum("ji,...jq->...iq", t.A, xm)
        xm = jnp.einsum("...pq,qk->...pk", xm, t.B)  # B^T on the right
        y = xm.reshape(*x.shape[:-1], t.n)
        if t.inv_perm is not None:
            y = jnp.take(y, t.inv_perm, axis=-1)
        return y
    # hadamard: T = (A_odd ⊗ H S) P
    odd = 1 if t.A is None else t.A.shape[0]
    pow2 = t.n // odd
    if not inverse:
        if t.perm is not None:
            x = jnp.take(x, t.perm, axis=-1)
        xm = x.reshape(*x.shape[:-1], odd, pow2)
        xm = xm * t.signs  # S
        xm = _fwht(xm)  # H (symmetric)
        if t.A is not None:
            xm = jnp.einsum("ij,...jq->...iq", t.A, xm)
        return xm.reshape(*x.shape[:-1], t.n)
    xm = x.reshape(*x.shape[:-1], odd, pow2)
    if t.A is not None:
        xm = jnp.einsum("ji,...jq->...iq", t.A, xm)
    xm = _fwht(xm)
    xm = xm * t.signs  # S^T = S
    y = xm.reshape(*x.shape[:-1], t.n)
    if t.inv_perm is not None:
        y = jnp.take(y, t.inv_perm, axis=-1)
    return y


# ---------------------------------------------------------------------------
# Algorithm 1 / 2 pieces
# ---------------------------------------------------------------------------


def diag_rescale(W: jax.Array, H: jax.Array, eps: float = 1e-12):
    """Sec. B.1 diagonal rescale minimizing tr(D^-1 H D^-1) ||W D||_F^2.

    Stationarity gives D_i ∝ H_ii^{1/4} / ||W_{:,i}||^{1/2} (the paper's
    text writes sqrt(H_ii / ||W_i||), same scale family).  Returns
    (W D, D^-1 H D^-1, D).
    """
    col_norm = jnp.sqrt(jnp.sum(W * W, axis=0) + eps)
    D = (jnp.diagonal(H) + eps) ** 0.25 / jnp.sqrt(col_norm)
    Wr = W * D[None, :]
    Hr = H / (D[:, None] * D[None, :])
    return Wr, Hr, D


def quant_range(W: jax.Array, rho: float) -> jax.Array:
    """Spectrum-based symmetric quantization range s = rho*||W||_F/sqrt(mn)."""
    m, n = W.shape
    return rho * jnp.linalg.norm(W) / math.sqrt(m * n)


def to_grid(W: jax.Array, s: jax.Array, maxq: int) -> jax.Array:
    """Map [-s, s] -> [0, maxq] (continuous; rounding happens in LDLQ)."""
    return (W / s + 1.0) * (maxq / 2.0)


def from_grid(Wq: jax.Array, s: jax.Array, maxq: int) -> jax.Array:
    """Alg. 2 line 2: W <- s * ((Wq / maxq) * 2 - 1)."""
    return s * (Wq * (2.0 / maxq) - 1.0)


@dataclasses.dataclass(frozen=True)
class PreprocessState:
    """Everything needed to revert Algorithm 1 (and to run inference)."""

    U: OrthogonalTransform  # m side
    V: OrthogonalTransform  # n side
    D: Optional[jax.Array]  # (n,) diagonal rescale, or None
    s: jax.Array  # scalar quantization range
    maxq: int


def incoherence_preprocess(
    W: jax.Array,
    H: jax.Array,
    *,
    bits: int,
    seed: int,
    rho: float = 2.4,
    alpha: float = 0.01,
    kind: TransformKind = "kronecker",
    rescale: bool = True,
    permute: bool = True,
    spectrum_range: bool = True,
):
    """Algorithm 1.  Returns (W_grid, H_tilde, state).

    W_grid lives on the continuous grid domain [0, maxq]; H_tilde is the
    conjugated Hessian to feed LDLQ.
    """
    m, n = W.shape
    maxq = 2**bits - 1
    # line: H <- H + alpha mean(diag H) I   (OPTQ damping, kept under IncP)
    H = H + alpha * jnp.mean(jnp.diagonal(H)) * jnp.eye(n, dtype=H.dtype)
    D = None
    if rescale:
        W, H, D = diag_rescale(W, H)
    U = make_transform(kind, m, seed * 2 + 1, permute=permute, dtype=W.dtype)
    V = make_transform(kind, n, seed * 2 + 2, permute=permute, dtype=W.dtype)
    # W <- U W V^T ; H <- V H V^T, all via structured ops (never dense n×n
    # transform matrices).
    W = apply_transform(V, W)  # rows: W V^T
    W = apply_transform(U, W.T).T  # cols: U W
    H = apply_transform(V, H)  # H V^T
    H = apply_transform(V, H.T).T  # V H V^T
    H = (H + H.T) * 0.5  # re-symmetrize fp error
    if spectrum_range:
        s = quant_range(W, rho)
    else:
        s = jnp.max(jnp.abs(W))
    Wg = to_grid(W, s, maxq)
    return Wg, H, PreprocessState(U=U, V=V, D=D, s=s, maxq=maxq)


def incoherence_postprocess(Wq: jax.Array, state: PreprocessState) -> jax.Array:
    """Algorithm 2: revert grid scale, transforms and diagonal rescale."""
    W = from_grid(Wq, state.s, state.maxq)
    W = apply_transform(state.U, W.T, inverse=True).T  # U^T W
    W = apply_transform(state.V, W, inverse=True)  # W V
    if state.D is not None:
        W = W / state.D[None, :]
    return W


# ---------------------------------------------------------------------------
# Incoherence measurement (Figures 2/3)
# ---------------------------------------------------------------------------


def mu_weight(W: jax.Array) -> jax.Array:
    """µ_W such that max|W_ij| = µ ||W||_F / sqrt(mn) (Def. 1)."""
    m, n = W.shape
    return jnp.max(jnp.abs(W)) * math.sqrt(m * n) / jnp.linalg.norm(W)


def mu_hessian(H: jax.Array) -> jax.Array:
    """µ_H such that max|Q_ij| = µ/sqrt(n) for eigvecs Q of H (Def. 1)."""
    n = H.shape[0]
    _, Q = jnp.linalg.eigh(H)
    return jnp.max(jnp.abs(Q)) * math.sqrt(n)
