"""Incoherence-processing tests: transform orthogonality/invertibility,
proxy invariance, µ reduction (Figs. 2/3), Alg.1/2 round-trip, and the
hypothesis property suite for the structured transforms."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_hessian, make_weights

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import incoherence as inc
from repro.core.proxy import proxy_loss

DIMS = [8, 24, 64, 96, 128, 160]


@pytest.mark.parametrize("kind", ["kronecker", "hadamard"])
@pytest.mark.parametrize("n", DIMS)
def test_transform_orthogonal(kind, n):
    t = inc.make_transform(kind, n, seed=n)
    X = jax.random.normal(jax.random.PRNGKey(0), (5, n))
    Y = inc.apply_transform(t, X)
    # norm preservation (orthogonality)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(Y), axis=-1),
        np.linalg.norm(np.asarray(X), axis=-1),
        rtol=1e-4,
    )
    # inverse round-trip
    Xr = inc.apply_transform(t, Y, inverse=True)
    np.testing.assert_allclose(np.asarray(Xr), np.asarray(X), atol=1e-4)


@pytest.mark.parametrize("kind", ["kronecker", "hadamard"])
def test_transform_matches_dense_matrix(kind):
    """The structured operator equals a genuine orthogonal dense matrix."""
    n = 24 if kind == "kronecker" else 24  # 24 = 3 * 2^3 exercises both paths
    t = inc.make_transform(kind, n, seed=5)
    T = inc.apply_transform(t, jnp.eye(n))  # rows = T e_i -> T^T? check ortho
    TT = np.asarray(T)
    np.testing.assert_allclose(TT @ TT.T, np.eye(n), atol=1e-4)


def test_transform_seeded_deterministic():
    t1 = inc.make_transform("kronecker", 64, seed=9)
    t2 = inc.make_transform("kronecker", 64, seed=9)
    X = jax.random.normal(jax.random.PRNGKey(1), (3, 64))
    np.testing.assert_array_equal(
        np.asarray(inc.apply_transform(t1, X)),
        np.asarray(inc.apply_transform(t2, X)),
    )
    t3 = inc.make_transform("kronecker", 64, seed=10)
    assert not np.allclose(
        np.asarray(inc.apply_transform(t3, X)),
        np.asarray(inc.apply_transform(t1, X)),
    )


def test_proxy_invariance_under_conjugation():
    """tr(W~ H~ W~^T) == tr(W H W^T): the transformation preserves Eq. (1)."""
    m, n = 32, 48
    W = make_weights(m, n, seed=0)
    H = make_hessian(n, seed=0)
    U = inc.make_transform("kronecker", m, seed=1)
    V = inc.make_transform("kronecker", n, seed=2)
    Wt = inc.apply_transform(V, W)
    Wt = inc.apply_transform(U, Wt.T).T
    Ht = inc.apply_transform(V, H)
    Ht = inc.apply_transform(V, Ht.T).T
    a = float(jnp.einsum("ij,jk,ik->", Wt, Ht, Wt))
    b = float(jnp.einsum("ij,jk,ik->", W, H, W))
    assert abs(a - b) / abs(b) < 1e-3


@pytest.mark.parametrize("kind", ["kronecker", "hadamard"])
def test_mu_reduction(kind):
    """Figs. 2/3: incoherence processing reduces µ_W and µ_H on outlier data."""
    m, n = 64, 128
    W = make_weights(m, n, seed=2, outliers=0.01, outlier_scale=1.0)
    H = make_hessian(n, seed=2)
    U = inc.make_transform(kind, m, seed=3)
    V = inc.make_transform(kind, n, seed=4)
    Wt = inc.apply_transform(V, W)
    Wt = inc.apply_transform(U, Wt.T).T
    Ht = inc.apply_transform(V, H)
    Ht = inc.apply_transform(V, Ht.T).T
    assert float(inc.mu_weight(Wt)) < float(inc.mu_weight(W)) * 0.5
    assert float(inc.mu_hessian((Ht + Ht.T) / 2)) < float(inc.mu_hessian(H))


def test_preprocess_postprocess_roundtrip_without_rounding():
    """Alg.1 then Alg.2 with the identity in between recovers W exactly."""
    W = make_weights(32, 64, seed=6)
    H = make_hessian(64, seed=6)
    Wg, Ht, state = inc.incoherence_preprocess(W, H, bits=8, seed=0)
    Wrec = inc.incoherence_postprocess(Wg, state)  # no rounding applied
    np.testing.assert_allclose(np.asarray(Wrec), np.asarray(W), atol=2e-4)
    # conjugated H stays SPD-ish (damped)
    evs = np.linalg.eigvalsh(np.asarray((Ht + Ht.T) / 2))
    assert evs.min() > 0


def test_diag_rescale_reduces_objective():
    """Sec. B.1: the rescale should not increase tr(H)·||W||_F^2."""
    W = make_weights(48, 96, seed=7, outliers=0.02)
    H = make_hessian(96, seed=7)
    Wr, Hr, D = inc.diag_rescale(W, H)
    before = float(jnp.trace(H) * jnp.sum(W * W))
    after = float(jnp.trace(Hr) * jnp.sum(Wr * Wr))
    assert after <= before * 1.0001
    # exact revert
    np.testing.assert_allclose(
        np.asarray(Wr / D[None, :]), np.asarray(W), rtol=1e-5
    )


def test_grid_mapping_roundtrip():
    W = make_weights(16, 32, seed=8)
    s = inc.quant_range(W, 2.4)
    maxq = 3
    Wg = inc.to_grid(W, s, maxq)
    Wb = inc.from_grid(Wg, s, maxq)
    np.testing.assert_allclose(np.asarray(Wb), np.asarray(W), atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 96).map(lambda v: 2 * v),  # even dims (hadamard needs pow2 part)
    seed=st.integers(0, 2**16),
    kind=st.sampled_from(["kronecker", "hadamard"]),
)
def test_property_transform_isometry(n, seed, kind):
    """Property: every seeded transform is an isometry and invertible."""
    t = inc.make_transform(kind, n, seed=seed)
    x = jax.random.normal(jax.random.PRNGKey(seed % 7), (2, n))
    y = inc.apply_transform(t, x)
    assert abs(float(jnp.linalg.norm(y) - jnp.linalg.norm(x))) < 1e-2
    xr = inc.apply_transform(t, y, inverse=True)
    assert float(jnp.max(jnp.abs(xr - x))) < 1e-3


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 24).map(lambda v: 2 * v),
    n=st.integers(2, 24).map(lambda v: 4 * v),
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 1000),
)
def test_property_pre_post_inverse(m, n, bits, seed):
    """Property: postprocess(preprocess(W)) == W for any shape/bits/seed."""
    W = make_weights(m, n, seed=seed)
    H = make_hessian(n, seed=seed, tokens=256)
    Wg, _, state = inc.incoherence_preprocess(W, H, bits=bits, seed=seed)
    Wrec = inc.incoherence_postprocess(Wg, state)
    assert float(jnp.max(jnp.abs(Wrec - W))) < 5e-4
