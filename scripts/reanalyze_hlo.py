"""Re-derive every dry-run JSON's roofline from its archived HLO.

The compiled HLO is archived per cell (experiments/hlo/*.zst), so analyzer
improvements re-apply WITHOUT recompiling 64 cells:

    PYTHONPATH=src python scripts/reanalyze_hlo.py
"""
from __future__ import annotations

import json
import pathlib
import sys

import zstandard

from repro.configs import get_config, SHAPES
from repro.runtime.hlo_analysis import analyze_hlo
from repro.runtime.roofline import roofline_terms


def main():
    n = 0
    for jf in sorted(pathlib.Path("experiments/dryrun").glob("*.json")):
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        hp = rec.get("hlo_path")
        if not hp or not pathlib.Path(hp).exists():
            print(f"skip {jf.name}: no archived HLO", file=sys.stderr)
            continue
        text = zstandard.ZstdDecompressor().decompress(
            open(hp, "rb").read()
        ).decode()
        chips = rec["chips"]
        stats = analyze_hlo(text, chips)
        terms = roofline_terms(
            hlo_flops=stats.flops,
            hlo_bytes=stats.bytes_accessed,
            collective_bytes=stats.collectives.total_bytes,
            chips=chips,
            cfg=get_config(rec["arch"]),
            shape=SHAPES[rec["shape"]],
            flops_are_global=False,
        )
        rec["hlo_weighted"] = {
            "flops_per_device": stats.flops,
            "bytes_per_device": stats.bytes_accessed,
        }
        rec["collectives"] = stats.collectives.summary()
        rec["roofline"] = terms.to_dict()
        json.dump(rec, open(jf, "w"), indent=1, default=str)
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
