"""SSE encoding and the cursor-diff token stream.

Why a cursor diff instead of a token queue: the engine ticks on its own
thread, so a tick may emit tokens BETWEEN ``Engine.submit`` returning
and the handler registering its stream on the event loop.  A queue
filled by tick dispatch would silently drop those tokens; a
:class:`TokenStream` instead keeps a ``sent`` cursor and diffs it
against the request's append-only ``out_tokens`` each wake-up, so a
late registration (or a coalesced burst of notifications) never loses
or duplicates a token.  Tick dispatch only ever *nudges* the stream —
correctness never depends on one nudge per token.
"""
from __future__ import annotations

import asyncio
import json
from typing import TYPE_CHECKING, AsyncIterator, Optional

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.serve.scheduler import Request

__all__ = ["TokenStream", "sse_event", "sse_headers"]


def sse_event(event: str, data: dict) -> bytes:
    """One Server-Sent-Events frame."""
    return (f"event: {event}\ndata: {json.dumps(data)}\n\n").encode()


def sse_headers() -> list:
    return [
        ("Content-Type", "text/event-stream"),
        ("Cache-Control", "no-store"),
        ("X-Accel-Buffering", "no"),
    ]


class TokenStream:
    """Fan-out endpoint for one request's tokens.

    The tick task calls :meth:`nudge` (same event loop — no locking)
    whenever a tick emitted for, or terminalized, this request;
    :meth:`pump` is the handler-side async iterator yielding each new
    token exactly once, then a final ``(None, request)`` sentinel when
    the request is terminal and fully drained.
    """

    def __init__(self, req: "Request", sent: int = 0):
        self.req = req
        # out_tokens[:sent] already yielded — nonzero for a failover
        # resubmission, whose resumed prefix the ORIGINAL stream already
        # delivered (the router splices; re-sending would duplicate)
        self.sent = sent
        self._wake = asyncio.Event()
        # catch up work that happened before registration
        if len(req.out_tokens) > sent or req.state.terminal:
            self._wake.set()

    def nudge(self) -> None:
        self._wake.set()

    @property
    def drained(self) -> bool:
        return self.req.state.terminal and self.sent >= len(
            self.req.out_tokens
        )

    async def pump(
        self, idle_timeout_s: Optional[float] = None
    ) -> AsyncIterator[tuple]:
        """Yield ``(token, None)`` per fresh token, then ``(None, req)``
        once terminal.  ``idle_timeout_s`` bounds the wait between
        wake-ups (a dead tick loop must not wedge handlers forever);
        expiry raises :class:`TimeoutError`."""
        req = self.req
        while True:
            # reading len() + indexing an append-only list is safe
            # across the engine-thread boundary (GIL-atomic)
            toks = req.out_tokens
            while self.sent < len(toks):
                tok = toks[self.sent]
                self.sent += 1
                yield int(tok), None
            if req.state.terminal:
                if self.sent >= len(req.out_tokens):
                    yield None, req
                    return
                continue  # tokens landed after the terminal check
            self._wake.clear()
            # re-check after clear: a nudge between the len() read and
            # clear() would otherwise be lost
            if len(req.out_tokens) > self.sent or req.state.terminal:
                continue
            if idle_timeout_s is None:
                await self._wake.wait()
            else:
                await asyncio.wait_for(self._wake.wait(), idle_timeout_s)


class StreamTable:
    """rid -> TokenStream registry the tick task fans results into."""

    def __init__(self):
        self._streams: dict[int, TokenStream] = {}

    def register(self, req: "Request", sent: int = 0) -> TokenStream:
        ts = TokenStream(req, sent=sent)
        self._streams[req.rid] = ts
        return ts

    def unregister(self, rid: int) -> None:
        self._streams.pop(rid, None)

    def __len__(self) -> int:
        return len(self._streams)

    def dispatch(self, tick_result) -> None:
        """Nudge every stream a tick touched (event-loop side)."""
        touched = set()
        for req, _tok in tick_result.emitted:
            touched.add(req.rid)
        for req in tick_result.finished:
            touched.add(req.rid)
        for rid in touched:
            ts = self._streams.get(rid)
            if ts is not None:
                ts.nudge()

    def nudge_all(self) -> None:
        for ts in self._streams.values():
            ts.nudge()
