"""Persistent quantized artifacts: quantize once, serve forever.

``save_quantized`` persists a ``QuantizedModel`` through the atomic
checkpoint store (checkpoint/store.py): packed int32 weights, per-layer
scales and diagonal rescales, and fp embed/norm params go into npz shards;
everything regenerable — the incoherence transforms — is stored only as
(kind, n, seed) metadata in the manifest, alongside the full ``ArchConfig``
and ``QuipConfig``.  ``load_quantized`` rebuilds the model without touching
the QuIP pipeline, so ``launch/serve.py --load-quantized <dir>`` starts
serving packed 2-bit weights with no calibration pass.

Layout::

    <dir>/step_00000000/shard_*.npz + manifest.json

with array keys ``embed/tok``, ``final_norm/scale``,
``blocks/<i>/<ln1|ln2|q_norm|k_norm>/...`` and
``blocks/<i>/<linear>/{packed,s,D}``.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Optional

import jax.numpy as jnp

from repro.checkpoint.store import ArtifactCorruption, load_arrays, save_checkpoint
from repro.configs.base import ArchConfig
from repro.core.quantizer import (
    QuantizedLinear,
    QuipConfig,
    linear_from_arrays,
    linear_to_arrays,
)

__all__ = [
    "save_quantized",
    "load_quantized",
    "ArtifactCorruption",
    "ARTIFACT_FORMAT",
]

ARTIFACT_FORMAT = 1
_NORM_KEYS = ("ln1", "ln2", "q_norm", "k_norm")


def save_quantized(
    directory, qm, qcfg: QuipConfig, *, extra_meta: Optional[dict] = None
) -> pathlib.Path:
    """Persist a ``launch.quantize.QuantizedModel`` (+ its QuipConfig)."""
    blocks = []
    linear_meta: dict[str, dict] = {}
    for i, blk in enumerate(qm.blocks):
        bt: dict = {}
        for name, val in blk.items():
            if isinstance(val, QuantizedLinear):
                arrays, meta = linear_to_arrays(val)
                bt[name] = arrays
                linear_meta[f"{i}/{name}"] = meta
            else:
                bt[name] = val
        blocks.append(bt)
    tree = {"embed": qm.embed, "final_norm": qm.final_norm, "blocks": blocks}
    meta = {
        "kind": "quip_quantized_model",
        "format": ARTIFACT_FORMAT,
        "arch_config": dataclasses.asdict(qm.cfg),
        "quip_config": dataclasses.asdict(qcfg),
        "n_blocks": len(qm.blocks),
        "linears": linear_meta,
        **(extra_meta or {}),
    }
    return save_checkpoint(directory, 0, tree, extra_meta=meta)


def load_quantized(directory, *, placer=None, verify=True, faults=None):
    """-> (QuantizedModel, meta).  No re-quantization: packed weights load
    directly and transforms regenerate from their stored seeds.

    ``placer``: optional ``f(key, np_array) -> array`` applied per leaf on
    the way out of the store — ``serve.distributed.artifact_placer`` uses
    it to commit packed codes straight to their mesh sharding.

    ``verify``: check shard SHA-256 digests against the manifest; a
    mismatch raises :class:`ArtifactCorruption` naming the shard
    (manifests written before digests existed load with a warning).
    ``faults``: optional :class:`~repro.serve.faults.FaultPlan` whose
    armed ``corrupt_shard`` rules force digest mismatches — the
    integrity path is testable without rotting bytes on disk."""
    from repro.launch.quantize import QuantizedModel  # deferred: avoid cycle

    corrupt = faults.corrupt_shards() if faults is not None else ()
    arrays, _step, meta = load_arrays(
        directory, placer=placer, verify=verify, _corrupt_shards=corrupt)
    if meta.get("kind") != "quip_quantized_model":
        raise ValueError(
            f"{directory} is not a quantized artifact "
            f"(manifest kind={meta.get('kind')!r})"
        )
    cfg_dict = dict(meta["arch_config"])
    cfg_dict["shape_skips"] = tuple(cfg_dict.get("shape_skips", ()))  # json list
    cfg = ArchConfig(**cfg_dict)

    def subtree(prefix: str) -> dict:
        out: dict = {}
        plen = len(prefix)
        for key, arr in arrays.items():
            if key.startswith(prefix):
                out[key[plen:]] = jnp.asarray(arr)
        return out

    blocks = []
    for i in range(meta["n_blocks"]):
        blk: dict = {}
        for norm in _NORM_KEYS:
            sub = subtree(f"blocks/{i}/{norm}/")
            if sub:
                blk[norm] = sub
            elif f"blocks/{i}/{norm}" in arrays:  # bare array (q/k_norm)
                blk[norm] = jnp.asarray(arrays[f"blocks/{i}/{norm}"])
        for lkey, lmeta in meta["linears"].items():
            idx, name = lkey.split("/", 1)
            if int(idx) != i:
                continue
            blk[name] = linear_from_arrays(
                subtree(f"blocks/{i}/{name}/"), lmeta
            )
        blocks.append(blk)
    qm = QuantizedModel(
        cfg=cfg,
        embed=subtree("embed/"),
        final_norm=subtree("final_norm/"),
        blocks=blocks,
        stats=meta.get("stats", []),
    )
    return qm, meta
