"""Pallas kernel sweeps (interpret=True) vs pure-jnp oracles:
kron_mul, hadamard, ldlq in-block kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_hessian

from repro.core.incoherence import random_orthogonal
from repro.core.ldlq import ldl_decomposition, ldlq as ldlq_seq
from repro.kernels.hadamard import ops as had_ops
from repro.kernels.hadamard.ref import hadamard_ref
from repro.kernels.kron_mul import ops as kron_ops
from repro.kernels.kron_mul.ref import kron_mul_dense_ref, kron_mul_ref
from repro.kernels.ldlq.ops import ldlq_pallas


# --- kron_mul ---------------------------------------------------------------


@pytest.mark.parametrize("p,q", [(4, 8), (8, 8), (12, 16), (16, 128)])
@pytest.mark.parametrize("N", [1, 7, 32])
def test_kron_mul_kernel_vs_ref(p, q, N):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(p * q + N), 3)
    A = random_orthogonal(k1, p)
    B = random_orthogonal(k2, q)
    x = jax.random.normal(k3, (N, p * q), jnp.float32)
    out = kron_ops.kron_mul(x, A, B, interpret=True)
    ref = kron_mul_ref(x, A, B)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_kron_mul_ref_matches_dense_kron():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    A = random_orthogonal(k1, 6)
    B = random_orthogonal(k2, 10)
    x = jax.random.normal(k3, (5, 60))
    np.testing.assert_allclose(
        np.asarray(kron_mul_ref(x, A, B)),
        np.asarray(kron_mul_dense_ref(x, A, B)),
        atol=1e-4,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kron_mul_dtypes(dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    A = random_orthogonal(k1, 8)
    B = random_orthogonal(k2, 16)
    x = jax.random.normal(k3, (3, 128)).astype(dtype)
    out = kron_ops.kron_mul(x, A, B, interpret=True)
    assert out.dtype == dtype
    ref = kron_mul_ref(x, A.astype(dtype), B.astype(dtype))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2
    )


def test_kron_mul_leading_dims():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    A = random_orthogonal(k1, 4)
    B = random_orthogonal(k2, 8)
    x = jax.random.normal(k3, (2, 3, 32))
    out = kron_ops.kron_mul(x, A, B, interpret=True)
    assert out.shape == (2, 3, 32)


# --- hadamard ---------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 64, 128, 256, 1024])
@pytest.mark.parametrize("N", [1, 5])
def test_hadamard_kernel_vs_butterfly_ref(n, N):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + N))
    x = jax.random.normal(k1, (N, n), jnp.float32)
    signs = jnp.sign(jax.random.normal(k2, (n,))) + 0.0
    out = had_ops.hadamard_transform(x, signs, interpret=True)
    ref = hadamard_ref(x, signs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_hadamard_is_isometry():
    n = 512
    x = jax.random.normal(jax.random.PRNGKey(3), (4, n))
    signs = jnp.ones((n,))
    y = had_ops.hadamard_transform(x, signs, interpret=True)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-4,
    )


def test_hadamard_involution():
    """H (H x) == x for the normalized transform with unit signs."""
    n = 256
    x = jax.random.normal(jax.random.PRNGKey(4), (2, n))
    signs = jnp.ones((n,))
    y = had_ops.hadamard_transform(x, signs, interpret=True)
    z = had_ops.hadamard_transform(y, signs, interpret=True)
    np.testing.assert_allclose(np.asarray(z), np.asarray(x), atol=1e-4)


# --- ldlq in-block kernel ----------------------------------------------------


@pytest.mark.parametrize("m,n,block", [(32, 128, 128), (100, 256, 128), (64, 64, 64)])
@pytest.mark.parametrize("bits", [2, 4])
def test_ldlq_pallas_matches_sequential(m, n, block, bits):
    maxq = 2**bits - 1
    W = jax.random.uniform(jax.random.PRNGKey(m + n), (m, n)) * maxq
    H = make_hessian(n, seed=n, damp=1e-2)
    Udot, _ = ldl_decomposition(H)
    ref = ldlq_seq(W, Udot, maxq)
    out = ldlq_pallas(W, Udot, maxq, block=block, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_ldlq_pallas_cpu_fallback():
    W = jax.random.uniform(jax.random.PRNGKey(9), (16, 64)) * 3
    H = make_hessian(64, seed=9, damp=1e-2)
    Udot, _ = ldl_decomposition(H)
    out = ldlq_pallas(W, Udot, 3, block=64)  # dispatches to XLA off-TPU
    ref = ldlq_seq(W, Udot, 3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
