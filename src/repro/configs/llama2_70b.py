"""llama2-70b [dense] — the paper's own Table-1 evaluation model
(Touvron et al. 2023); included as the paper-fidelity anchor."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama2-70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32000,
    mlp="swiglu",
    rope_theta=1e4,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama2-70b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        mlp="swiglu",
        dtype="float32",
        microbatch=2,
        remat="none",
    )
