"""Pallas TPU kernel: randomized Hadamard transform y = H_n (s ⊙ x).

TPU adaptation (DESIGN.md §3/§6): a log-depth butterfly network is
VPU-hostile (strided shuffles across lanes); instead we use the Kronecker
identity  H_{a·b} = H_a ⊗ H_b  and evaluate the transform as TWO dense MXU
matmuls with small Hadamard factor matrices (a, b ≤ 128):

    X = reshape(s ⊙ x, (a, b));   Y = H_a · X · H_bᵀ / sqrt(n)

This turns the O(n log n) butterfly into O(n(a+b)) systolic work that the
MXU does at full rate — on TPU the matmul form beats the "fast" transform
for every n that fits a 2-factor split (n ≤ 16384; 3-factor splits cover
the rest).  Factors are built host-side (Sylvester) and stay in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def sylvester(n: int) -> np.ndarray:
    """Unnormalized H_n (n a power of two) via Sylvester's construction."""
    if n <= 0 or n & (n - 1):
        raise ValueError(f"Hadamard order must be a power of two, got {n}")
    H = np.ones((1, 1), np.float32)
    while H.shape[0] < n:
        H = np.block([[H, H], [H, -H]])
    return H


def _had_kernel(x_ref, s_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int):
    bB = x_ref.shape[0]
    x = x_ref[...] * s_ref[...]  # sign flip (broadcast over rows)
    X = x.reshape(bB, a, b)
    Ha = ha_ref[...]
    Hb = hb_ref[...]
    T = jax.lax.dot_general(
        X, Hb, (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bB, a, b)
    Y = jax.lax.dot_general(
        T, Ha, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bB, b, a)
    Y = jnp.swapaxes(Y, 1, 2) * (1.0 / np.sqrt(a * b))
    o_ref[...] = Y.reshape(bB, a * b).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("a", "b", "bB", "interpret"))
def hadamard_kernel(
    x: jax.Array,
    signs: jax.Array,
    Ha: jax.Array,
    Hb: jax.Array,
    *,
    a: int,
    b: int,
    bB: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x: (N, a*b); signs: (a*b,); H factors unnormalized Sylvester."""
    N, n = x.shape
    if n != a * b:
        raise ValueError(
            f"x feature dim {n} != a*b = {a}*{b} = {a * b}"
        )
    if N % bB:
        raise ValueError(
            f"row count N={N} must be a multiple of the batch tile bB={bB}"
        )
    return pl.pallas_call(
        functools.partial(_had_kernel, a=a, b=b),
        grid=(N // bB,),
        in_specs=[
            pl.BlockSpec((bB, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bB, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, n), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x, signs, Ha, Hb)
