"""Public wrappers around the paged-attention Pallas kernels.

``paged_gqa_decode`` is what the serving adapter's fast path calls once per
layer per decode step; ``paged_gqa_prefill`` is its chunked-prefill
sibling, called once per layer per batched prefill dispatch.  Both handle:

* backend dispatch — the Pallas kernel on TPU (or under ``interpret``/
  ``force_kernel`` for tests), the jnp oracle elsewhere (this CPU
  container), exactly like ``kernels.quant_matmul.ops``;
* for decode, the **self-token merge**: the kernel accumulates only over
  context pages and returns ``(o, m, l)``; the new token's own (K, V) —
  which is never read back from the pool — is folded in analytically:

      m' = max(m, s_self);  o' = o·e^{m−m'} + v_self·e^{s_self−m'}
      l' = l·e^{m−m'} + e^{s_self−m'};      out = o' / l'

  which equals softmax over [context, self] up to fp reassociation, so the
  fast path needs neither a pre-attention scatter nor a KV concat.  The
  prefill kernel folds its intra-chunk causal block in as one extra grid
  step and normalizes in place, so its wrapper only reshapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_kernel,
    paged_prefill_kernel,
)
from repro.kernels.paged_attention.ref import (
    paged_gqa_decode_ref,
    paged_gqa_prefill_ref,
)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def paged_gqa_decode(
    q: jax.Array,
    k_new: jax.Array,
    v_new: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    ctx_len: jax.Array,
    *,
    layer: int,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    interpret: bool = False,
    force_kernel: bool = False,
) -> jax.Array:
    """One-token GQA decode attention against the physical page pool.

    q (B, H, hd) post-RoPE queries; k_new/v_new (B, KV, hd) the token's own
    post-RoPE K/V (not yet scattered); k/v_pages the full (L, P, ps, KV, hd)
    pool (+ per-(token, head) scales for int8 pages); block_tables (B, Pa)
    bucketed to the attended prefix; ctx_len (B,).  -> (B, H, hd) q.dtype.
    """
    if not (on_tpu() or interpret or force_kernel):
        return paged_gqa_decode_ref(
            q, k_new, v_new, k_pages, v_pages, block_tables, ctx_len,
            layer=layer, k_scale=k_scale, v_scale=v_scale,
        )

    B, H, hd = q.shape
    KV = k_new.shape[1]
    if H % KV:
        raise ValueError(
            f"n_heads {H} must be a multiple of n_kv_heads {KV}"
        )
    qg = q.reshape(B, KV, H // KV, hd)
    o, m, l = paged_attention_kernel(
        qg, k_pages, v_pages, block_tables, ctx_len,
        layer=layer, k_scale=k_scale, v_scale=v_scale, interpret=interpret,
    )
    qf = qg.astype(jnp.float32)
    s_self = jnp.einsum(
        "bkgd,bkd->bkg", qf, k_new.astype(jnp.float32)
    ) * (hd**-0.5)
    m0, l0 = m[..., 0], l[..., 0]
    m_tot = jnp.maximum(m0, s_self)
    a_ctx = jnp.exp(m0 - m_tot)
    a_self = jnp.exp(s_self - m_tot)
    num = o * a_ctx[..., None] + (
        v_new.astype(jnp.float32)[:, :, None, :] * a_self[..., None]
    )
    den = l0 * a_ctx + a_self
    out = num / den[..., None]
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_gqa_prefill(
    q: jax.Array,
    k_chunk: jax.Array,
    v_chunk: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    ctx_len: jax.Array,
    *,
    layer: int,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    k_self: jax.Array | None = None,
    v_self: jax.Array | None = None,
    interpret: bool = False,
    force_kernel: bool = False,
) -> jax.Array:
    """Chunk-batch causal prefill attention against the physical page pool.

    q (B, C, H, hd) post-RoPE chunk queries (lane b's token t at absolute
    position ``ctx_len[b] + t``); k_chunk/v_chunk (B, C, KV, hd) the
    chunk's own post-RoPE K/V (not yet scattered); k/v_pages the full
    (L, P, ps, KV, hd) pool (+ per-(token, head) scales for int8 pages);
    block_tables (B, Pa) bucketed to the longest prior context; ctx_len
    (B,) ragged prior-context lengths; k/v_self optional (B, C, KV, hd)
    DIAGONAL override — each token's attention to itself uses these
    instead of the chunk arrays (the speculative verifier's int8-exactness
    hook; see ``paged_gqa_verify``).  -> (B, C, H, hd) q.dtype.
    """
    if not (on_tpu() or interpret or force_kernel):
        return paged_gqa_prefill_ref(
            q, k_chunk, v_chunk, k_pages, v_pages, block_tables, ctx_len,
            layer=layer, k_scale=k_scale, v_scale=v_scale,
            k_self=k_self, v_self=v_self,
        )

    B, C, H, hd = q.shape
    KV = k_chunk.shape[2]
    if H % KV:
        raise ValueError(
            f"n_heads {H} must be a multiple of n_kv_heads {KV}"
        )
    G = H // KV
    qg = q.reshape(B, C, KV, G, hd).transpose(0, 2, 3, 1, 4)
    o = paged_prefill_kernel(
        qg, k_chunk, v_chunk, k_pages, v_pages, block_tables, ctx_len,
        layer=layer, k_scale=k_scale, v_scale=v_scale,
        k_self=k_self, v_self=v_self, interpret=interpret,
    )  # (B, KV, G, C, hd) normalized fp32
    return o.transpose(0, 3, 1, 2, 4).reshape(B, C, H, hd).astype(q.dtype)


def paged_gqa_verify(
    q: jax.Array,
    k_chunk: jax.Array,
    v_chunk: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    ctx_len: jax.Array,
    *,
    layer: int,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    k_self: jax.Array | None = None,
    v_self: jax.Array | None = None,
    interpret: bool = False,
    force_kernel: bool = False,
) -> jax.Array:
    """Speculative-verify attention: the chunked-prefill kernel reused.

    A draft-and-verify tick IS a chunked prefill over the drafted tokens:
    lane b carries ``[last_emitted, d_1, ..., d_K]`` at absolute positions
    ``ctx_len[b] .. ctx_len[b] + K``, each token attends the lane's paged
    prior context plus the causal prefix of the chunk itself, and the
    chunk width is ``K + 1`` instead of ``prefill_chunk``.  The grid, the
    index-map clamp, the int8 page handling, and the one trailing
    intra-chunk causal step are untouched — so the verifier inherits the
    prefill kernel's whole parity surface (tests/test_paged_attention.py)
    and any future kernel speedup for free.

    Exactness vs the one-token decode path: for int8 pools the caller
    passes the int8 ROUND-TRIP of the chunk K/V as ``k/v_chunk`` (what the
    pool will return for these tokens once scattered) and the fp original
    as ``k/v_self`` (what one-token decode folds in analytically for the
    self position) — every score then matches the sequential path exactly.
    Kept as a named entry so the serving adapter's verify dispatch states
    its intent, and so a verify-specific kernel schedule (e.g. a
    K+1-specialized grid) can slot in later without touching the adapter.
    """
    if q.shape[1] < 1:
        raise ValueError(
            f"verify chunk needs >= 1 token (the last emitted token), "
            f"got width {q.shape[1]}"
        )
    return paged_gqa_prefill(
        q, k_chunk, v_chunk, k_pages, v_pages, block_tables, ctx_len,
        layer=layer, k_scale=k_scale, v_scale=v_scale,
        k_self=k_self, v_self=v_self, interpret=interpret,
        force_kernel=force_kernel,
    )
