"""Encoder-decoder (whisper-style) and VLM (llama-3.2-vision-style) stacks.

Modality frontends are STUBS per the assignment: ``input_specs()`` provides
precomputed frame/patch embeddings of shape (B, S, d_model) / (B, P,
d_model); only the transformer backbone is real (and quantizable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.transformer import _stack, _stack_axes, remat_wrap

__all__ = [
    "init_encdec", "encdec_axes", "encdec_forward", "encdec_prefill",
    "encdec_decode_step", "init_encdec_cache", "encdec_cache_axes",
    "init_vlm", "vlm_axes", "vlm_forward", "vlm_prefill",
    "vlm_decode_step", "init_vlm_cache", "vlm_cache_axes",
]


# ===========================================================================
# Encoder-decoder (whisper backbone; conv audio frontend stubbed)
# ===========================================================================


def _init_enc_block(key, cfg: ArchConfig) -> dict:
    return {
        "ln1": L.init_norm(cfg, cfg.d_model, "ln"),
        "attn": L.init_attention(key, cfg),
        "ln2": L.init_norm(cfg, cfg.d_model, "ln"),
        "mlp": L.init_mlp(L._key(key, "mlp"), cfg),
    }


def _init_dec_block(key, cfg: ArchConfig) -> dict:
    p = _init_enc_block(key, cfg)
    p["ln_x"] = L.init_norm(cfg, cfg.d_model, "ln")
    p["xattn"] = L.init_attention(L._key(key, "xattn"), cfg)
    return p


def _enc_block_axes(cfg):
    return {
        "ln1": L.norm_axes("ln"),
        "attn": L.attention_axes(cfg),
        "ln2": L.norm_axes("ln"),
        "mlp": L.mlp_axes(cfg),
    }


def _dec_block_axes(cfg):
    ax = _enc_block_axes(cfg)
    ax["ln_x"] = L.norm_axes("ln")
    ax["xattn"] = L.attention_axes(cfg)
    return ax


def init_encdec(key, cfg: ArchConfig) -> dict:
    n_enc = cfg.n_enc_layers or cfg.n_layers
    n_dec = cfg.n_dec_layers or cfg.n_layers
    return {
        "embed": L.init_embedding(L._key(key, "embed"), cfg),
        "enc_layers": _stack(
            L._key(key, "enc"), n_enc, lambda k: _init_enc_block(k, cfg)
        ),
        "enc_norm": L.init_norm(cfg, cfg.d_model, "ln"),
        "dec_layers": _stack(
            L._key(key, "dec"), n_dec, lambda k: _init_dec_block(k, cfg)
        ),
        "final_norm": L.init_norm(cfg, cfg.d_model, "ln"),
    }


def encdec_axes(cfg: ArchConfig) -> dict:
    return {
        "embed": L.embedding_axes(cfg),
        "enc_layers": _stack_axes(_enc_block_axes(cfg)),
        "enc_norm": L.norm_axes("ln"),
        "dec_layers": _stack_axes(_dec_block_axes(cfg)),
        "final_norm": L.norm_axes("ln"),
    }


def _encode(params, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""
    B, S, _ = frames.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = frames

    def body(x, lp):
        h = L.norm_apply(lp["ln1"], x, cfg)
        x = x + L.attention_full(
            lp["attn"], h, cfg, positions=positions, causal=False
        )
        h = L.norm_apply(lp["ln2"], x, cfg)
        return x + L.mlp_apply(lp["mlp"], h, cfg), None

    body = remat_wrap(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.norm_apply(params["enc_norm"], x, cfg)


def _dec_block(lp, x, enc, cfg, positions, enc_positions, return_kv=False):
    h = L.norm_apply(lp["ln1"], x, cfg)
    if return_kv:
        a, kv = L.attention_full(
            lp["attn"], h, cfg, positions=positions, causal=True, return_kv=True
        )
    else:
        a = L.attention_full(lp["attn"], h, cfg, positions=positions, causal=True)
        kv = None
    x = x + a
    h = L.norm_apply(lp["ln_x"], x, cfg)
    xa = L.attention_full(
        lp["xattn"], h, cfg, positions=positions, causal=False,
        x_kv=enc, positions_kv=enc_positions,
    )
    x = x + xa
    h = L.norm_apply(lp["ln2"], x, cfg)
    return x + L.mlp_apply(lp["mlp"], h, cfg), kv


def encdec_forward(params, batch: dict, cfg: ArchConfig):
    """batch: {"frames": (B, S_enc, D), "tokens": (B, S_dec)}."""
    enc = _encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_positions = jnp.arange(enc.shape[1], dtype=jnp.int32)
    x = L.embed(params["embed"], tokens)

    def body(x, lp):
        x, _ = _dec_block(lp, x, enc, cfg, positions, enc_positions)
        return x, None

    body = remat_wrap(body, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, jnp.float32(0.0)


def init_encdec_cache(cfg: ArchConfig, batch: int, max_len: int, kv_dtype=None):
    n_dec = cfg.n_dec_layers or cfg.n_layers
    self_c = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_dec, *a.shape)),
        L.init_kv_cache(cfg, batch, max_len, kv_dtype),
    )
    cross_c = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_dec, *a.shape)),
        L.init_kv_cache(cfg, batch, max_len),
    )
    return {"self": self_c, "cross": cross_c}


def encdec_cache_axes(cfg: ArchConfig, int8: bool = False) -> dict:
    return {
        "self": _stack_axes(L.kv_cache_axes(int8)),
        "cross": _stack_axes(L.kv_cache_axes(False)),
    }


def _cross_kv(lp, enc, cfg):
    """Precompute cross-attention K/V from encoder states."""
    B, S, _ = enc.shape
    k = (enc @ lp["xattn"]["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (enc @ lp["xattn"]["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qkv_bias:
        k = k + lp["xattn"]["bk"].reshape(cfg.n_kv_heads, cfg.head_dim)
        v = v + lp["xattn"]["bv"].reshape(cfg.n_kv_heads, cfg.head_dim)
    return k, v


def encdec_prefill(
    params, batch: dict, cfg: ArchConfig, kv_dtype=None, max_len=None
):
    """Encode + decoder prompt prefill.  Returns (logits (B, V), cache)."""
    enc = _encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_positions = jnp.arange(enc.shape[1], dtype=jnp.int32)
    x = L.embed(params["embed"], tokens)
    self0 = L.init_kv_cache(cfg, B, max_len or S, kv_dtype)
    cross0 = L.init_kv_cache(cfg, B, enc.shape[1])

    def body(x, lp):
        x, (k, v) = _dec_block(
            lp, x, enc, cfg, positions, enc_positions, return_kv=True
        )
        ck, cv = _cross_kv(lp, enc, cfg)
        return x, {
            "self": L.cache_store(self0, k, v, 0),
            "cross": L.cache_store(cross0, ck, cv, 0),
        }

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x[:, -1:, :])[:, 0]
    return logits, caches


def encdec_decode_step(params, tokens, cfg: ArchConfig, cache, pos):
    x = L.embed(params["embed"], tokens)

    def body(x, xs):
        lp, cache_l = xs
        h = L.norm_apply(lp["ln1"], x, cfg)
        a, new_self = L.attention_decode(lp["attn"], h, cfg, cache_l["self"], pos)
        x = x + a
        h = L.norm_apply(lp["ln_x"], x, cfg)
        xa, _ = L.attention_decode(
            lp["xattn"], h, cfg, cache_l["cross"], pos, cross=True
        )
        x = x + xa
        h = L.norm_apply(lp["ln2"], x, cfg)
        x = x + L.mlp_apply(lp["mlp"], h, cfg)
        return x, {"self": new_self, "cross": cache_l["cross"]}

    x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = L.norm_apply(params["final_norm"], x, cfg)
    return L.lm_logits(params["embed"], x)[:, 0], new_caches


# ===========================================================================
# VLM (llama-3.2-vision backbone; patch frontend stubbed)
# ===========================================================================
# Layout: n_layers total; every cfg.cross_every-th layer is a gated
# cross-attention layer -> superblocks of (cross_every - 1) self layers
# followed by one cross layer, scanned at the superblock level.


def _vlm_counts(cfg: ArchConfig):
    per = cfg.cross_every
    n_super = cfg.n_layers // per
    n_self = n_super * (per - 1)
    tail = cfg.n_layers - n_super * per  # leftover self layers
    return n_super, per - 1, n_self + tail, tail


def _init_self_block(key, cfg):
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": L.init_attention(key, cfg),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(L._key(key, "mlp"), cfg),
    }


def _init_cross_block(key, cfg):
    return {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "xattn": L.init_attention(key, cfg, cross=True),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(L._key(key, "mlp"), cfg),
        "mlp_gate": jnp.zeros((), jnp.dtype(cfg.dtype)),
    }


def _self_axes(cfg):
    return {
        "ln1": L.norm_axes(),
        "attn": L.attention_axes(cfg),
        "ln2": L.norm_axes(),
        "mlp": L.mlp_axes(cfg),
    }


def _cross_axes(cfg):
    return {
        "ln1": L.norm_axes(),
        "xattn": L.attention_axes(cfg, cross=True),
        "ln2": L.norm_axes(),
        "mlp": L.mlp_axes(cfg),
        "mlp_gate": (),
    }


def init_vlm(key, cfg: ArchConfig) -> dict:
    n_super, per_self, n_self_total, tail = _vlm_counts(cfg)
    return {
        "embed": L.init_embedding(L._key(key, "embed"), cfg),
        "self_layers": _stack(
            L._key(key, "self"), n_self_total, lambda k: _init_self_block(k, cfg)
        ),
        "cross_layers": _stack(
            L._key(key, "cross"), n_super, lambda k: _init_cross_block(k, cfg)
        ),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }


def vlm_axes(cfg: ArchConfig) -> dict:
    return {
        "embed": L.embedding_axes(cfg),
        "self_layers": _stack_axes(_self_axes(cfg)),
        "cross_layers": _stack_axes(_cross_axes(cfg)),
        "final_norm": L.norm_axes(),
    }


def _self_block(lp, x, cfg, positions, return_kv=False):
    h = L.norm_apply(lp["ln1"], x, cfg)
    if return_kv:
        a, kv = L.attention_full(
            lp["attn"], h, cfg, positions=positions, causal=True, return_kv=True
        )
    else:
        a = L.attention_full(lp["attn"], h, cfg, positions=positions, causal=True)
        kv = None
    x = x + a
    h = L.norm_apply(lp["ln2"], x, cfg)
    return x + L.mlp_apply(lp["mlp"], h, cfg), kv


def _cross_block(lp, x, patches, cfg, positions, patch_positions):
    h = L.norm_apply(lp["ln1"], x, cfg)
    a = L.attention_full(
        lp["xattn"], h, cfg, positions=positions, causal=False,
        x_kv=patches, positions_kv=patch_positions,
    )  # tanh gate applied inside via p["gate"]
    x = x + a
    h = L.norm_apply(lp["ln2"], x, cfg)
    f = L.mlp_apply(lp["mlp"], h, cfg)
    gate = jnp.tanh(lp["mlp_gate"].astype(jnp.float32)).astype(x.dtype)
    return x + gate * f


def vlm_forward(params, batch: dict, cfg: ArchConfig):
    """batch: {"tokens": (B, S), "patches": (B, P, D)}."""
    tokens, patches = batch["tokens"], batch["patches"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    patch_positions = jnp.arange(patches.shape[1], dtype=jnp.int32)
    n_super, per_self, n_self_total, tail = _vlm_counts(cfg)
    x = L.embed(params["embed"], tokens)

    main_self = jax.tree.map(
        lambda a: a[: n_super * per_self].reshape(
            n_super, per_self, *a.shape[1:]
        ),
        params["self_layers"],
    )
    tail_self = jax.tree.map(lambda a: a[n_super * per_self :], params["self_layers"])

    def inner(x, lp):
        x, _ = _self_block(lp, x, cfg, positions)
        return x, None

    inner_r = remat_wrap(inner, cfg)

    def superblock(x, lps):
        self_lps, cross_lp = lps
        x, _ = jax.lax.scan(inner_r, x, self_lps)
        x = _cross_block(cross_lp, x, patches, cfg, positions, patch_positions)
        return x, None

    superblock = remat_wrap(superblock, cfg)
    x, _ = jax.lax.scan(superblock, x, (main_self, params["cross_layers"]))
    if tail:
        x, _ = jax.lax.scan(inner_r, x, tail_self)
    x = L.norm_apply(params["final_norm"], x, cfg)
    return x, jnp.float32(0.0)


def init_vlm_cache(cfg: ArchConfig, batch: int, max_len: int, kv_dtype=None):
    n_super, per_self, n_self_total, tail = _vlm_counts(cfg)
    self_c = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_self_total, *a.shape)),
        L.init_kv_cache(cfg, batch, max_len, kv_dtype),
    )
    cross_c = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super, *a.shape)),
        L.init_kv_cache(cfg, batch, cfg.n_patches),
    )
    return {"self": self_c, "cross": cross_c}


def vlm_cache_axes(cfg: ArchConfig, int8: bool = False) -> dict:
    return {
        "self": _stack_axes(L.kv_cache_axes(int8)),
        "cross": _stack_axes(L.kv_cache_axes(False)),
    }


def vlm_prefill(
    params, batch: dict, cfg: ArchConfig, kv_dtype=None, max_len=None
):
    tokens, patches = batch["tokens"], batch["patches"]
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    patch_positions = jnp.arange(patches.shape[1], dtype=jnp.int32)
    n_super, per_self, n_self_total, tail = _vlm_counts(cfg)
    x = L.embed(params["embed"], tokens)
    self0 = L.init_kv_cache(cfg, B, max_len or S, kv_dtype)
    cross0 = L.init_kv_cache(cfg, B, cfg.n_patches)

    main_self = jax.tree.map(
        lambda a: a[: n_super * per_self].reshape(
            n_super, per_self, *a.shape[1:]
        ),
        params["self_layers"],
    )
    tail_self = jax.tree.map(lambda a: a[n_super * per_self :], params["self_layers"])

    def inner(x, lp):
        x, kv = _self_block(lp, x, cfg, positions, return_kv=True)
        return x, L.cache_store(self0, *kv, 0)

    def superblock(x, lps):
        self_lps, cross_lp = lps
        x, self_caches = jax.lax.scan(inner, x, self_lps)
        ck, cv = _cross_kv(cross_lp, patches, cfg)
        x = _cross_block(cross_lp, x, patches, cfg, positions, patch_positions)
        return x, (self_caches, L.cache_store(cross0, ck, cv, 0))

    x, (self_caches, cross_caches) = jax.lax.scan(
        superblock, x, (main_self, params["cross_layers"])
    )
    self_caches = jax.tree.map(
        lambda a: a.reshape(n_super * per_self, *a.shape[2:]), self_caches
    )
    if tail:
        x, tail_caches = jax.lax.scan(inner, x, tail_self)
        self_caches = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), self_caches, tail_caches
        )
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x[:, -1:, :])[:, 0]
    return logits, {"self": self_caches, "cross": cross_caches}


def vlm_decode_step(params, tokens, cfg: ArchConfig, cache, pos):
    x = L.embed(params["embed"], tokens)
    n_super, per_self, n_self_total, tail = _vlm_counts(cfg)

    main_self = jax.tree.map(
        lambda a: a[: n_super * per_self].reshape(
            n_super, per_self, *a.shape[1:]
        ),
        params["self_layers"],
    )
    tail_self = jax.tree.map(lambda a: a[n_super * per_self :], params["self_layers"])
    main_cache = jax.tree.map(
        lambda a: a[: n_super * per_self].reshape(
            n_super, per_self, *a.shape[1:]
        ),
        cache["self"],
    )
    tail_cache = jax.tree.map(lambda a: a[n_super * per_self :], cache["self"])

    def inner(x, xs):
        lp, cache_l = xs
        h = L.norm_apply(lp["ln1"], x, cfg)
        a, new_c = L.attention_decode(lp["attn"], h, cfg, cache_l, pos)
        x = x + a
        h = L.norm_apply(lp["ln2"], x, cfg)
        return x + L.mlp_apply(lp["mlp"], h, cfg), new_c

    def superblock(x, xs):
        self_lps, self_cs, cross_lp, cross_c = xs
        x, new_self = jax.lax.scan(inner, x, (self_lps, self_cs))
        h = L.norm_apply(cross_lp["ln1"], x, cfg)
        a, _ = L.attention_decode(cross_lp["xattn"], h, cfg, cross_c, pos, cross=True)
        x = x + a
        h = L.norm_apply(cross_lp["ln2"], x, cfg)
        gate = jnp.tanh(cross_lp["mlp_gate"].astype(jnp.float32)).astype(x.dtype)
        x = x + gate * L.mlp_apply(cross_lp["mlp"], h, cfg)
        return x, new_self

    x, new_main = jax.lax.scan(
        superblock, x,
        (main_self, main_cache, params["cross_layers"], cache["cross"]),
    )
    new_main = jax.tree.map(
        lambda a: a.reshape(n_super * per_self, *a.shape[2:]), new_main
    )
    if tail:
        x, new_tail = jax.lax.scan(inner, x, (tail_self, tail_cache))
        new_main = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), new_main, new_tail
        )
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x)[:, 0]
    return logits, {"self": new_main, "cross": cache["cross"]}
