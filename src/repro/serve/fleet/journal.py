"""The failover journal: per-request emitted-token records the router
keeps so a replica crash mid-stream is recoverable (DESIGN.md §15).

The router appends every token it relays; when the upstream replica
dies before the ``done`` frame, :meth:`JournalEntry.resume_body` builds
the resubmission — the ORIGINAL request body plus ``resume_tokens`` —
and the replacement replica replays prompt+emitted and continues at the
same emission index.  Because greedy decode is argmax and on-device
sampling keys on ``fold_in(seed, emission_index)``, the spliced
continuation is token-identical to an uninterrupted run; the journal
never needs to store anything but the tokens themselves.

Entries are dropped on completion (the journal holds live requests
only); lifetime counters survive for ``/metricsz``.
"""
from __future__ import annotations

import itertools
from typing import Optional

__all__ = ["JournalEntry", "RequestJournal"]


class JournalEntry:
    """One live request's failover state."""

    __slots__ = ("jid", "body", "tokens", "attempts", "replica", "done",
                 "finish_reason", "stream", "head_sent")

    def __init__(self, jid: int, body: dict, stream: bool):
        self.jid = jid  # router-side id (replica rids are per-process)
        self.body = body  # original parsed JSON body, never mutated
        self.tokens: list[int] = []  # every token relayed to the client
        self.attempts: list[int] = []  # replica indices tried, in order
        self.replica: Optional[int] = None  # current assignment
        self.done = False
        self.finish_reason: Optional[str] = None
        self.stream = stream
        self.head_sent = False  # client HTTP/SSE head already written
        #   (a failover splice must never re-send it)

    @property
    def n_failovers(self) -> int:
        return max(0, len(self.attempts) - 1)

    def assign(self, replica: int) -> None:
        self.attempts.append(replica)
        self.replica = replica

    def record(self, index: int, token: int) -> None:
        """Journal one relayed token.  ``index`` is the global emission
        index from the SSE frame; it must equal the journal length — a
        gap or overlap means the resume splice lost sync."""
        if index != len(self.tokens):
            raise ValueError(
                f"journal splice out of sync: frame index {index}, "
                f"journal holds {len(self.tokens)}")
        self.tokens.append(int(token))

    def resume_body(self) -> dict:
        """The resubmission body: the original request with the
        journaled emissions as ``resume_tokens``.  Everything else —
        seed, max_new, stop_tokens, tenant — rides along unchanged, so
        the continuation draws the same keys the dead replica would
        have."""
        body = dict(self.body)
        body["resume_tokens"] = list(self.tokens)
        return body


class RequestJournal:
    """jid → :class:`JournalEntry` for every in-flight routed request,
    plus lifetime counters (opened/completed/failed/failovers)."""

    def __init__(self):
        self._entries: dict[int, JournalEntry] = {}
        self._ids = itertools.count()
        self.opened = 0
        self.completed = 0
        self.failed = 0
        self.failovers = 0

    def __len__(self) -> int:
        return len(self._entries)

    def open(self, body: dict, stream: bool = True) -> JournalEntry:
        e = JournalEntry(next(self._ids), body, stream)
        self._entries[e.jid] = e
        self.opened += 1
        return e

    def note_failover(self, entry: JournalEntry) -> None:
        self.failovers += 1

    def close(self, entry: JournalEntry, *,
              finish_reason: Optional[str]) -> None:
        """Retire a finished (or abandoned) entry; the tokens are the
        client's now — the journal keeps only counters."""
        entry.done = finish_reason is not None
        entry.finish_reason = finish_reason
        if finish_reason is None:
            self.failed += 1
        else:
            self.completed += 1
        self._entries.pop(entry.jid, None)

    def live(self) -> list:
        return list(self._entries.values())
