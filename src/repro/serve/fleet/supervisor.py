"""Replica supervision: spawn N data-parallel FrontDoor processes,
watch them, restart them, give up deliberately (DESIGN.md §15).

Detection is two-channel, because replicas fail two ways:

- **crash** — the process dies (``kill -9``, OOM, a bug).  The factory's
  liveness poll catches it immediately; in-flight streams surface as
  connection resets the router fails over.
- **wedge** — the process lives and its sockets answer, but the engine
  executor is stuck inside a dispatch.  ``/healthz`` still responds
  (the event loop is fine) and reports ``last_tick_age_s``; past the
  replica's stall threshold it flips to 503 ``wedged`` and the
  supervisor hard-kills and restarts — a drain would hang forever on
  the wedged executor, so SIGKILL is the correct signal here.

Restarts back off exponentially (``backoff_base_s * 2**restarts``,
capped) and a give-up circuit breaker (``max_restarts``) parks a
flapping replica slot in state ``gone`` instead of crash-looping it;
the router routes around ``gone`` slots and the fleet keeps serving on
the survivors.

The :class:`ReplicaFactory` protocol keeps process management swappable:
:class:`ProcessReplicaFactory` runs real ``launch/serve.py --http-port``
subprocesses (the CLI fleet), while tests implement the same four
methods over in-process thread-hosted FrontDoors — the supervisor and
router logic is identical either way.
"""
from __future__ import annotations

import asyncio
import dataclasses
import signal
import socket
import subprocess
import sys
import threading
from typing import Optional

from repro.serve.frontdoor.wire import get_json

__all__ = [
    "FleetReport",
    "ProcessReplicaFactory",
    "ReplicaHandle",
    "Supervisor",
    "free_port",
]


def free_port(host: str = "127.0.0.1") -> int:
    """An ephemeral port that was free a moment ago (bind-then-release;
    the tiny reuse race is retried by the replica's startup gate)."""
    with socket.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]


class ReplicaHandle:
    """One replica slot's live state — shared between the supervisor
    (which writes health/process fields) and the router (which reads
    them to route and writes its own load accounting).  Single event
    loop: no locking."""

    __slots__ = ("index", "host", "port", "pid", "proc", "state",
                 "generation", "restarts", "consec_fail", "inflight",
                 "served", "routed", "pressure", "last_tick_age_s",
                 "ticks", "last_err", "exit_code", "_restart_task")

    def __init__(self, index: int, host: str):
        self.index = index
        self.host = host
        self.port = 0
        self.pid: Optional[int] = None
        self.proc = None  # factory-owned payload (Popen / FrontDoor)
        self.state = "starting"  # starting|healthy|suspect|wedged|dead
        #   |restarting|gone|drained
        self.generation = 0  # bumped per (re)spawn
        self.restarts = 0
        self.consec_fail = 0  # consecutive failed probes
        self.inflight = 0  # router-side: open proxied requests
        self.served = 0  # router-side: streams completed here
        self.routed = 0  # router-side: requests assigned here
        self.pressure = 0.0  # from /healthz (ladder: queue+pool max)
        self.last_tick_age_s: Optional[float] = None
        self.ticks = 0
        self.last_err: Optional[str] = None
        self.exit_code: Optional[int] = None  # final incarnation's
        self._restart_task: Optional[asyncio.Task] = None

    @property
    def available(self) -> bool:
        return self.state == "healthy"

    def to_dict(self) -> dict:
        return {
            "index": self.index, "port": self.port, "pid": self.pid,
            "state": self.state, "generation": self.generation,
            "restarts": self.restarts, "inflight": self.inflight,
            "served": self.served, "routed": self.routed,
            "pressure": self.pressure,
            "last_tick_age_s": self.last_tick_age_s,
            "ticks": self.ticks, "exit_code": self.exit_code,
        }


class ProcessReplicaFactory:
    """Spawn replicas as real ``launch/serve.py`` subprocesses.

    ``base_argv`` is the full replica command line EXCLUDING the bind
    flags (the factory appends ``--http-port``/``--http-host`` per
    spawn).  ``first_spawn_args`` maps replica index → extra argv for
    generation 0 only — per-replica chaos plans (``--replica-fault``)
    must not re-arm on the respawned process, or a ``replica_kill``
    would kill every incarnation and trip the circuit breaker by
    design."""

    def __init__(self, base_argv: list, *, host: str = "127.0.0.1",
                 first_spawn_args: Optional[dict] = None,
                 echo: bool = True):
        self.base_argv = list(base_argv)
        self.host = host
        self.first_spawn_args = dict(first_spawn_args or {})
        self.echo = echo

    def _pump(self, handle: ReplicaHandle, pipe) -> None:
        tag = f"[replica {handle.index}]"
        for line in iter(pipe.readline, b""):
            if self.echo:
                print(f"{tag} {line.decode(errors='replace').rstrip()}",
                      flush=True)
        pipe.close()

    def spawn(self, handle: ReplicaHandle) -> None:
        handle.port = free_port(self.host)
        argv = [*self.base_argv, "--http-host", self.host,
                "--http-port", str(handle.port)]
        if handle.generation == 0:
            argv += self.first_spawn_args.get(handle.index, [])
        proc = subprocess.Popen(
            argv, stdout=subprocess.PIPE, stderr=subprocess.STDOUT
        )
        handle.proc = proc
        handle.pid = proc.pid
        handle.generation += 1
        # drain the pipe on a daemon thread (prefix-echoed) so a chatty
        # replica never blocks on a full pipe buffer
        threading.Thread(
            target=self._pump, args=(handle, proc.stdout), daemon=True
        ).start()

    def alive(self, handle: ReplicaHandle) -> bool:
        return handle.proc is not None and handle.proc.poll() is None

    def kill(self, handle: ReplicaHandle) -> None:
        """Hard stop (SIGKILL) — the wedged-replica path, where SIGTERM
        would wait on an executor that never comes back."""
        if handle.proc is not None and handle.proc.poll() is None:
            handle.proc.kill()
            handle.proc.wait()

    def drain(self, handle: ReplicaHandle,
              timeout_s: float) -> Optional[int]:
        """Graceful stop: SIGTERM (the replica's own drain path — leak
        gate, summary lines, exit code), SIGKILL past the budget.
        Returns the exit code, or None when no process was live."""
        proc = handle.proc
        if proc is None or proc.poll() is not None:
            # already dead before the drain started: no drain ran, so
            # there is no leak gate to read — the crash exit code (e.g.
            # -9) is the FAILURE's code, not a gate verdict
            return None
        proc.send_signal(signal.SIGTERM)
        try:
            proc.wait(timeout=timeout_s)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait()
        return proc.returncode


@dataclasses.dataclass
class FleetReport:
    """What a fleet drain did — the router CLI's exit value.  ``clean``
    aggregates the per-replica leak gates: every replica that was ALIVE
    at drain time must have drained to exit code 0 (a slot whose
    process was already dead or still in restart backoff has no pages
    to leak — the machine is gone)."""

    reason: str
    duration_s: float
    routed: int
    completed: int
    failed: int
    failovers: int
    aborted_streams: int
    replicas: list

    @property
    def clean(self) -> bool:
        return all(r["exit_code"] in (0, None) for r in self.replicas)

    @property
    def exit_code(self) -> int:
        return 0 if self.clean else 1

    def lines(self) -> list:
        out = [
            f"fleet drain[{self.reason}] finished in "
            f"{self.duration_s:.3f}s: {self.completed} completed, "
            f"{self.failed} failed, {self.aborted_streams} aborted",
            f"routed {self.routed} requests, {self.failovers} "
            f"failover(s)",
        ]
        for r in self.replicas:
            out.append(
                f"replica {r['index']}: state={r['state']} "
                f"served={r['served']} restarts={r['restarts']} "
                f"exit={r['exit_code']}")
        out.append("fleet leak gates: " + (
            "clean on every drained replica" if self.clean else "FAILED"))
        return out


class Supervisor:
    """Owns the replica slots: spawn, probe, restart, drain."""

    def __init__(self, factory, n: int, *, host: str = "127.0.0.1",
                 probe_interval_s: float = 0.5,
                 probe_timeout_s: float = 2.0,
                 fail_threshold: int = 3,
                 start_timeout_s: float = 180.0,
                 max_restarts: int = 3,
                 backoff_base_s: float = 0.5,
                 backoff_max_s: float = 10.0,
                 replica_drain_timeout_s: float = 30.0):
        if n < 1:
            raise ValueError(f"fleet needs >= 1 replica, got {n}")
        if max_restarts < 0:
            raise ValueError(f"max_restarts must be >= 0, got {max_restarts}")
        self.factory = factory
        self.host = host
        self.probe_interval_s = probe_interval_s
        self.probe_timeout_s = probe_timeout_s
        self.fail_threshold = fail_threshold
        self.start_timeout_s = start_timeout_s
        self.max_restarts = max_restarts
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.replica_drain_timeout_s = replica_drain_timeout_s
        self.handles = [ReplicaHandle(i, host) for i in range(n)]
        self._draining = False

    # ---- lifecycle -------------------------------------------------------

    async def start(self) -> None:
        """Spawn every replica and wait until each answers /healthz."""
        for h in self.handles:
            self.factory.spawn(h)
        results = await asyncio.gather(
            *(self._wait_ready(h) for h in self.handles))
        if not any(results):
            raise RuntimeError("no replica became healthy at fleet start")

    async def _wait_ready(self, handle: ReplicaHandle) -> bool:
        loop = asyncio.get_running_loop()
        deadline = loop.time() + self.start_timeout_s
        while loop.time() < deadline:
            if self.factory.alive(handle) is False:
                handle.state = "dead"
                handle.last_err = "died during startup"
                return False
            try:
                status, payload = await get_json(
                    handle.host, handle.port, "/healthz",
                    timeout=self.probe_timeout_s)
            except (ConnectionError, OSError, asyncio.TimeoutError):
                await asyncio.sleep(0.1)
                continue
            if status == 200:
                self._mark_healthy(handle, payload)
                return True
            await asyncio.sleep(0.1)
        handle.state = "dead"
        handle.last_err = f"not ready within {self.start_timeout_s}s"
        return False

    def _mark_healthy(self, handle: ReplicaHandle, payload) -> None:
        handle.state = "healthy"
        handle.consec_fail = 0
        handle.last_err = None
        if isinstance(payload, dict):
            handle.ticks = int(payload.get("ticks", handle.ticks))
            handle.pressure = float(payload.get("pressure", 0.0) or 0.0)
            handle.last_tick_age_s = payload.get("last_tick_age_s")

    # ---- probing ---------------------------------------------------------

    async def probe_loop(self) -> None:
        """Heartbeat every replica forever (cancelled at drain)."""
        while True:
            await asyncio.gather(
                *(self.probe_once(h) for h in self.handles))
            await asyncio.sleep(self.probe_interval_s)

    async def probe_once(self, handle: ReplicaHandle) -> None:
        if handle.state in ("restarting", "gone", "drained") \
                or self._draining:
            return
        if self.factory.alive(handle) is False:
            handle.last_err = "process died"
            self._fail(handle, "dead")
            return
        try:
            status, payload = await get_json(
                handle.host, handle.port, "/healthz",
                timeout=self.probe_timeout_s)
        except (ConnectionError, OSError, asyncio.TimeoutError) as e:
            handle.consec_fail += 1
            handle.last_err = f"probe failed: {e!r}"
            if handle.consec_fail >= self.fail_threshold:
                self._fail(handle, "dead")
            elif handle.state == "healthy":
                handle.state = "suspect"
            return
        if status == 200:
            self._mark_healthy(handle, payload)
            return
        wedged = isinstance(payload, dict) \
            and payload.get("status") == "wedged"
        if wedged:
            age = payload.get("last_tick_age_s")
            handle.last_err = f"wedged (last_tick_age_s={age})"
            self._fail(handle, "wedged")
        else:
            # e.g. a draining replica's healthz stays 200; any other
            # non-200 counts toward the failure threshold
            handle.consec_fail += 1
            if handle.consec_fail >= self.fail_threshold:
                self._fail(handle, "dead")

    def _fail(self, handle: ReplicaHandle, state: str) -> None:
        """Mark a replica down and kick off its restart (idempotent)."""
        handle.state = state
        if self._draining or handle._restart_task is not None:
            return
        handle._restart_task = asyncio.get_running_loop().create_task(
            self._restart(handle))

    async def _restart(self, handle: ReplicaHandle) -> None:
        try:
            while not self._draining:
                # hard-kill whatever is left: a wedged process ignores
                # graceful signals by construction
                await asyncio.get_running_loop().run_in_executor(
                    None, self.factory.kill, handle)
                if handle.restarts >= self.max_restarts:
                    handle.state = "gone"  # circuit breaker: give up
                    handle.last_err = (
                        f"gave up after {handle.restarts} restarts")
                    return
                backoff = min(
                    self.backoff_max_s,
                    self.backoff_base_s * (2 ** handle.restarts))
                handle.restarts += 1
                handle.state = "restarting"
                await asyncio.sleep(backoff)
                if self._draining:
                    return
                self.factory.spawn(handle)
                if await self._wait_ready(handle):
                    return  # healthy again; probe loop takes over
                # startup failed: loop — the next lap burns another
                # restart budget slot and doubles the backoff
        finally:
            handle._restart_task = None

    # ---- drain -----------------------------------------------------------

    async def drain(self) -> None:
        """Coordinated fleet drain: stop restarts, SIGTERM every live
        replica concurrently, collect per-replica exit codes (the leak
        gates — each replica exits 0 only if its own gate was clean)."""
        self._draining = True
        for h in self.handles:
            if h._restart_task is not None:
                h._restart_task.cancel()
        loop = asyncio.get_running_loop()

        async def _one(h: ReplicaHandle) -> None:
            if h.state in ("healthy", "suspect"):
                code = await loop.run_in_executor(
                    None, self.factory.drain, h,
                    self.replica_drain_timeout_s)
                h.exit_code = code
                if code is not None:
                    h.state = "drained"
            else:
                # no live serving incarnation (crashed, mid-restart,
                # wedged, gone): there is no leak gate to read — a
                # wedged executor would hang a graceful drain forever
                # and a respawn mid-startup holds no pages yet, so reap
                # whatever is left and record None ("machine is gone")
                await loop.run_in_executor(None, self.factory.kill, h)
                h.exit_code = None

        await asyncio.gather(*(_one(h) for h in self.handles))
