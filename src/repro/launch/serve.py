"""Serving driver: continuous-batching engine over paged KV caches.

    # quantize once, persist packed weights:
    PYTHONPATH=src python -m repro.launch.quantize --arch qwen3-14b --smoke \
        --bits 2 --out-dir /tmp/q

    # serve many concurrent requests from the artifact (no re-quantization):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
        --load-quantized /tmp/q --requests 6 --gen 16

Requests arrive staggered (``--arrival-gap``), join the decode batch while
earlier requests are mid-generation, and decode through the KV-cached
adapter — for quantized models that is the packed
``D⁻¹ → V → quant_matmul → Uᵀ`` path, NOT per-token prefix recompute.
``--paged`` decodes in place over the page pool (paged-attention kernel
path, no per-step dense KV gather); ``--paged-prefill`` additionally runs
each engine tick's prefill chunks as ONE batched cross-request dispatch
over the pool (chunked-prefill kernel path); ``--prefix-cache`` maps
previously-seen prompt-prefix pages (hash trie, refcounted copy-on-write)
into new requests instead of recomputing them; ``--kv-int8`` stores int8
KV pages.  ``--check`` verifies the engine's greedy tokens against the
recompute reference (or, for lossy int8 pages, against the gather-dense
engine oracle over the same page contents) — the oracle always runs the
dense path.

``--mesh DP,MP`` serves tensor-parallel over a (data, model) device mesh
(serve/distributed.py): packed weights shard column/row-parallel, the KV
page pool shards over KV heads, and paged decode runs under shard_map
with no cross-device KV traffic.  On CPU, force a multi-device host
first: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--speculative K`` (with ``--paged``) turns each decode tick into a
draft-and-verify tick: a host-side n-gram prompt-lookup drafter
(``--draft ngram``) proposes up to K tokens per lane from the lane's own
history, and ONE fused (B, K+1) dispatch — the chunked-prefill kernel
reused as the verifier — accepts each lane's longest matching prefix, so
a tick emits 1..K+1 tokens per lane for one weight pass.  Rejected
drafts' K/V is rolled back (``PagedKVPool.truncate``); greedy speculative
decode is token-identical to one-token decode, so it composes with
``--check``.

``--temperature``/``--top-p`` enable per-request nucleus sampling
(greedy when 0 — the default and the only ``--check`` mode);
``--stop-token`` (repeatable) finishes a request early on emission.  On
the paged path the softmax/top-p draw runs ON DEVICE, fused into the
decode/verify dispatch with per-request ``fold_in`` keys;
``--host-sample`` keeps the host-side numpy draw for debugging (the two
backends draw different — but each reproducible — non-greedy streams).

``--deadline-s`` / ``--max-queue`` / ``--screen-logits`` turn on the
robustness layer (DESIGN.md §12): per-request wall-clock deadlines
enforced at tick boundaries, bounded-queue admission backpressure, and a
per-lane NaN/Inf logit screen that quarantines a poisoned request without
touching its co-batched neighbours.  ``--fault-plan SPEC`` arms seeded
deterministic fault injection (serve/faults.py) for chaos drills — e.g.
``'alloc_fail@rid=0;nan_logits@rid=2;cancel@rid=4,tick=6'`` — and
composes with ``--check``: surviving requests must still match the
fault-free oracle token-for-token, early-terminated ones as an exact
prefix, and the run fails if any KV page leaks.

``--trace-out PATH`` records per-tick spans (step phases, fused
dispatches, request lifecycle events) into a ring buffer and writes a
Chrome/Perfetto trace-event JSON on exit; ``--trace-sync`` blocks on the
KV pool at span edges so durations measure device time rather than async
dispatch enqueue; ``--metrics-every SECS`` prints periodic one-line
metric snapshots to stderr (serve/telemetry.py — all off by default,
with a one-no-op-call hot-path cost when off).

Serving-quality canaries (DESIGN.md §13; serve/quality.py):
``--canary-every SECS`` runs a teacher-forced NLL probe over a pinned
canary prompt set through the dense reference trunk at that period (plus
once at run start) — out-of-band, the KV pool is untouched, so live
traffic stays token-identical; ``--shadow-rate F`` re-scores a
deterministic crc32-selected fraction of finished requests against the
same dense oracle and histograms max-abs-logit-diff / token-flip-rate.
``--quality-baseline PATH`` (with ``--load-quantized``) compares the
artifact's quality manifest against a stored baseline
(``launch/quality_report.py --write-baseline``) and warns on layers
whose proxy loss regressed beyond ``--quality-threshold``;
``--quality-strict`` refuses to serve instead.

``--fleet N`` (DESIGN.md §15; serve/fleet/) serves N data-parallel
replica processes behind one router on ``--router-port``: each replica
is this same CLI with the fleet flags stripped and an ephemeral
``--http-port`` appended, supervised with health probes (heartbeat +
tick-stall watchdog), exponential-backoff restarts and a give-up
circuit breaker.  The router balances by sticky prefix affinity with
least-loaded fallback, passes typed rejections through unchanged, and
journals every relayed token so a replica crash mid-stream fails over
to a survivor with a token-identical spliced continuation (greedy and
on-device-sampled paths).  ``--replica-fault IDX:SPEC`` arms a
fault plan on one replica's FIRST incarnation only — e.g.
``--replica-fault '1:replica_kill@tick=40'`` for a crash drill —
while a plain ``--fault-plan`` would re-arm on every respawn.
SIGTERM on the router runs the coordinated fleet drain (stop
admission, finish streams, drain every replica, aggregate leak
gates).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.quantizer import QuipConfig
from repro.data import make_calibration
from repro.models import build_model

__all__ = ["greedy_generate", "quantized_generate", "build_engine", "main"]

# flags that configure the fleet parent (router + supervisor) and must
# NOT reach replica child processes; --http-port/--http-host are
# stripped too because the factory appends fresh ones per spawn
_FLEET_ONLY_FLAGS = frozenset((
    "--fleet", "--router-port", "--probe-interval-s", "--max-restarts",
    "--restart-backoff-s", "--replica-fault", "--http-port",
    "--http-host",
))


def _replica_argv(argv: list) -> list:
    """The replica child command tail: ``argv`` minus the fleet-only
    flags (handles both ``--flag value`` and ``--flag=value``).  Flags
    must be spelled out in full on a fleet command line — argparse
    prefix abbreviations would slip past this filter."""
    out, i = [], 0
    while i < len(argv):
        arg = argv[i]
        if arg.split("=", 1)[0] in _FLEET_ONLY_FLAGS:
            i += 1 if "=" in arg else 2
            continue
        out.append(arg)
        i += 1
    return out


def greedy_generate(model, params, prompt, gen: int, kv_dtype=None):
    """Reference fp path: Model.prefill + decode_step (dense batch cache)."""
    B, S = prompt.shape
    logits, cache = model.prefill(
        params, {"tokens": prompt}, kv_dtype=kv_dtype, max_len=S + gen
    )
    toks = [jnp.argmax(logits, -1)[:, None]]
    decode = jax.jit(model.decode_step)
    for i in range(gen - 1):
        logits, cache = decode(params, toks[-1], cache, jnp.int32(S + i))
        toks.append(jnp.argmax(logits, -1)[:, None])
    return jnp.concatenate(toks, axis=1)


def quantized_generate(qm, prompt, gen: int):
    """Reference recompute path: full-prefix quantized forward per token
    (O(S^2) per token — kept as the equivalence oracle for the engine's
    cached decode; see tests/test_serve.py)."""
    toks = prompt
    for _ in range(gen):
        logits = qm.logits(toks)[:, -1]
        toks = jnp.concatenate([toks, jnp.argmax(logits, -1)[:, None]], axis=1)
    return toks[:, prompt.shape[1]:]


def build_engine(adapter, *, max_seq_len, args, paged=None,
                 paged_prefill=None, prefix_cache=None,
                 speculative=None, faults=None, robust=True,
                 tenants=None) -> "Engine":
    from repro.serve import Engine, EngineConfig

    paged = getattr(args, "paged", False) if paged is None else paged
    ecfg = EngineConfig(
        tenants=tenants if robust else None,
        max_seq_len=max_seq_len,
        n_slots=args.slots,
        page_size=args.page_size,
        n_pages=args.pages,
        token_budget=args.token_budget,
        prefill_chunk=args.prefill_chunk,
        paged_decode=paged,
        paged_prefill=(
            getattr(args, "paged_prefill", False)
            if paged_prefill is None else paged_prefill
        ),
        prefix_cache=(
            getattr(args, "prefix_cache", False)
            if prefix_cache is None else prefix_cache
        ),
        kv_int8=getattr(args, "kv_int8", False),
        speculative_k=(
            getattr(args, "speculative", 0) if speculative is None
            else speculative
        ),
        draft=getattr(args, "draft", "ngram"),
        # the fused on-device draw is the paged-path default; --host-sample
        # keeps the host-side numpy draw for debugging
        device_sample=paged and not getattr(args, "host_sample", False),
        # robustness knobs stay off for reference oracles (robust=False):
        # an oracle must finish every request even under a chaos drill
        deadline_s=getattr(args, "deadline_s", None) if robust else None,
        max_queue=getattr(args, "max_queue", None) if robust else None,
        screen_logits=(
            getattr(args, "screen_logits", False) if robust else False
        ),
        # quality canaries follow the robustness gating: a --check oracle
        # must stay a bare reference run (no probes, no shadow re-scores)
        canary_every=getattr(args, "canary_every", None) if robust else None,
        shadow_rate=getattr(args, "shadow_rate", 0.0) if robust else 0.0,
        shadow_seed=getattr(args, "seed", 0),
    )
    return Engine(adapter, ecfg, faults=faults if robust else None)


def _serve_batch_fallback(model, params, prompts, args) -> int:
    """Non-dense families: the engine adapter is dense-only for now
    (ROADMAP open item); serve one fixed batch through the family's own
    Model.prefill/decode_step path, as the pre-engine driver did."""
    t0 = time.time()
    out = greedy_generate(model, params, prompts, args.gen)
    dt = time.time() - t0
    total = out.shape[0] * out.shape[1]
    print(f"[serve] fp {model.cfg.name} (batch fallback, family="
          f"{model.cfg.family}): {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6,
                    help="number of concurrent requests to serve")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--arrival-gap", type=float, default=0.02,
                    help="stagger between request arrivals (s)")
    # weights
    ap.add_argument("--quantize", action="store_true",
                    help="run the QuIP pipeline in-process before serving")
    ap.add_argument("--load-quantized", default=None, metavar="DIR",
                    help="serve packed weights from a quantize.py --out-dir "
                         "artifact (skips the quantization pipeline)")
    ap.add_argument("--bits", type=int, default=2)
    # engine knobs
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=None,
                    help="physical KV pages (default: no overcommit)")
    ap.add_argument("--token-budget", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--paged", action="store_true",
                    help="decode in place over the page pool (paged-"
                         "attention kernel path; no per-step dense KV "
                         "gather) instead of the gather-dense oracle")
    ap.add_argument("--paged-prefill", action="store_true",
                    help="prefill as ONE batched cross-request dispatch "
                         "per engine tick over the page pool (chunked-"
                         "prefill kernel path) instead of a B=1 "
                         "gather-dense loop")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="hash-trie prompt-prefix cache over full KV "
                         "pages: identical prompt prefixes are admitted "
                         "with their pages mapped (refcounted, copy-on-"
                         "write), not recomputed")
    ap.add_argument("--kv-int8", action="store_true",
                    help="store KV pages int8 with per-(token, head) scales")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="speculative decode (needs --paged): draft up to "
                         "K tokens per lane per tick and verify them in "
                         "ONE fused (B, K+1) dispatch — the chunked-"
                         "prefill kernel as verifier; rejected drafts' "
                         "K/V is rolled back")
    ap.add_argument("--draft", default="ngram", choices=("ngram",),
                    help="self-drafter for --speculative (ngram = prompt-"
                         "lookup over each lane's own token history)")
    ap.add_argument("--host-sample", action="store_true",
                    help="keep the host-side numpy softmax/top-p draw "
                         "(debugging); default on the paged path is the "
                         "on-device draw fused into the decode/verify "
                         "dispatch (per-request fold_in keys)")
    ap.add_argument("--mesh", default=None, metavar="DP,MP",
                    help="serve tensor-parallel over a (data, model) mesh: "
                         "packed weights + KV page pool + paged decode all "
                         "shard over the model axis (serve/distributed.py)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (with --temperature > 0)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="per-request sampling seed base")
    ap.add_argument("--stop-token", type=int, action="append", default=None,
                    help="finish a request when it emits this token "
                         "(repeatable)")
    ap.add_argument("--check", action="store_true",
                    help="verify engine tokens against the recompute path")
    # failure domains (DESIGN.md §12; all off by default)
    ap.add_argument("--deadline-s", type=float, default=None, metavar="SECS",
                    help="per-request wall-clock deadline from arrival, "
                         "enforced at tick boundaries; an expired request "
                         "FAILS with finish_reason='deadline'")
    ap.add_argument("--max-queue", type=int, default=None, metavar="N",
                    help="bounded admission queue: submits past N pending "
                         "requests raise a retryable AdmissionRejected "
                         "instead of queueing unboundedly")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="deterministic fault injection for chaos drills: "
                         "'kind[@key=val,...][;rule...]' with kinds "
                         "alloc_fail|pool_exhausted|nan_logits|"
                         "dispatch_error|corrupt_shard|cancel and keys "
                         "tick/rid/shard/times, e.g. "
                         "'alloc_fail@rid=0;cancel@rid=4,tick=6'")
    # streaming front door (DESIGN.md §14; serve/frontdoor/)
    ap.add_argument("--http-port", type=int, default=None, metavar="PORT",
                    help="serve over HTTP/SSE instead of the fixed batch: "
                         "start the asyncio front door on this port (0 = "
                         "ephemeral), POST /v1/generate + healthz/readyz/"
                         "metricsz; SIGTERM/SIGINT drain gracefully")
    ap.add_argument("--http-host", default="127.0.0.1",
                    help="front-door bind address (default 127.0.0.1)")
    ap.add_argument("--tenants", default=None, metavar="SPEC",
                    help="per-tenant admission policies, comma-separated "
                         "'name:rate:burst:priority' (rate in req/s, empty "
                         "or 'inf' = unlimited; priority 0 = highest), "
                         "e.g. 'paid:inf:4:0,free:2.0:4:1'")
    ap.add_argument("--drain-timeout-s", type=float, default=5.0,
                    metavar="SECS",
                    help="graceful-drain budget: in-flight lanes past this "
                         "get cancelled (pages still released exactly)")
    ap.add_argument("--tick-stall-s", type=float, default=10.0,
                    metavar="SECS",
                    help="tick-stall watchdog threshold: /healthz flips "
                         "to 503 'wedged' when the engine has not "
                         "COMPLETED a tick in this long (the supervisor "
                         "hard-restarts wedged replicas)")
    # replica fleet (DESIGN.md §15; serve/fleet/)
    ap.add_argument("--fleet", type=int, default=None, metavar="N",
                    help="serve N data-parallel replica processes (each "
                         "this CLI + an ephemeral --http-port) behind "
                         "one supervised router; implies HTTP serving")
    ap.add_argument("--router-port", type=int, default=0, metavar="PORT",
                    help="fleet router bind port (0 = ephemeral; with "
                         "--fleet)")
    ap.add_argument("--probe-interval-s", type=float, default=0.5,
                    metavar="SECS",
                    help="supervisor health-probe period (with --fleet)")
    ap.add_argument("--max-restarts", type=int, default=3, metavar="N",
                    help="give-up circuit breaker: park a replica slot "
                         "as 'gone' after N restarts (with --fleet)")
    ap.add_argument("--restart-backoff-s", type=float, default=0.5,
                    metavar="SECS",
                    help="base restart backoff, doubling per restart "
                         "(with --fleet)")
    ap.add_argument("--replica-fault", action="append", default=None,
                    metavar="IDX:SPEC",
                    help="arm a --fault-plan SPEC on replica IDX's FIRST "
                         "incarnation only (repeatable; with --fleet) — "
                         "e.g. '1:replica_kill@tick=40' for a crash "
                         "drill whose respawn comes back clean")
    ap.add_argument("--no-ladder", action="store_true",
                    help="disable the load-shedding degradation ladder "
                         "(spec K shrink -> spec off -> shed lowest class)")
    ap.add_argument("--screen-logits", action="store_true",
                    help="NaN/Inf-screen every step's logits per lane "
                         "(one fused device reduction); a poisoned lane "
                         "is quarantined (FAILS with "
                         "finish_reason='nan_logits'), co-batched lanes "
                         "decode on unharmed")
    # telemetry (serve/telemetry.py; off by default — NULL_TRACER costs
    # one no-op call per span site)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record per-tick spans (step phases + fused "
                         "dispatches + request lifecycle events) and write "
                         "a Chrome/Perfetto trace-event JSON here "
                         "(load at ui.perfetto.dev)")
    ap.add_argument("--trace-sync", action="store_true",
                    help="block on the KV pool at span edges so span "
                         "durations measure device time, not async "
                         "dispatch enqueue (needs --trace-out; slows "
                         "serving — measurement mode only)")
    ap.add_argument("--metrics-every", type=float, default=None,
                    metavar="SECS",
                    help="print a one-line metrics snapshot (throughput "
                         "counters, pool occupancy, TTFT/ITL/e2e p50+p99) "
                         "to stderr every SECS seconds of engine time")
    # serving-quality canaries (DESIGN.md §13; serve/quality.py)
    ap.add_argument("--canary-every", type=float, default=None,
                    metavar="SECS",
                    help="teacher-forced NLL probe over a pinned canary "
                         "prompt set every SECS seconds (plus once at run "
                         "start) — out-of-band over the dense reference "
                         "trunk, live traffic stays token-identical")
    ap.add_argument("--canary-prompts", type=int, default=2,
                    help="canary set size (pinned sequences per probe)")
    ap.add_argument("--canary-len", type=int, default=16,
                    help="canary sequence length (tokens)")
    ap.add_argument("--shadow-rate", type=float, default=0.0, metavar="F",
                    help="re-score this deterministic fraction of finished "
                         "requests against the dense oracle trunk "
                         "(max-abs-logit-diff + token-flip-rate "
                         "histograms; crc32 selection, not hash())")
    ap.add_argument("--quality-baseline", default=None, metavar="PATH",
                    help="with --load-quantized: compare the artifact's "
                         "quality manifest against this baseline JSON "
                         "(launch/quality_report.py --write-baseline) and "
                         "warn on proxy-loss regressions")
    ap.add_argument("--quality-threshold", type=float, default=1.2,
                    help="regression ratio for --quality-baseline "
                         "(default 1.2x)")
    ap.add_argument("--quality-strict", action="store_true",
                    help="refuse to serve (exit nonzero) on any "
                         "--quality-baseline regression instead of warning")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.serve import CachedDecoder, DistributedCachedDecoder, \
        make_serving_mesh
    from repro.serve.artifacts import ArtifactCorruption, load_quantized
    from repro.serve.faults import AdmissionRejected, parse_fault_plan
    from repro.serve.scheduler import RequestState, SamplingParams

    faults = None
    if args.fault_plan:
        try:
            faults = parse_fault_plan(args.fault_plan)
        except ValueError as e:
            raise SystemExit(f"--fault-plan: {e}")

    tenants = None
    if args.tenants:
        from repro.serve.frontdoor.admission import parse_tenants

        try:
            tenants = parse_tenants(args.tenants)
        except ValueError as e:
            raise SystemExit(f"--tenants: {e}")
    if args.http_port is not None and args.check:
        raise SystemExit(
            "--check drives a fixed in-process workload; the HTTP front "
            "door serves whatever clients send — drop one of the two"
        )
    if args.speculative and not args.paged:
        raise SystemExit(
            "--speculative verifies drafts over the paged pool (the "
            "chunked-prefill kernel path); add --paged"
        )
    if args.speculative < 0:
        raise SystemExit(f"--speculative must be >= 0, got {args.speculative}")
    if args.temperature == 0 and args.top_p < 1.0:
        raise SystemExit(
            "--top-p only applies to non-greedy decoding; pass "
            "--temperature > 0 (temperature 0 is exact greedy argmax)"
        )
    if args.check and args.temperature > 0:
        raise SystemExit(
            "--check verifies greedy tokens against a greedy oracle; "
            "drop --temperature (or --check)"
        )
    if args.check and args.stop_token:
        raise SystemExit(
            "--check compares full fixed-length token streams; the "
            "references don't model early stop — drop --stop-token"
        )
    if args.trace_sync and not args.trace_out:
        raise SystemExit(
            "--trace-sync sharpens span timing for a recorded trace; "
            "add --trace-out PATH"
        )
    if not 0.0 <= args.shadow_rate <= 1.0:
        raise SystemExit(
            f"--shadow-rate must be in [0, 1], got {args.shadow_rate}"
        )
    if args.canary_every is not None and args.canary_every <= 0:
        raise SystemExit(
            f"--canary-every must be > 0 seconds, got {args.canary_every}"
        )
    if args.quality_baseline and not args.load_quantized:
        raise SystemExit(
            "--quality-baseline audits an artifact's quality manifest; "
            "add --load-quantized DIR (quantize with --out-dir first)"
        )
    if args.quality_strict and not args.quality_baseline:
        raise SystemExit(
            "--quality-strict needs a baseline to enforce; add "
            "--quality-baseline PATH"
        )
    if args.fleet is None:
        for flag, val, default in (
                ("--router-port", args.router_port, 0),
                ("--replica-fault", args.replica_fault, None)):
            if val != default:
                raise SystemExit(f"{flag} only applies to a replica "
                                 f"fleet; add --fleet N")
    else:
        if args.fleet < 1:
            raise SystemExit(f"--fleet needs >= 1 replica, "
                             f"got {args.fleet}")
        if args.check:
            raise SystemExit(
                "--check drives a fixed in-process workload; --fleet "
                "serves HTTP replicas — drop one of the two"
            )
        if args.http_port is not None:
            raise SystemExit(
                "--fleet assigns each replica its own ephemeral "
                "--http-port; use --router-port for the client-facing "
                "port"
            )
    if args.fleet is not None:
        # fleet parent: never builds a model — it spawns N replica
        # copies of this CLI (fleet flags stripped, fresh --http-port
        # appended per spawn) and serves the router in front of them
        import asyncio

        from repro.serve.fleet import (
            FleetRouter,
            ProcessReplicaFactory,
            Supervisor,
        )

        first_spawn: dict[int, list] = {}
        for spec in args.replica_fault or ():
            idx_s, sep, plan = spec.partition(":")
            if not sep or not idx_s.isdigit():
                raise SystemExit(
                    f"--replica-fault expects IDX:SPEC, got {spec!r}")
            idx = int(idx_s)
            if not 0 <= idx < args.fleet:
                raise SystemExit(
                    f"--replica-fault: replica {idx} out of range for "
                    f"--fleet {args.fleet}")
            try:  # validate here, where the error is attributable
                parse_fault_plan(plan)
            except ValueError as e:
                raise SystemExit(f"--replica-fault {spec!r}: {e}")
            first_spawn.setdefault(idx, []).extend(
                ["--fault-plan", plan])
        tail = _replica_argv(
            list(argv) if argv is not None else sys.argv[1:])
        factory = ProcessReplicaFactory(
            [sys.executable, "-m", "repro.launch.serve", *tail],
            host=args.http_host, first_spawn_args=first_spawn,
        )
        sup = Supervisor(
            factory, args.fleet, host=args.http_host,
            probe_interval_s=args.probe_interval_s,
            max_restarts=args.max_restarts,
            backoff_base_s=args.restart_backoff_s,
            replica_drain_timeout_s=args.drain_timeout_s + 30.0,
        )
        router = FleetRouter(
            sup, host=args.http_host, port=args.router_port,
            drain_timeout_s=args.drain_timeout_s,
        )
        report = asyncio.run(router.serve_forever())
        return report.exit_code
    mesh = None
    if args.mesh:
        try:
            dp, mp = (int(x) for x in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh expects DP,MP (e.g. 1,2), "
                             f"got {args.mesh!r}")
        try:
            mesh = make_serving_mesh(dp, mp)
        except ValueError as e:
            raise SystemExit(f"--mesh: {e}")

    qm = None
    if args.load_quantized:
        try:
            if mesh is not None:
                # leaves stream straight onto their mesh placement
                adapter, meta = DistributedCachedDecoder.load(
                    args.load_quantized, mesh=mesh, load_faults=faults
                )
                cfg = adapter.cfg
                if args.check:  # plain copy for the single-device oracle
                    qm, _ = load_quantized(args.load_quantized)
            else:
                qm, meta = load_quantized(args.load_quantized, faults=faults)
                cfg = qm.cfg
                adapter = CachedDecoder.from_quantized(qm)
        except ArtifactCorruption as e:
            # integrity failure is its own domain: the artifact EXISTS but
            # its bytes don't match the manifest — don't suggest re-pathing
            raise SystemExit(f"--load-quantized: {e}")
        except (FileNotFoundError, ValueError, KeyError) as e:
            raise SystemExit(
                f"--load-quantized: {e} (expected a directory written by "
                f"launch/quantize.py --out-dir)"
            )
        label = f"quip-{meta['quip_config']['bits']}bit[artifact]"
        print(f"[serve] loaded quantized artifact: {cfg.name} "
              f"{meta['quip_config']['bits']}-bit ({args.load_quantized})")
        if args.quality_baseline:
            from repro.serve.quality import check_artifact_quality, \
                load_baseline

            try:
                baseline = load_baseline(args.quality_baseline)
            except (FileNotFoundError, ValueError) as e:
                raise SystemExit(f"--quality-baseline: {e}")
            regressions = check_artifact_quality(
                meta.get("quality"), baseline,
                threshold=args.quality_threshold,
            )
            for r in regressions:
                print(f"[serve] QUALITY REGRESSION {r['layer']}: "
                      f"proxy {r['baseline']:.4g} -> "
                      f"{'missing' if r['current'] is None else format(r['current'], '.4g')}"
                      f" (> {args.quality_threshold:.2f}x baseline)")
            if regressions and args.quality_strict:
                raise SystemExit(
                    f"refusing to serve: {len(regressions)} layer(s) "
                    f"regressed beyond {args.quality_threshold:.2f}x the "
                    f"quality baseline (drop --quality-strict to serve "
                    f"anyway)"
                )
            if not regressions:
                print(f"[serve] quality baseline OK "
                      f"({len(baseline['proxy_loss'])} layers within "
                      f"{args.quality_threshold:.2f}x)")
    else:
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        if cfg.family != "dense" and mesh is not None:
            raise SystemExit(
                "--mesh drives the dense-family engine adapter; other "
                "families serve through the batch fallback (single device)"
            )
        if cfg.family != "dense":
            if args.quantize:
                raise SystemExit(
                    "--quantize drives the dense family; per-layer "
                    "quantization for other families goes through "
                    "repro.core.quantize_layer directly"
                )
            if args.check:
                raise SystemExit(
                    "--check verifies the engine against the reference "
                    "decode path, but non-dense families serve THROUGH "
                    "that reference path (engine adapter is dense-only; "
                    "ROADMAP open item) — nothing to check"
                )
            prompts = make_calibration(
                cfg.vocab, n_segments=args.requests, seg_len=args.prompt_len,
                seed=args.seed + 3,
            ).tokens
            return _serve_batch_fallback(model, params, prompts, args)
        if args.quantize:
            from repro.launch.quantize import quantize_dense_model

            calib = make_calibration(cfg.vocab, n_segments=8, seg_len=64,
                                     seed=args.seed + 7)
            qcfg = QuipConfig(bits=args.bits, method="ldlq", use_kernel=False)
            qm = quantize_dense_model(params, cfg, qcfg, calib.tokens,
                                      seed=args.seed, verbose=False)
            adapter = (
                DistributedCachedDecoder.from_quantized(qm, mesh=mesh)
                if mesh is not None else CachedDecoder.from_quantized(qm)
            )
            label = f"quip-{args.bits}bit"
        else:
            adapter = (
                DistributedCachedDecoder.from_model(model, params, mesh=mesh)
                if mesh is not None else CachedDecoder.from_model(model, params)
            )
            label = "fp"

    prompts = make_calibration(
        cfg.vocab, n_segments=args.requests, seg_len=args.prompt_len,
        seed=args.seed + 3,
    ).tokens

    engine = build_engine(
        adapter, max_seq_len=args.prompt_len + args.gen, args=args,
        faults=faults, tenants=tenants,
    )
    if args.canary_every is not None:
        # pinned OFF the traffic seed stream: the canary set must stay
        # fixed across runs for the NLL gauge to be comparable
        engine.attach_canary(make_calibration(
            cfg.vocab, n_segments=args.canary_prompts,
            seg_len=args.canary_len, seed=args.seed + 1234,
        ).tokens)
    tracer = None
    if args.trace_out:
        from repro.serve import Tracer

        tracer = Tracer(sync=args.trace_sync)
        engine.attach_tracer(tracer)
    if mesh is not None:
        pool = engine.pool
        print(f"[serve] mesh data={dp} model={mp}: KV pool "
              f"{pool.total_bytes()} B total, {pool.device_bytes()} B/device")
    if args.http_port is not None:
        import asyncio

        from repro.serve.frontdoor import FrontDoor

        fd = FrontDoor(
            engine, host=args.http_host, port=args.http_port,
            drain_timeout_s=args.drain_timeout_s, ladder=not args.no_ladder,
            tick_stall_s=args.tick_stall_s,
        )
        report = asyncio.run(fd.serve_forever())
        s = engine.summary()
        for line in report.lines():
            print(f"[serve] {line}")
        fin = " ".join(
            f"{k}={v}" for k, v in sorted(s.items())
            if k.startswith("finish:")
        )
        if fin:
            print(f"[serve] finish reasons: {fin}")
        print(f"[serve] http: requests={s['http_requests']} "
              f"rejections={s['http_rejections']} "
              f"shed={s['shed_requests']} "
              f"disconnects={s['client_disconnects']} "
              f"ladder_escalations={s.get('ladder_escalations', 0)} "
              f"ladder_deescalations={s.get('ladder_deescalations', 0)}")
        if tracer is not None:
            tracer.export_chrome_trace(args.trace_out)
            print(f"[serve] trace: {len(tracer)} spans -> {args.trace_out}")
        return report.exit_code

    stop_tokens = tuple(args.stop_token or ())
    try:  # validate the sampling flags before the admission loop, so bad
        # values don't surface as a misleading pool-capacity error below
        sampling = [
            SamplingParams(temperature=args.temperature, top_p=args.top_p,
                           seed=args.sample_seed + i)
            for i in range(args.requests)
        ]
    except ValueError as e:
        raise SystemExit(f"bad sampling flags: {e}")
    submitted = []  # (prompt index, request) for accepted submissions
    for i in range(args.requests):
        try:
            req = engine.submit(
                np.asarray(prompts[i]), max_new=args.gen,
                arrival=i * args.arrival_gap,
                sampling=sampling[i],
                stop_tokens=stop_tokens,
            )
        except AdmissionRejected as e:
            if e.retryable:
                # bounded queue backpressure: a real client would retry
                # with backoff; the fixed-workload driver just reports it
                print(f"[serve] request {i} rejected (retryable): {e}")
                continue
            raise SystemExit(f"cannot admit request: {e} "
                             f"(grow --pages / --page-size or shrink --gen)")
        except ValueError as e:
            raise SystemExit(f"cannot admit request: {e}")
        submitted.append((i, req))
    t0 = time.perf_counter()
    interrupted = 0
    try:
        done = engine.run(metrics_every=args.metrics_every)
    except KeyboardInterrupt:
        # ^C is a drain request, not a crash: cancel every live lane
        # (pages released refcount-exactly) and fall through to the same
        # summary lines + leak gate a clean run prints
        interrupted = len(engine.cancel_all())
        done = engine.finished
    dt = time.perf_counter() - t0
    if interrupted:
        print(f"\n[serve] interrupted: drained {interrupted} in-flight "
              f"request(s) as cancelled")
    s = engine.summary()
    total = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {label} {cfg.name}: {len(done)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/dt:.1f} tok/s)")
    n_fin = sum(1 for r in done if r.state is RequestState.FINISHED)
    n_can = sum(1 for r in done if r.state is RequestState.CANCELLED)
    n_fail = sum(1 for r in done if r.state is RequestState.FAILED)
    outcome = (f"[serve] outcomes: finished={n_fin} cancelled={n_can} "
               f"failed={n_fail}")
    if n_fail:
        reasons: dict[str, int] = {}
        for r in done:
            if r.state is RequestState.FAILED:
                reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
        outcome += f" reasons={reasons}"
    if n_can or n_fail or faults is not None:
        print(outcome)
    if faults is not None:
        print(f"[serve] faults injected: {len(faults.log)} "
              f"({'; '.join(e['kind'] for e in faults.log)})")
    # blast-radius invariant: whatever was cancelled/failed/injected, every
    # page must be back (the prefix trie legitimately retains its own refs)
    leaked = engine.pool.pages_in_use - engine.pool.cached_pages
    if leaked != 0 or engine.pool._slots:
        print(f"[serve] FAIL: {leaked} leaked pages, "
              f"{len(engine.pool._slots)} live slots after drain")
        return 1
    print(f"[serve] steps={s['steps']} prefill_tokens={s['prefill_tokens']} "
          f"decode_tokens={s['decode_tokens']} evictions={s['evictions']} "
          f"peak_kv_occupancy={s['peak_occupancy']:.0%}")
    if args.paged_prefill or args.prefix_cache:
        print(f"[serve] prefill_batch_size={s['prefill_batch_size']} "
              f"prefix_hit_tokens={s['prefix_hit_tokens']} "
              f"cached_pages={s['cached_pages']} "
              f"shared_pages={s['shared_pages']} "
              f"cow_copies={s['cow_copies']}")
    if args.speculative:
        print(f"[serve] speculative K={args.speculative}: "
              f"acceptance_rate={s['acceptance_rate']:.2f} "
              f"accepted_per_tick={s['accepted_per_tick']:.2f} "
              f"tokens_per_lane_tick={s['tokens_per_lane_tick']:.2f} "
              f"rolled_back={s['rolled_back_tokens']}")
    if s.get("ttft_s_p50") is not None:
        print(f"[serve] latency: ttft_p50={s['ttft_s_p50'] * 1e3:.1f}ms "
              f"ttft_p99={s['ttft_s_p99'] * 1e3:.1f}ms "
              f"itl_p50={(s['itl_s_p50'] or 0) * 1e3:.2f}ms "
              f"queue_p50={(s['queue_s_p50'] or 0) * 1e3:.1f}ms")
    if args.canary_every is not None:
        print(f"[serve] quality: canary_nll={s['canary_nll']:.6f} "
              f"canary_runs={s['canary_runs']} "
              f"act_absmax={s['act_absmax']:.3g} act_sat={s['act_sat']:.2e}")
    if args.shadow_rate > 0:
        print(f"[serve] shadow: samples={s['shadow_samples']} "
              f"tokens={s['shadow_tokens']} flips={s['shadow_token_flips']} "
              f"max_abs_logit_diff_p99="
              f"{s.get('shadow_max_abs_logit_diff_p99') or 0:.3g} "
              f"flip_rate_p99={s.get('shadow_flip_rate_p99') or 0:.3g}")
    if tracer is not None:
        from repro.serve import phase_breakdown

        tracer.export_chrome_trace(args.trace_out)
        pb = phase_breakdown(tracer.spans)
        phases = " ".join(
            f"{name}={p['time_s'] * 1e3:.0f}ms({p['share']:.0%})"
            for name, p in sorted(
                pb["phases"].items(), key=lambda kv: -kv[1]["time_s"]
            )
        )
        print(f"[serve] trace: {len(tracer)} spans -> {args.trace_out} "
              f"(dropped={tracer.dropped}) coverage={pb['coverage']:.0%} "
              f"{phases}")

    if args.check:
        if args.kv_int8 and not (args.paged or args.paged_prefill):
            raise SystemExit(
                "--kv-int8 --check needs --paged (and/or --paged-prefill): "
                "int8 pages are lossy vs the dense references, so the only "
                "independent oracle is the gather-dense engine over the "
                "same int8 page contents — without a paged path that "
                "oracle IS the engine under test"
            )
        if args.kv_int8:
            # int8 pages are lossy vs the dense references; the oracle is
            # a gather-dense engine decoding the same int8 page contents —
            # always a SINGLE-DEVICE engine, so --mesh --kv-int8 --check
            # verifies TP against the unsharded implementation
            oracle_adapter = adapter
            if mesh is not None:
                oracle_adapter = (
                    CachedDecoder.from_quantized(qm) if qm is not None
                    else CachedDecoder.from_model(model, params)
                )
            oracle = build_engine(
                oracle_adapter, max_seq_len=args.prompt_len + args.gen,
                args=args, paged=False, paged_prefill=False,
                prefix_cache=False, speculative=0, robust=False,
            )
            oref = [
                oracle.submit(np.asarray(prompts[i]), max_new=args.gen)
                for i in range(args.requests)
            ]
            oracle.run()
            ref = np.stack([
                np.asarray(r.out_tokens, np.int32)
                for r in sorted(oref, key=lambda r: r.rid)
            ])
            ref_label = "gather-dense int8 engine"
        elif qm is not None:
            ref = np.asarray(quantized_generate(qm, jnp.asarray(prompts), args.gen))
            ref_label = "quantized recompute"
        else:
            ref = np.asarray(greedy_generate(model, params, prompts, args.gen))
            ref_label = "fp prefill/decode"
        # FINISHED rows must match the oracle token-for-token at full
        # length; CANCELLED/FAILED rows must be an exact PREFIX of it —
        # a fault may stop a request early but never corrupt its stream
        total_cmp = matched = 0
        truncated_ok = True
        for i, r in submitted:
            out = np.asarray(r.out_tokens, np.int32)
            exp = np.asarray(ref[i], np.int32)
            if r.state is RequestState.FINISHED:
                if out.size != exp.size:
                    truncated_ok = False
                    continue
            else:
                exp = exp[: out.size]
            total_cmp += exp.size
            matched += int(np.sum(out == exp))
        agree = matched / max(1, total_cmp)
        print(f"[serve] check vs {ref_label}: token agreement {agree:.2%} "
              f"over {total_cmp} tokens")
        if agree < 1.0 or not truncated_ok:
            print(f"[serve] FAIL: engine cached decode diverged from the "
                  f"{ref_label} oracle")
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
