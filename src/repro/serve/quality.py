"""Quantization-quality observability (DESIGN.md §13).

Performance telemetry (serve/telemetry.py) answers "how fast is the
engine"; this module answers "is the model it serves still the model we
audited".  Three layers share it:

  * **quantize time** — :func:`build_quality_section` folds the per-layer
    quality reports ``core.quantizer.quantize_layer`` emits (µ(W)/µ(H)
    pre/post incoherence, Hessian spectrum, absolute + H-relative proxy
    loss, error norms, wall-clock) into the ``quality`` section of the
    artifact manifest, next to the shard digests — quality ships WITH the
    weights it describes.

  * **load time** — :func:`check_artifact_quality` compares a loaded
    artifact's quality section against a stored baseline (a JSON file
    written by ``launch/quality_report.py --write-baseline``) and returns
    the layers whose proxy loss regressed beyond a threshold ratio;
    ``launch/serve.py --quality-baseline`` warns on them (or refuses with
    ``--quality-strict``).  Artifacts saved before quality manifests
    existed compare as "unknown" with a warning, mirroring the
    pre-digest-manifest load path.

  * **serve time** — :func:`canary_probe` runs a teacher-forced forward
    over a pinned canary prompt set through the adapter's dense reference
    trunk (out-of-band: the KV pool is never touched, so live traffic
    stays token-identical) and returns the canary NLL plus per-layer
    activation absmax/saturation; :class:`ShadowSampler` re-scores a
    deterministic fraction of finished requests against the same dense
    oracle and histograms max-abs-logit-diff and token-flip counts — the
    one-shot ``--check`` generalized into an always-on sampled monitor.

Shadow selection hashes ``(seed, rid)`` with crc32, NOT ``hash()`` —
``PYTHONHASHSEED`` must never decide which requests get audited.
"""
from __future__ import annotations

import json
import pathlib
import warnings
import zlib
from typing import Optional

import numpy as np

__all__ = [
    "QUALITY_FORMAT",
    "ShadowSampler",
    "aggregate_quality",
    "build_quality_section",
    "canary_probe",
    "check_artifact_quality",
    "load_baseline",
    "teacher_forced_logits",
    "teacher_forced_nll",
    "write_baseline",
]

QUALITY_FORMAT = 1


# ---------------------------------------------------------------------------
# quality manifest section (quantize time)
# ---------------------------------------------------------------------------


def build_quality_section(stats: list) -> dict:
    """Fold ``QuantizedModel.stats`` (one dict per block, keyed by linear
    name) into the manifest ``quality`` section::

        {"format": 1,
         "layers": {"<block>/<linear>": <quantize_layer stats dict>},
         "aggregate": {...}}
    """
    layers = {
        f"{i}/{name}": dict(st)
        for i, blk in enumerate(stats)
        for name, st in blk.items()
        if st  # collect_stats=False layers carry no report
    }
    return {
        "format": QUALITY_FORMAT,
        "layers": layers,
        "aggregate": aggregate_quality(layers),
    }


def aggregate_quality(layers: dict) -> dict:
    """Model-level rollup of the per-layer reports."""
    if not layers:
        return {}
    vals = lambda k: [st[k] for st in layers.values() if k in st]
    return {
        "n_layers": len(layers),
        "total_proxy_loss": float(np.sum(vals("proxy_loss"))),
        "mean_proxy_rel": float(np.mean(vals("proxy_rel"))),
        "max_proxy_rel": float(np.max(vals("proxy_rel"))),
        "max_mu_w_post": float(np.max(vals("mu_w_post"))),
        "max_mu_h_post": float(np.max(vals("mu_h_post"))),
        "max_h_cond": float(np.max(vals("h_cond"))),
        "max_frob_rel_err": float(np.max(vals("frob_rel_err"))),
        "total_wall_s": float(np.sum(vals("wall_s"))),
    }


# ---------------------------------------------------------------------------
# baselines (load time)
# ---------------------------------------------------------------------------


def write_baseline(path, quality: dict, *, source: Optional[str] = None) -> dict:
    """Persist the per-layer proxy losses of ``quality`` as a baseline."""
    obj = {
        "kind": "quip_quality_baseline",
        "format": QUALITY_FORMAT,
        "source": source,
        "proxy_loss": {
            key: st["proxy_loss"] for key, st in quality["layers"].items()
        },
        "aggregate": quality.get("aggregate", {}),
    }
    path = pathlib.Path(path)
    path.write_text(json.dumps(obj, indent=1))
    return obj


def load_baseline(path) -> dict:
    obj = json.loads(pathlib.Path(path).read_text())
    if obj.get("kind") != "quip_quality_baseline":
        raise ValueError(
            f"{path} is not a quality baseline "
            f"(kind={obj.get('kind')!r}); write one with "
            f"launch/quality_report.py --write-baseline"
        )
    return obj


def check_artifact_quality(
    quality: Optional[dict], baseline: dict, *, threshold: float = 1.2
) -> list:
    """Compare an artifact's quality section against a baseline.

    Returns one regression record per layer whose proxy loss exceeds
    ``threshold ×`` its baseline value (and one for layers the baseline
    knows but the artifact doesn't).  An artifact with no quality section
    (saved before quality manifests existed) warns and compares clean —
    the same contract as pre-digest-manifest loads.
    """
    if threshold <= 0:
        raise ValueError(f"threshold must be > 0, got {threshold}")
    if not quality or "layers" not in quality:
        warnings.warn(
            "artifact manifest has no quality section (saved before "
            "quality manifests existed); baseline comparison skipped — "
            "re-quantize to audit proxy loss",
            stacklevel=2,
        )
        return []
    regressions = []
    for key, base in baseline["proxy_loss"].items():
        st = quality["layers"].get(key)
        if st is None:
            regressions.append({
                "layer": key, "baseline": base, "current": None,
                "ratio": None, "reason": "missing_layer",
            })
            continue
        cur = st["proxy_loss"]
        if cur > base * threshold:
            regressions.append({
                "layer": key, "baseline": base, "current": cur,
                "ratio": cur / base if base > 0 else float("inf"),
                "reason": "proxy_loss",
            })
    return regressions


# ---------------------------------------------------------------------------
# serve-time canaries
# ---------------------------------------------------------------------------


def teacher_forced_logits(adapter, tokens: np.ndarray) -> np.ndarray:
    """Full-sequence causal logits through the adapter's dense probe
    trunk (``CachedDecoder.activation_probe`` — the reference forward
    with an empty context window).  ONE dispatch serves the canary
    gauge, the shadow oracle, and any offline recomputation, which is
    what makes "online gauge == offline value" an equality, not a
    tolerance.  The same trunk runs on single-device and TP adapters,
    so a sharded canary scores the sequence the unsharded one would.

    ``tokens`` (B, S) int32; returns logits (B, S, V) float32 on host.
    """
    return adapter.activation_probe(tokens)[0]


def _nll_from_logits(logits: np.ndarray, tokens: np.ndarray) -> float:
    """−mean log p(t_i | t_<i) in float64 on host — one deterministic
    implementation shared by the canary gauge and any offline check, so
    the two are equal bit-for-bit, not merely close."""
    z = logits[:, :-1].astype(np.float64)
    z = z - z.max(axis=-1, keepdims=True)
    logp = z - np.log(np.exp(z).sum(axis=-1, keepdims=True))
    tgt = np.asarray(tokens, np.int64)[:, 1:]
    picked = np.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return float(-picked.mean())


def teacher_forced_nll(adapter, tokens: np.ndarray) -> float:
    """Teacher-forced NLL of ``tokens`` under the adapter's dense trunk."""
    return _nll_from_logits(teacher_forced_logits(adapter, tokens), tokens)


# activation saturation: fraction of hidden-state elements at or beyond
# this magnitude — an early-warning overflow canary for fp16-class
# serving dtypes (float16 max is 65504)
SAT_THRESHOLD = 3.0e4


def canary_probe(adapter, tokens: np.ndarray) -> tuple[float, dict]:
    """One canary tick: teacher-forced NLL over the pinned prompt set
    plus per-layer activation absmax / saturation fraction from the same
    forward.  Out-of-band by construction — nothing touches the KV pool,
    so concurrent traffic stays token-identical."""
    logits, act = adapter.activation_probe(tokens)
    return _nll_from_logits(logits, np.asarray(tokens, np.int32)), act


# ---------------------------------------------------------------------------
# shadow fp-oracle drift sampling
# ---------------------------------------------------------------------------


class ShadowSampler:
    """Always-on sampled generalization of ``serve.py --check``.

    A deterministic fraction of requests (crc32 of ``(seed, rid)`` —
    stable across processes and batch composition) record their
    per-emission logits; when such a request FINISHES, the same adapter
    re-scores its full ``prompt + output`` sequence through the dense
    reference trunk and the sampler observes:

      * ``shadow_max_abs_logit_diff`` (histogram) — max |serving-path
        logits − oracle logits| over the request's emissions;
      * ``shadow_token_flips`` (counter) + ``shadow_flip_rate``
        (histogram) — emissions where the two paths' argmax disagree
        (path drift, independent of sampling temperature);
      * ``shadow_samples`` / ``shadow_tokens`` (counters).

    On the fp gather-dense path the serving forward IS the oracle, so
    the flip rate is exactly zero — the invariant tests pin.
    """

    def __init__(self, adapter, rate: float, *, seed: int = 0,
                 metrics=None, tracer=None):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"shadow rate must be in [0, 1], got {rate}")
        self.adapter = adapter
        self.rate = rate
        self.seed = seed
        self.metrics = metrics
        self.tracer = tracer

    def selects(self, rid: int) -> bool:
        if self.rate <= 0.0:
            return False
        h = zlib.crc32(f"{self.seed}:{rid}".encode())
        return h / 2**32 < self.rate

    def observe(self, req) -> Optional[dict]:
        """Re-score one finished shadow request; returns the drift record
        (also pushed into the metrics registry / tracer when wired)."""
        if not req.out_tokens or len(req.step_logits) != len(req.out_tokens):
            return None  # replayed logits missing — nothing honest to score
        full = np.concatenate(
            [req.prompt, np.asarray(req.out_tokens, np.int32)]
        )
        oracle = teacher_forced_logits(self.adapter, full[None])[0]
        # emission i's logits predict out_tokens[i]: oracle row P-1+i
        rows = oracle[len(req.prompt) - 1 : len(req.prompt) - 1
                      + len(req.out_tokens)]
        served = np.stack(
            [np.asarray(l, np.float32) for l in req.step_logits]
        )
        diff = float(np.max(np.abs(served - rows)))
        flips = int(np.sum(
            np.argmax(served, axis=-1) != np.argmax(rows, axis=-1)
        ))
        rec = {
            "rid": req.rid,
            "tokens": len(req.out_tokens),
            "max_abs_logit_diff": diff,
            "token_flips": flips,
            "flip_rate": flips / len(req.out_tokens),
        }
        if self.metrics is not None:
            m = self.metrics
            m.inc("shadow_samples")
            m.inc("shadow_tokens", rec["tokens"])
            m.inc("shadow_token_flips", flips)
            m.histogram("shadow_max_abs_logit_diff").observe(diff)
            m.histogram("shadow_flip_rate").observe(rec["flip_rate"])
        if self.tracer is not None:
            self.tracer.event("shadow_drift", **rec)
        return rec
