"""End-to-end Algorithm-3 tests: the paper's Table-2 orderings in proxy form,
greedy descent property, hessian accumulation, and the inference path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_hessian, make_weights

from repro.core.greedy import greedy, greedy_pass
from repro.core.hessian import HessianAccumulator, damp, expert_hessians
from repro.core.proxy import proxy_loss, trD_trH
from repro.core.quantizer import QuipConfig, QuantizedLinear, quantize_layer


@pytest.fixture(scope="module")
def wh():
    return make_weights(96, 128, seed=11), make_hessian(128, seed=11)


def _quantize(W, H, **kw):
    cfg = QuipConfig(use_kernel=False, **kw)
    return quantize_layer(W, H, cfg, seed=0)


def test_incoherence_step_function_at_2bit(wh):
    """The headline: at 2 bits IncP turns a collapsed quantizer viable
    (Table 2's 'step function change'), for both near and ldlq."""
    W, H = wh
    for method in ["near", "ldlq"]:
        _, base = _quantize(W, H, bits=2, method=method, incoherence=False)
        _, incp = _quantize(W, H, bits=2, method=method, incoherence=True)
        assert incp["proxy_loss"] < base["proxy_loss"] * 0.05, method
        assert incp["frob_rel_err"] < 1.0


def test_ldlq_beats_near_under_incp(wh):
    W, H = wh
    _, near = _quantize(W, H, bits=2, method="near", incoherence=True)
    _, ldlq = _quantize(W, H, bits=2, method="ldlq", incoherence=True)
    assert ldlq["proxy_loss"] < near["proxy_loss"]


@pytest.mark.parametrize("method", ["near", "ldlq", "ldlq_rg", "greedy"])
def test_more_bits_less_loss(wh, method):
    W, H = wh
    losses = [
        _quantize(W, H, bits=b, method=method, incoherence=True)[1]["proxy_loss"]
        for b in (2, 3, 4)
    ]
    assert losses[0] > losses[1] > losses[2]


def test_hadamard_transform_comparable_to_kronecker(wh):
    """Beyond-paper randomized-Hadamard IncP matches Kronecker quality."""
    W, H = wh
    _, kron = _quantize(W, H, bits=2, method="ldlq", incoherence=True,
                        transform="kronecker")
    _, had = _quantize(W, H, bits=2, method="ldlq", incoherence=True,
                       transform="hadamard")
    assert had["proxy_loss"] < kron["proxy_loss"] * 3.0
    assert kron["proxy_loss"] < had["proxy_loss"] * 3.0


def test_greedy_post_pass_descends(wh):
    """Each greedy pass after LDLQ cannot increase the proxy loss."""
    W, H = wh
    from repro.core.ldlq import ldl_decomposition, ldlq as ldlq_fn
    from repro.core.incoherence import incoherence_preprocess

    Wg, Ht, _ = incoherence_preprocess(W, H, bits=2, seed=0)
    Udot, _ = ldl_decomposition(Ht)
    What = ldlq_fn(Wg, Udot, 3)
    prev = float(proxy_loss(What, Wg, Ht))
    for _ in range(3):
        What = greedy_pass(Wg, Ht, What, 3)
        cur = float(proxy_loss(What, Wg, Ht))
        assert cur <= prev * (1 + 1e-5)
        prev = cur


def test_greedy_stays_on_grid(wh):
    W, H = wh
    from repro.core.incoherence import incoherence_preprocess

    Wg, Ht, _ = incoherence_preprocess(W, H, bits=2, seed=0)
    What = greedy(Wg, Ht, 3, passes=2)
    vals = np.unique(np.asarray(What))
    assert set(vals) <= {0.0, 1.0, 2.0, 3.0}


def test_quantized_linear_inference_matches_dequant(wh):
    W, H = wh
    layer, _ = _quantize(W, H, bits=2, method="ldlq", incoherence=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (5, W.shape[1]))
    y_path = layer(x)
    y_deq = x @ layer.dequantize().T
    np.testing.assert_allclose(np.asarray(y_path), np.asarray(y_deq), atol=1e-3)


def test_quantized_linear_pallas_path(wh):
    """use_kernel=True exercises quant_matmul through the layer __call__."""
    W, H = wh
    cfg = QuipConfig(bits=2, method="ldlq", incoherence=True, use_kernel=True)
    layer, _ = quantize_layer(W, H, cfg, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(0), (5, W.shape[1]))
    np.testing.assert_allclose(
        np.asarray(layer(x)), np.asarray(x @ layer.dequantize().T), atol=1e-3
    )


def test_trD_trH_statistic(wh):
    """Table 6: tr(D)/tr(H) < 0.65 on realistic (low-rank-ish) H."""
    _, H = wh
    assert float(trD_trH(damp(H, 0.01))) < 0.65


def test_hessian_accumulator_matches_direct():
    X = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    acc = HessianAccumulator.create(32)
    for i in range(0, 64, 16):
        acc = acc.update(X[i : i + 16])
    np.testing.assert_allclose(
        np.asarray(acc.finalize()),
        np.asarray(X.T @ X / 64),
        rtol=1e-3,
        atol=1e-6,  # fp32 accumulation-order noise
    )


def test_hessian_accumulator_mask():
    X = jax.random.normal(jax.random.PRNGKey(2), (10, 8))
    mask = jnp.array([1, 1, 1, 0, 0, 0, 0, 0, 0, 0], jnp.float32)
    acc = HessianAccumulator.create(8).update(X, mask)
    np.testing.assert_allclose(
        np.asarray(acc.finalize()), np.asarray(X[:3].T @ X[:3] / 3), rtol=1e-5
    )


def test_expert_hessians_starved_fallback():
    X = jax.random.normal(jax.random.PRNGKey(3), (256, 16))
    idx = jnp.zeros((256, 2), jnp.int32)  # everything routed to expert 0
    Hs, counts = expert_hessians(X, idx, num_experts=4, min_tokens=8)
    shared = np.asarray(X.T @ X / 256)
    np.testing.assert_allclose(np.asarray(Hs[1]), shared, rtol=1e-5)  # starved
    assert float(counts[0]) == 512.0  # top-2 double count
    # expert 0 saw everything: its H is the (weighted) second moment
    assert not np.allclose(np.asarray(Hs[0]), shared * 0)


def test_stochastic_method_runs(wh):
    W, H = wh
    layer, stats = _quantize(W, H, bits=3, method="ldlq_stoch", incoherence=True)
    assert np.isfinite(stats["proxy_loss"])
