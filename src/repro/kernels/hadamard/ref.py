"""Pure-jnp oracle: butterfly FWHT (the textbook O(n log n) form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fwht_ref(x: jax.Array) -> jax.Array:
    """Normalized Walsh–Hadamard along the last axis (power-of-two dim)."""
    n = x.shape[-1]
    stages = n.bit_length() - 1
    shape = x.shape
    y = x.reshape(-1, n).astype(jnp.float32)
    for _ in range(stages):
        y = y.reshape(y.shape[0], -1, 2)
        a, b = y[..., 0], y[..., 1]
        y = jnp.concatenate([a + b, a - b], axis=-1)
    return (y * (n**-0.5)).reshape(shape).astype(x.dtype)


def hadamard_ref(x: jax.Array, signs: jax.Array) -> jax.Array:
    return fwht_ref(x * signs)
