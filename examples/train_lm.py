"""End-to-end training driver example: ~100M-param LM, fault-tolerant loop.

Default invocation trains a reduced model for a few steps so the example
finishes on one CPU; pass --full for the ~100M configuration (few hundred
steps; sized for a real accelerator).

    PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    args = ap.parse_args()
    if args.full:
        # ~100M params: rwkv6-1.6b reduced by width via the real arch config
        argv = [
            "--arch", "qwen3-14b", "--steps", "300",
            "--global-batch", "32", "--seq-len", "512",
            "--ckpt-dir", args.ckpt_dir, "--save-every", "50",
        ]
    else:
        argv = [
            "--arch", "qwen3-14b", "--smoke", "--steps", "10",
            "--global-batch", "2", "--seq-len", "32",
            "--ckpt-dir", args.ckpt_dir, "--save-every", "5",
            "--log-every", "2",
        ]
    return train_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
