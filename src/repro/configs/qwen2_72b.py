"""qwen2-72b [dense] — GQA, QKV bias — arXiv:2407.10671."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1e6,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2-72b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        mlp="swiglu",
        dtype="float32",
        microbatch=2,
        remat="none",
    )
