"""Hypothesis crash-schedule sweep (ISSUE 10): under RANDOM mid-stream
replica kills and restarts, every admitted request must either finish
with the exact token stream of an unkilled single-replica reference or
fail with a typed, client-actionable rejection — never a mangled or
silently truncated stream — and the fleet drain's leak gates must be
clean on every replica, including restarted incarnations.

Deterministic fleet tests live in test_fleet.py (whose in-process
harness this module reuses); this module holds only the property sweep
and skips wholesale without hypothesis (repo idiom — scripts/ci.sh
best-effort installs it)."""
from __future__ import annotations

import jax
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.data import make_calibration
from repro.models import build_model
from repro.serve.faults import parse_fault_plan
from test_fleet import (
    GEN,
    PROMPT_LEN,
    _fleet,
    _gen_tokens,
    _get_json,
    _post,
    _reference,
    _wait,
)


@pytest.fixture(scope="module")
def fp_stack():
    cfg = get_smoke_config("qwen3-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=2,
                               seg_len=PROMPT_LEN, seed=3).tokens
    return cfg, model, params, prompts


@settings(max_examples=3, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.function_scoped_fixture])
@given(data=st.data())
def test_random_kill_restart_schedules(fp_stack, data):
    cfg, model, params, prompts = fp_stack
    refs = [_reference(model, params, p) for p in prompts]

    n = data.draw(st.integers(2, 3), label="replicas")
    n_kills = data.draw(st.integers(0, 2), label="kills")
    schedule = [
        (data.draw(st.integers(0, n - 1), label=f"kill{j}_replica"),
         data.draw(st.integers(1, GEN - 1), label=f"kill{j}_tokens"))
        for j in range(n_kills)
    ]
    restart_idx = data.draw(
        st.one_of(st.none(), st.integers(0, n - 1)), label="restart")

    # each scheduled kill is a mid-stream transport death on that
    # replica's FIRST incarnation (exactly what the router sees when a
    # process takes kill -9: EOF before the done frame)
    plans: dict[int, list] = {}
    for idx, k in schedule:
        plans.setdefault(idx, []).append(f"disconnect@tokens={k}")

    def fault_for(index, generation):
        if generation == 0 and index in plans:
            return parse_fault_plan(";".join(plans[index]))
        return None

    router = _fleet(model, params, n=n, fault_for=fault_for)
    try:
        # every admitted request finishes token-identical to the
        # unkilled reference, whatever the schedule did
        for p, ref in zip(prompts, refs):
            assert _gen_tokens(router.port, p) == ref
        # the typed-failure arm of the property: an inadmissible
        # request is rejected with its typed body, never a broken
        # stream
        c, r = _post(router.port,
                     {"prompt": [1, 2, 3], "max_new": 10_000})
        import json as _json
        body = _json.loads(r.read())
        c.close()
        assert r.status == 413 and body["retryable"] is False
        if restart_idx is not None:
            h = router.sup.handles[restart_idx]
            h.proc.drain_and_join("chaos-kill")
            assert _wait(
                lambda: h.state == "healthy" and h.restarts >= 1,
                timeout=60)
            # the restarted incarnation serves the same stream
            assert _gen_tokens(router.port, prompts[0]) == refs[0]
        _, fz = _get_json(router.port, "/fleetz")
        assert fz["journal"]["live"] == 0  # nothing left half-open
    finally:
        report = router.drain_and_join()
    # leak gates: every drained replica (restarted incarnations
    # included) exited 0 — zero leaked pages, zero mapped slots
    assert report.exit_code == 0
    assert all(r["exit_code"] in (0, None) for r in report.replicas)
    assert report.failed == 0
