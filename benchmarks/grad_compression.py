import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
# ^ standalone module (run via `python -m benchmarks.grad_compression`):
# needs a 16-device data axis to materialize the gradient all-reduce.

"""Distributed-optimization trick, measured: int8 error-feedback gradient
compression over the data axis.

Lowers two shard_map gradient-sync steps on a 16-way data mesh and counts
collective link-bytes in the compiled HLO:

  fp32 baseline:  g_mean = psum(g) / 16         (ring: 2 x 4 B/elem x 15/16)
  int8-EF:        q, s, e = ef_compress(g)
                  phase 1: all_to_all the int8 chunks (reduce-scatter with
                           int8 on the wire), accumulate locally in f32;
                  phase 2: requantize the reduced chunk to int8 and
                           all-gather it (int8 on the wire again).
                  => 2 x 1 B/elem x 15/16 vs 2 x 4 -> ~4x fewer link bytes

The error-feedback buffer keeps the quantization residual local, so the
compression is unbiased over steps (tests/test_substrate.py proves the
accumulation property)."""
import json

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.optim.compression import ef_int8_compress, init_ef_state
from repro.runtime.hlo_analysis import analyze_hlo


def main(argv=None):
    n_dev = len(jax.devices())
    mesh = Mesh(jax.devices(), ("data",))
    nelem = 1 << 20  # 1M-element gradient leaf (4 MB fp32)

    gspec = jax.ShapeDtypeStruct((n_dev, nelem), jnp.float32)
    espec = jax.ShapeDtypeStruct((n_dev, nelem), jnp.float32)

    @jax.jit
    def sync_fp32(g):
        def f(g):
            return jax.lax.psum(g, "data") / n_dev

        return shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P())(g)

    @jax.jit
    def sync_int8(g, e):
        def f(g, e):
            q, s, err = ef_int8_compress({"g": g[0]}, {"g": e[0]})
            # phase 1: int8 reduce-scatter (all_to_all keeps int8 on the
            # wire; the accumulate happens locally in f32 — a direct int8
            # psum would overflow)
            chunks = q["g"].reshape(n_dev, -1)  # (n_dev, nelem/n_dev) int8
            mine = jax.lax.all_to_all(
                chunks, "data", split_axis=0, concat_axis=0, tiled=False
            )  # (n_dev, chunk) int8: everyone's contribution to my chunk
            sg = jax.lax.all_gather(s["g"], "data")  # (n_dev,) f32 scales
            red = jnp.einsum(
                "dn,d->n", mine.astype(jnp.float32), sg
            ) / n_dev  # (chunk,) f32 reduced mean
            # phase 2: requantize + int8 all-gather
            s2 = jnp.max(jnp.abs(red)) / 127.0 + 1e-12
            q2 = jnp.clip(jnp.round(red / s2), -127, 127).astype(jnp.int8)
            qg = jax.lax.all_gather(q2, "data")  # (n_dev, chunk) int8
            s2g = jax.lax.all_gather(s2, "data")  # (n_dev,) f32
            mean = (qg.astype(jnp.float32) * s2g[:, None]).reshape(-1)
            return mean, err["g"][None]

        return shard_map(
            f, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P(), P("data")), check_rep=False,
        )(g, e)

    results = {}
    for name, fn, args in (
        ("fp32", sync_fp32, (gspec,)),
        ("int8_ef", sync_int8, (gspec, espec)),
    ):
        hlo = fn.lower(*args).compile().as_text()
        st = analyze_hlo(hlo, n_dev)
        results[name] = st.collectives.total_bytes
        print(f"grad_compression/{name},0.0,"
              f"collective_bytes={st.collectives.total_bytes/1e6:.2f}MB "
              f"{st.collectives.summary()['by_kind']}")
    ratio = results["fp32"] / max(results["int8_ef"], 1)
    print(f"grad_compression/ratio,0.0,fp32/int8 = {ratio:.2f}x fewer "
          f"link bytes (theory ~4x: int8 wire both phases, EF keeps it "
          f"unbiased over steps)")
    with open("experiments/grad_compression.json", "w") as f:
        json.dump({**results, "ratio": ratio}, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
