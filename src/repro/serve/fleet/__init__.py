"""Replica fleet: supervision, crash failover, token-identical stream
recovery (DESIGN.md §15).

- :mod:`repro.serve.fleet.supervisor` — spawn/probe/restart N replica
  FrontDoor processes (heartbeat + tick-stall watchdog, exponential
  backoff, give-up circuit breaker), coordinated fleet drain.
- :mod:`repro.serve.fleet.router` — stdlib asyncio HTTP router:
  prefix-affinity + least-loaded balancing, typed-rejection
  pass-through, and journal-backed in-flight failover that splices a
  token-identical continuation into a live SSE stream when a replica
  dies mid-generation.
- :mod:`repro.serve.fleet.journal` / :mod:`repro.serve.fleet.affinity`
  — the supporting pieces (emitted-token journal, rendezvous hashing).
"""
from repro.serve.fleet.affinity import prefix_key, rendezvous_rank
from repro.serve.fleet.journal import JournalEntry, RequestJournal
from repro.serve.fleet.router import FleetRouter
from repro.serve.fleet.supervisor import (
    FleetReport,
    ProcessReplicaFactory,
    ReplicaHandle,
    Supervisor,
    free_port,
)

__all__ = [
    "FleetReport",
    "FleetRouter",
    "JournalEntry",
    "ProcessReplicaFactory",
    "ReplicaHandle",
    "RequestJournal",
    "Supervisor",
    "free_port",
    "prefix_key",
    "rendezvous_rank",
]
