"""int8 error-feedback gradient compression (distributed-optimization trick).

Quantize per-leaf gradients to int8 with a per-leaf absmax scale and keep
the quantization residual in an error-feedback buffer that is added back
the next step — unbiased over time, 4x fewer bytes on the data-parallel
all-reduce when the reduce is performed on the int8 payload (see
``repro.launch.train`` / the shard_map DP wrapper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_ef_state", "ef_int8_compress", "ef_int8_decompress"]


def init_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf(g: jax.Array, e: jax.Array):
    gf = g.astype(jnp.float32) + e
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    err = gf - q.astype(jnp.float32) * scale
    return q, scale, err


def ef_int8_compress(grads, ef_state):
    """-> (int8 tree, scale tree, new ef_state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [_compress_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    qs = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    errs = treedef.unflatten([o[2] for o in out])
    return qs, scales, errs


def ef_int8_decompress(qs, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, qs, scales
    )
