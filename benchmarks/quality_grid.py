"""Tables 1/2 analogue: quantization-method x bits quality grid.

Trains the small bench LM, then quantizes it block-by-block with every
(method x processing x bits) combination and reports held-out perplexity.
The paper's claims to reproduce:
  * 2-bit baseline processing collapses; 2-bit IncP stays viable ("step
    function change"), for EVERY rounding method incl. nearest;
  * LDLQ(+IncP) = QuIP beats Near(+IncP);
  * 4-bit is close to fp16 for everything.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.quantizer import QuipConfig
from repro.data import make_calibration
from repro.launch.quantize import perplexity, quantize_dense_model

from benchmarks.common import emit, eval_ppl, trained_lm


def run(args) -> dict:
    cfg, model, params = trained_lm(steps=args.train_steps)
    calib = make_calibration(cfg.vocab, n_segments=args.calib_segments,
                             seg_len=args.calib_len, seed=7)
    eval_toks = make_calibration(cfg.vocab, n_segments=8, seg_len=128,
                                 seed=99).tokens

    ppl_fp = perplexity(
        lambda t: model.logits(params, model.forward(params, {"tokens": t})[0]),
        eval_toks,
    )
    results = {"fp16": ppl_fp}
    methods = ["near", "ldlq"] if args.quick else ["near", "ldlq", "ldlq_rg", "greedy"]
    bits_list = [2] if args.quick else [4, 3, 2]
    for method in methods:
        for incp in (False, True):
            for bits in bits_list:
                t0 = time.time()
                qcfg = QuipConfig(
                    bits=bits, method=method, incoherence=incp,
                    greedy_passes=3, use_kernel=False,
                )
                qm = quantize_dense_model(
                    params, cfg, qcfg, calib.tokens, verbose=False
                )
                ppl = perplexity(qm.logits, eval_toks)
                key = f"{method}{'+incp' if incp else ''}@{bits}b"
                results[key] = ppl
                emit(f"quality_grid/{key}", (time.time() - t0) * 1e6,
                     f"ppl={ppl:.2f}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--calib-segments", type=int, default=16)
    ap.add_argument("--calib-len", type=int, default=128)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/quality_grid.json")
    args = ap.parse_args(argv)
    results = run(args)
    print(json.dumps(results, indent=1))
    if args.out:
        import pathlib

        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
