"""ShapeDtypeStruct stand-ins for every model input (dry-run contract).

Weak-type-correct, shardable, zero device allocation.  ``input_specs``
covers the data inputs per shape kind; params/opt-state/cache abstracts
come from ``Model.abstract_params`` / ``Model.abstract_cache`` (also via
``jax.eval_shape`` — never allocated).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.lm import Model

__all__ = ["input_specs", "abstract_opt_state"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Data inputs for the step function of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch = {
            "tokens": _sds((B, S), jnp.int32),
            "targets": _sds((B, S), jnp.int32),
        }
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            batch["patches"] = _sds((B, cfg.n_patches, cfg.d_model), dt)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            batch["patches"] = _sds((B, cfg.n_patches, cfg.d_model), dt)
        return {"batch": batch}
    # decode: one new token against a seq_len-deep cache/state
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def abstract_opt_state(optimizer, abstract_params):
    return jax.eval_shape(optimizer.init, abstract_params)
