"""Rounding-method registry: the paper's Table-2 grid of quantizers.

Every method maps ``(W_grid, H, maxq, key) -> What_grid`` on the integer
grid domain ``[0, maxq]``; incoherence processing composes orthogonally (it
happens before/after, in :mod:`repro.core.quantizer`).

  near     nearest rounding, no feedback
  stoch    unbiased stochastic rounding, no feedback
  ldlq     LDLQ == OPTQ (Theorem 6); blocked production schedule
  ldlq_rg  LDLQ with diag(H)-descending column reorder + greedy post-passes
  greedy   stand-alone greedy coordinate descent (Alg. 4)
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.greedy import greedy as _greedy_fn
from repro.core.ldlq import (
    ldl_decomposition,
    ldlq as _ldlq_seq,
    ldlq_blocked,
    quantize_nearest,
    quantize_stoch,
)

__all__ = ["round_weights", "METHODS", "pick_block"]


def pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of n that is <= target (LDLQ panel width)."""
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def _ldlq(W, H, maxq, key, *, stochastic=False, block=128):
    Udot, _ = ldl_decomposition(H)
    b = pick_block(W.shape[1], block)
    if b >= 8:
        return ldlq_blocked(
            W, Udot, maxq, block=b, stochastic=stochastic, key=key
        )
    return _ldlq_seq(W, Udot, maxq, stochastic=stochastic, key=key)


def _ldlq_rg(W, H, maxq, key, *, greedy_passes=10, block=128):
    d = jnp.diagonal(H)
    perm = jnp.argsort(-d)
    inv = jnp.argsort(perm)
    Wp = W[:, perm]
    Hp = H[perm][:, perm]
    What = _ldlq(Wp, Hp, maxq, key, block=block)
    if greedy_passes:
        What = _greedy_fn(Wp, Hp, maxq, passes=greedy_passes, init=What)
    return What[:, inv]


def _near(W, H, maxq, key):  # noqa: ARG001
    return quantize_nearest(W, maxq)


def _stoch(W, H, maxq, key):  # noqa: ARG001
    return quantize_stoch(W, maxq, key)


def _greedy(W, H, maxq, key, *, greedy_passes=10):  # noqa: ARG001
    return _greedy_fn(W, H, maxq, passes=greedy_passes)


METHODS: dict[str, Callable] = {
    "near": _near,
    "stoch": _stoch,
    "ldlq": _ldlq,
    "ldlq_stoch": lambda W, H, maxq, key, **kw: _ldlq(
        W, H, maxq, key, stochastic=True, **kw
    ),
    "ldlq_rg": _ldlq_rg,
    "greedy": _greedy,
}


def round_weights(
    method: str,
    W: jax.Array,
    H: jax.Array,
    maxq: int,
    key: Optional[jax.Array] = None,
    **kw,
) -> jax.Array:
    if method not in METHODS:
        raise KeyError(f"unknown rounding method {method!r}; have {list(METHODS)}")
    return METHODS[method](W, H, maxq, key, **kw)
