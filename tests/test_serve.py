"""Serving subsystem tests: paged KV pool invariants, continuous-batching
engine equivalence with the recompute/dense-cache reference paths (fp and
quantized), eviction-under-pressure recovery, and quantized-artifact
save/load round-trips."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import make_hessian, make_weights

from repro.configs import get_smoke_config
from repro.core.quantizer import (
    QuipConfig,
    linear_from_arrays,
    linear_to_arrays,
    quantize_layer,
)
from repro.data import make_calibration
from repro.models import build_model
from repro.serve import CachedDecoder, Engine, EngineConfig, PagedKVPool
from repro.serve.artifacts import load_quantized, save_quantized


def _smoke_cfg():
    return get_smoke_config("qwen3-14b")


# ---------------------------------------------------------------------------
# PagedKVPool invariants
# ---------------------------------------------------------------------------


def _pool(n_pages=9, page_size=4, n_slots=3, max_pages=4):
    return PagedKVPool(
        _smoke_cfg(), n_pages=n_pages, page_size=page_size, n_slots=n_slots,
        max_pages_per_seq=max_pages,
    )


def test_pool_admit_extend_release_accounting():
    pool = _pool()  # 8 usable pages
    assert pool.pages_in_use == 0
    a = pool.admit(5)  # 2 pages
    b = pool.admit(4)  # 1 page
    assert a is not None and b is not None and a != b
    assert pool.pages_in_use == 3
    assert pool.extend(a, 8)  # no new page needed
    assert pool.pages_in_use == 3
    assert pool.extend(a, 9)  # 3rd page
    assert pool.pages_in_use == 4
    pool.release(a)
    assert pool.pages_in_use == 1
    pool.release(b)
    assert pool.pages_in_use == 0
    assert pool.peak_pages_in_use == 4


def test_pool_admit_exhaustion_and_slot_limits():
    pool = _pool(n_pages=5, n_slots=2)  # 4 usable pages
    a = pool.admit(16)  # 4 pages: everything
    assert a is not None
    assert pool.admit(1) is None  # no pages left
    pool.release(a)
    a = pool.admit(1)
    b = pool.admit(1)
    assert a is not None and b is not None
    assert pool.admit(1) is None  # no slots left
    assert not pool.extend(a, 17)  # over max_pages_per_seq
    assert pool.fits(16) and not pool.fits(17)


def test_pool_extend_fails_without_free_pages():
    pool = _pool(n_pages=4, n_slots=2)  # 3 usable
    a = pool.admit(8)  # 2 pages
    b = pool.admit(4)  # 1 page
    assert not pool.extend(a, 9)  # would need a 3rd page
    pool.release(b)
    assert pool.extend(a, 9)


def test_pool_write_gather_roundtrip():
    cfg = _smoke_cfg()
    pool = _pool(page_size=4, max_pages=2)
    slot = pool.admit(6)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jnp.arange(L * 6 * KV * hd, dtype=jnp.float32).reshape(L, 6, KV, hd)
    pool.write_span(slot, 0, 6, k, -k)
    assert pool.length(slot) == 6
    gk, gv = pool.gather([slot, None])
    assert gk.shape == (L, 2, 8, KV, hd)
    np.testing.assert_array_equal(np.asarray(gk[:, 0, :6]), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(gv[:, 0, :6]), np.asarray(-k))
    # single-token write at position 6 (second page)
    tok_k = jnp.full((L, 1, KV, hd), 7.0)
    pool.write([slot], [6], tok_k, tok_k)
    gk, _ = pool.gather([slot])
    np.testing.assert_array_equal(np.asarray(gk[:, 0, 6]), np.asarray(tok_k[:, 0]))
    assert pool.length(slot) == 7


# ---------------------------------------------------------------------------
# Engine equivalence vs reference decode paths
# ---------------------------------------------------------------------------


def _run_engine(adapter, prompts, gen, *, arrival_gap=0.0, **ecfg_kw):
    kw = dict(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8, record_logits=True,
    )
    kw.update(ecfg_kw)
    engine = Engine(adapter, EngineConfig(**kw))
    reqs = [
        engine.submit(np.asarray(p), max_new=gen, arrival=i * arrival_gap)
        for i, p in enumerate(prompts)
    ]
    engine.run()
    return engine, reqs


def test_engine_fp_matches_dense_cache_path():
    """Engine (paged cache, continuous batching, chunked prefill) must
    reproduce Model.prefill/decode_step logits and greedy tokens."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10, seed=3).tokens
    gen = 6
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.01,
    )
    ref_toks = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        assert len(r.out_tokens) == gen
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref_toks[i])
    # logits equivalence (cached engine decode vs dense-cache decode),
    # recompute-free reference: full forward over prompt+generated
    full = np.concatenate([np.asarray(prompts), ref_toks], axis=1)
    hidden, _ = model.forward(params, {"tokens": jnp.asarray(full)})
    ref_logits = np.asarray(model.logits(params, hidden))
    S = prompts.shape[1]
    for i, r in enumerate(reqs):
        got = np.stack(r.step_logits)  # (gen, V)
        want = ref_logits[i, S - 1 : S - 1 + gen]
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.fixture(scope="module")
def quantized_smoke():
    from repro.launch.quantize import quantize_dense_model

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = make_calibration(cfg.vocab, n_segments=4, seg_len=32, seed=7)
    qcfg = QuipConfig(bits=2, method="ldlq", use_kernel=False)
    qm = quantize_dense_model(params, cfg, qcfg, calib.tokens, seed=0,
                              verbose=False)
    return cfg, qm, qcfg


def test_engine_quantized_matches_recompute(quantized_smoke):
    """Cached decode through the packed D^-1 -> V -> quant_matmul -> U^T
    path == the old per-token full-recompute, token-for-token."""
    from repro.launch.serve import quantized_generate

    cfg, qm, _ = quantized_smoke
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=12, seed=5).tokens
    gen = 5
    _, reqs = _run_engine(
        CachedDecoder.from_quantized(qm), prompts, gen, arrival_gap=0.01,
    )
    ref = np.asarray(quantized_generate(qm, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])
    # logits along the way must match the recompute oracle too
    for i, r in enumerate(reqs):
        seq = jnp.asarray(
            np.concatenate([np.asarray(prompts[i]), ref[i][:-1]])[None]
        )
        want = np.asarray(qm.logits(seq))[0, prompts.shape[1] - 1 :]
        np.testing.assert_allclose(
            np.stack(r.step_logits), want, rtol=2e-3, atol=2e-3
        )


# ---------------------------------------------------------------------------
# Paged fast path (in-place pool attention) vs the gather-dense oracle
# ---------------------------------------------------------------------------


def test_engine_paged_fp_matches_reference():
    """--check-style equivalence for the paged fast path: decode through
    the paged-attention dispatch (no per-step dense KV gather) must emit
    the exact greedy tokens of the dense-cache reference, logits included."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10, seed=3).tokens
    gen = 6
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.01, paged_decode=True,
    )
    ref_toks = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref_toks[i])
    full = np.concatenate([np.asarray(prompts), ref_toks], axis=1)
    hidden, _ = model.forward(params, {"tokens": jnp.asarray(full)})
    ref_logits = np.asarray(model.logits(params, hidden))
    S = prompts.shape[1]
    for i, r in enumerate(reqs):
        got = np.stack(r.step_logits)
        want = ref_logits[i, S - 1 : S - 1 + gen]
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_engine_paged_quantized_matches_recompute(quantized_smoke):
    """Paged decode with QuantizedLinear projections routed through the
    quant_matmul kernel dispatch == the per-token recompute oracle."""
    from repro.launch.serve import quantized_generate

    cfg, qm, _ = quantized_smoke
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=12, seed=5).tokens
    gen = 5
    _, reqs = _run_engine(
        CachedDecoder.from_quantized(qm), prompts, gen, arrival_gap=0.01,
        paged_decode=True,
    )
    ref = np.asarray(quantized_generate(qm, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_paged_int8_matches_gather_int8():
    """int8 pages: the paged kernel path dequantizes the same stored pages
    as the gather-dense oracle — token streams must agree exactly."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=9, seed=8).tokens
    gen = 5
    runs = []
    for paged in (False, True):
        _, reqs = _run_engine(
            CachedDecoder.from_model(model, params), prompts, gen,
            paged_decode=paged, kv_int8=True,
        )
        runs.append([np.asarray(r.out_tokens) for r in reqs])
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)


def test_engine_paged_eviction_under_page_pressure():
    """Eviction/requeue still reproduces exact tokens when decode runs the
    paged fast path (re-prefill after eviction goes through the oracle
    prefill into the same pool the kernel then reads)."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=10, paged_decode=True,
    )
    assert engine.stats["evictions"] > 0
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_paged_interpret_kernel_end_to_end():
    """The actual Pallas kernel (interpret mode) inside the fused decode
    dispatch — not just the jnp fallback — agrees with the reference."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=1, seg_len=10, seed=3).tokens
    gen = 3
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params, paged_interpret=True),
        prompts, gen, n_slots=2, paged_decode=True,
    )
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    np.testing.assert_array_equal(np.asarray(reqs[0].out_tokens), ref[0])


# ---------------------------------------------------------------------------
# Batched paged prefill (one fused cross-request dispatch per tick)
# ---------------------------------------------------------------------------


def test_engine_batched_prefill_fp_matches_reference():
    """Cross-request batched paged prefill must emit the exact greedy
    tokens AND logits of the dense-cache reference."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10, seed=3).tokens
    gen = 6
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.01, paged_decode=True, paged_prefill=True,
    )
    assert engine.stats["prefill_batches"] > 0
    ref_toks = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref_toks[i])
    full = np.concatenate([np.asarray(prompts), ref_toks], axis=1)
    hidden, _ = model.forward(params, {"tokens": jnp.asarray(full)})
    ref_logits = np.asarray(model.logits(params, hidden))
    S = prompts.shape[1]
    for i, r in enumerate(reqs):
        got = np.stack(r.step_logits)
        want = ref_logits[i, S - 1 : S - 1 + gen]
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_engine_batched_prefill_batches_multiple_lanes():
    """Co-arriving requests actually share one prefill dispatch (the
    scheduler's co-batchable group, not a B=1 loop)."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=8, seed=3).tokens
    engine, _ = _run_engine(
        CachedDecoder.from_model(model, params), prompts, 2,
        paged_decode=True, paged_prefill=True, token_budget=64,
    )
    assert engine.stats["prefill_batch_size"] >= 4


def test_engine_batched_prefill_quantized_matches_recompute(quantized_smoke):
    from repro.launch.serve import quantized_generate

    cfg, qm, _ = quantized_smoke
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=12, seed=5).tokens
    gen = 5
    _, reqs = _run_engine(
        CachedDecoder.from_quantized(qm), prompts, gen, arrival_gap=0.01,
        paged_decode=True, paged_prefill=True,
    )
    ref = np.asarray(quantized_generate(qm, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_batched_prefill_int8_matches_gather_int8():
    """int8 pages: the batched paged-prefill engine writes the same pages
    (shared quantizer) the gather-dense int8 engine reads — exact tokens."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=9, seed=8).tokens
    gen = 5
    runs = []
    for paged in (False, True):
        _, reqs = _run_engine(
            CachedDecoder.from_model(model, params), prompts, gen,
            paged_decode=paged, paged_prefill=paged, kv_int8=True,
        )
        runs.append([np.asarray(r.out_tokens) for r in reqs])
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)


def test_engine_batched_prefill_eviction_under_page_pressure():
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=10, paged_decode=True,
        paged_prefill=True,
    )
    assert engine.stats["evictions"] > 0
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_batched_prefill_interpret_kernel_end_to_end():
    """The actual chunked-prefill Pallas kernel (interpret mode) inside
    the fused dispatch — not just the jnp fallback — end to end."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=10, seed=3).tokens
    gen = 3
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params, paged_interpret=True),
        prompts, gen, n_slots=2, paged_decode=True, paged_prefill=True,
    )
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


# ---------------------------------------------------------------------------
# Prefix cache: trie hits, refcounts, copy-on-write, eviction
# ---------------------------------------------------------------------------


def test_engine_prefix_cache_skips_recompute_same_tokens():
    """Identical prompts: later admissions map cached pages (hit tokens
    counted, prefill work reduced) and still emit reference tokens."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = make_calibration(cfg.vocab, n_segments=1, seg_len=12, seed=3).tokens
    prompts = np.tile(np.asarray(base), (3, 1))
    gen = 5
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.2, paged_decode=True, paged_prefill=True,
        prefix_cache=True,
    )
    s = engine.summary()
    # 12-token prompts, 4-token pages: 2 later requests x >= 8 cached
    assert s["prefix_hit_tokens"] >= 16
    assert s["cached_pages"] >= 2
    assert s["prefill_tokens"] <= 3 * 12 - s["prefix_hit_tokens"]
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_prefix_cache_page_aligned_full_hit():
    """A prompt that is entirely cached full pages: admission maps a
    private COPY of the last page (copy-on-admit), recomputes only the
    final token, and emits the reference stream."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = make_calibration(cfg.vocab, n_segments=1, seg_len=8, seed=5).tokens
    prompts = np.tile(np.asarray(base), (2, 1))  # 8 tokens == 2 full pages
    gen = 4
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.2, paged_decode=True, paged_prefill=True,
        prefix_cache=True,
    )
    s = engine.summary()
    assert s["prefix_hit_tokens"] == 7  # capped at len(prompt) - 1
    assert s["cow_copies"] >= 1  # the copy-on-admit of the last page
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_prefix_cache_survives_eviction_pressure():
    """Prefix cache + overcommitted pool: cache-only pages are reclaimed
    under pressure, eviction/replay still reproduces exact tokens."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=10, paged_decode=True,
        paged_prefill=True, prefix_cache=True,
    )
    assert engine.stats["evictions"] > 0
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def _prefix_pool(**kw):
    args = dict(n_pages=13, page_size=4, n_slots=4, max_pages_per_seq=4,
                prefix_cache=True)
    args.update(kw)
    return PagedKVPool(_smoke_cfg(), **args)


def test_pool_prefix_trie_hit_and_refcounts():
    cfg = _smoke_cfg()
    pool = _prefix_pool()
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    toks = np.arange(10, dtype=np.int32)
    k = jnp.arange(L * 10 * KV * hd, dtype=jnp.float32).reshape(L, 10, KV, hd)
    a = pool.admit(10, tokens=toks)
    assert pool.length(a) == 0  # cold cache
    pool.write_span(a, 0, 10, k, -k)
    pool.register_prefix(a, toks)
    assert pool.cached_pages == 2  # two full 4-token pages of the prompt
    b = pool.admit(10, tokens=toks)
    assert pool.length(b) == 8  # both full pages mapped
    assert pool.shared_pages == 2 and pool.max_page_ref == 3
    gk, gv = pool.gather([b])
    np.testing.assert_array_equal(np.asarray(gk[:, 0, :8]), np.asarray(k[:, :8]))
    np.testing.assert_array_equal(np.asarray(gv[:, 0, :8]), np.asarray(-k[:, :8]))
    # different tokens past page 1 -> only one page matches
    toks2 = toks.copy()
    toks2[6] += 1
    c = pool.admit(10, tokens=toks2)
    assert pool.length(c) == 4
    # releasing the original keeps cached pages alive via the trie's refs
    pool.release(a)
    d = pool.admit(10, tokens=toks)
    assert pool.length(d) == 8


def test_pool_copy_on_write_divergence():
    """Writing into a shared page copies it first: the original owner's
    (and the cache's) view is untouched, the writer's view diverges."""
    cfg = _smoke_cfg()
    pool = _prefix_pool()
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    toks = np.arange(8, dtype=np.int32)
    k = jnp.arange(L * 8 * KV * hd, dtype=jnp.float32).reshape(L, 8, KV, hd)
    a = pool.admit(10, tokens=toks)
    pool.write_span(a, 0, 8, k, -k)
    pool.register_prefix(a, toks)
    b = pool.admit(10, tokens=toks)
    assert pool.length(b) == 8 and pool.shared_pages == 2
    assert pool.cow_copies == 0
    # b diverges INSIDE the shared prefix (e.g. a fork edited upstream)
    patch = jnp.full((L, 1, KV, hd), 99.0)
    pool.write_span(b, 5, 1, patch, patch)
    assert pool.cow_copies == 1
    ga, _ = pool.gather([a])
    np.testing.assert_array_equal(np.asarray(ga[:, 0, :8]), np.asarray(k))
    gb, _ = pool.gather([b])
    np.testing.assert_array_equal(np.asarray(gb[:, 0, 5]), np.asarray(patch[:, 0]))
    np.testing.assert_array_equal(np.asarray(gb[:, 0, 4]), np.asarray(k[:, 4]))
    # a fresh admit still sees the ORIGINAL cached content
    c = pool.admit(10, tokens=toks)
    gc_, _ = pool.gather([c])
    np.testing.assert_array_equal(np.asarray(gc_[:, 0, :8]), np.asarray(k))


def test_pool_prefix_cache_reclaimed_under_pressure():
    """Cache-only pages (refcount held solely by the trie) are reclaimed
    LRU-first when admit/extend would otherwise fail."""
    cfg = _smoke_cfg()
    pool = _prefix_pool()  # 12 usable pages
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    toks = np.arange(8, dtype=np.int32)
    k = jnp.zeros((L, 8, KV, hd), jnp.float32)
    a = pool.admit(8, tokens=toks)
    pool.write_span(a, 0, 8, k, k)
    pool.register_prefix(a, toks)
    pool.release(a)
    assert pool.cached_pages == 2 and pool.pages_in_use == 2
    # demand every page: the cached pages must be reclaimed, not block
    slots = [pool.admit(16) for _ in range(3)]
    assert all(s is not None for s in slots)
    assert pool.cached_pages == 0
    for s in slots:
        pool.release(s)
    assert pool.pages_in_use == 0


def test_pool_int8_write_gather_roundtrip():
    cfg = _smoke_cfg()
    pool = PagedKVPool(
        cfg, n_pages=9, page_size=4, n_slots=3, max_pages_per_seq=2,
        dtype=jnp.int8,
    )
    slot = pool.admit(6)
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    k = jax.random.normal(jax.random.PRNGKey(0), (L, 6, KV, hd), jnp.float32)
    pool.write_span(slot, 0, 6, k, -k)
    gk, gv = pool.gather([slot])
    assert gk.dtype == jnp.dtype(cfg.dtype)
    # int8 quantization error is bounded by scale/2 = max|x|/254 per head
    np.testing.assert_allclose(
        np.asarray(gk[:, 0, :6]), np.asarray(k), atol=0.03, rtol=0.02
    )
    np.testing.assert_allclose(
        np.asarray(gv[:, 0, :6]), np.asarray(-k), atol=0.03, rtol=0.02
    )


def test_engine_eviction_under_page_pressure():
    """Overcommitted pool: decode runs out of pages mid-stream, the newest
    sequence is evicted, requeued, and still finishes with exact tokens."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    # each seq needs 4 pages of 4; give the pool only 9 usable pages for 3
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=10,
    )
    assert engine.stats["evictions"] > 0
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_eviction_victim_can_be_asking_lane():
    """Regression: under hard pressure the victim must be the NEWEST
    running request — possibly the very lane asking for a page — never an
    older lane already granted pages this step (that used to leave a freed
    slot inside the decode batch -> KeyError)."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompts = make_calibration(cfg.vocab, n_segments=4, seg_len=16, seed=6).tokens
    gen = 16
    # 4 seqs x up to 8 pages of 4, but only 15 usable pages
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=4, page_size=4, n_pages=16, record_logits=False,
    )
    assert engine.stats["evictions"] > 0
    assert engine.pool.pages_in_use == 0  # everything released at drain
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_sampling_reproducible_and_greedy_default():
    """Non-greedy decode: same seed -> same stream regardless of batch
    composition; temperature 0 stays the exact greedy argmax path."""
    from repro.serve.scheduler import SamplingParams

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    adapter = CachedDecoder.from_model(model, params)
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=2).tokens
    gen = 6
    sp = SamplingParams(temperature=0.9, top_p=0.85, seed=42)

    def run(batch):
        engine = Engine(adapter, EngineConfig(
            max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
            token_budget=32, prefill_chunk=8,
        ))
        reqs = [
            engine.submit(np.asarray(prompts[i]), max_new=gen, sampling=sp)
            for i in batch
        ]
        engine.run()
        return {i: np.asarray(r.out_tokens) for i, r in zip(batch, reqs)}

    solo = run([0])
    batched = run([0, 1, 2])
    np.testing.assert_array_equal(solo[0], batched[0])
    # greedy (default SamplingParams) matches the reference generator
    from repro.launch.serve import greedy_generate

    engine = Engine(adapter, EngineConfig(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8,
    ))
    reqs = [engine.submit(np.asarray(p), max_new=gen) for p in prompts]
    engine.run()
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_sampling_param_validation():
    from repro.serve.scheduler import SamplingParams

    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_stop_token_finishes_request_early():
    """A request stops at its first stop-token emission (token included);
    the greedy stream up to that point is unchanged."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=2, seg_len=8, seed=2).tokens
    gen = 8
    ref = np.asarray(greedy_generate(model, params, prompts, gen))
    stop = int(ref[0, 2])  # stop request 0 at its 3rd greedy token
    engine = Engine(
        CachedDecoder.from_model(model, params),
        EngineConfig(max_seq_len=prompts.shape[1] + gen, n_slots=4,
                     page_size=4, token_budget=32, prefill_chunk=8),
    )
    r0 = engine.submit(np.asarray(prompts[0]), max_new=gen,
                       stop_tokens=(stop,))
    r1 = engine.submit(np.asarray(prompts[1]), max_new=gen)
    engine.run()
    want = list(ref[0, : list(ref[0]).index(stop) + 1])
    np.testing.assert_array_equal(np.asarray(r0.out_tokens), want)
    assert len(r0.out_tokens) <= 3
    np.testing.assert_array_equal(np.asarray(r1.out_tokens), ref[1])
    assert engine.pool.pages_in_use == 0  # early finish released its pages


def test_engine_rejects_oversized_request():
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(
        CachedDecoder.from_model(model, params),
        EngineConfig(max_seq_len=16, n_slots=2, page_size=4),
    )
    with pytest.raises(ValueError):
        engine.submit(np.arange(10, dtype=np.int32), max_new=8)  # 18 > 16


# ---------------------------------------------------------------------------
# Quantized artifacts: save -> load round-trip
# ---------------------------------------------------------------------------


def test_linear_arrays_roundtrip(small_wh):
    W, H = small_wh
    qcfg = QuipConfig(bits=2, use_kernel=False)
    layer, _ = quantize_layer(W, H, qcfg, seed=11, collect_stats=False)
    arrays, meta = linear_to_arrays(layer)
    arrays = {k: np.asarray(v) for k, v in arrays.items()}  # simulate npz
    rebuilt = linear_from_arrays(arrays, meta)
    np.testing.assert_array_equal(np.asarray(rebuilt.packed), np.asarray(layer.packed))
    # transforms regenerate bit-identically from seeds
    np.testing.assert_array_equal(
        np.asarray(rebuilt.dequantize()), np.asarray(layer.dequantize())
    )
    x = make_weights(5, W.shape[1], seed=9)
    np.testing.assert_allclose(
        np.asarray(rebuilt(x)), np.asarray(layer(x)), rtol=0, atol=1e-6
    )


def test_artifact_save_load_identical_outputs(tmp_path, quantized_smoke):
    cfg, qm, qcfg = quantized_smoke
    save_quantized(tmp_path / "art", qm, qcfg, extra_meta={"stats": qm.stats})
    qm2, meta = load_quantized(tmp_path / "art")
    assert meta["quip_config"]["bits"] == 2
    assert qm2.cfg == cfg
    toks = make_calibration(cfg.vocab, n_segments=2, seg_len=16, seed=2).tokens
    np.testing.assert_allclose(
        np.asarray(qm2.logits(toks)), np.asarray(qm.logits(toks)),
        rtol=0, atol=1e-5,
    )
    # per-linear quant_matmul outputs are identical
    lin, lin2 = qm.blocks[0]["attn.wq"], qm2.blocks[0]["attn.wq"]
    x = make_weights(3, lin.n, seed=13)
    np.testing.assert_allclose(
        np.asarray(lin2(x)), np.asarray(lin(x)), rtol=0, atol=1e-6
    )


def test_artifact_rejects_non_artifact_dir(tmp_path):
    from repro.checkpoint import save_checkpoint

    save_checkpoint(tmp_path / "ckpt", 0, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_quantized(tmp_path / "ckpt")


# ---------------------------------------------------------------------------
# Speculative decode: drafter, KV rollback, budget accounting, token parity
# ---------------------------------------------------------------------------


def _spec_prompts(n=3, reps=8):
    """Cyclic prompts: the model falls into repetitive continuations the
    n-gram drafter predicts, so speculative ticks actually accept."""
    return np.tile(np.asarray([7, 91, 33, 150], np.int32), (n, reps))


def test_drafter_ngram_proposals():
    from repro.serve.drafter import NgramDrafter, make_drafter

    d = NgramDrafter(4, max_ngram=3)
    # periodic history drafts at full depth (iterative continuation past
    # the history's edge, not truncated at it)
    np.testing.assert_array_equal(
        d.propose(np.tile([5, 9], 6)), [5, 9, 5, 9]
    )
    np.testing.assert_array_equal(d.propose([1, 2, 3, 7, 7, 7]), [7] * 4)
    # no repeated n-gram -> nothing proposed
    assert d.propose(np.arange(10)).size == 0
    # propose(k) caps below the drafter depth
    assert len(d.propose(np.tile([5, 9], 6), 2)) == 2
    # the trailing n-gram itself is never its own match
    assert d.propose(np.asarray([1, 2])).size == 0
    with pytest.raises(ValueError):
        NgramDrafter(0)
    with pytest.raises(ValueError):
        make_drafter("oracle", 4)
    assert make_drafter("ngram", 2).k == 2


def test_pool_truncate_rollback():
    pool = _pool(n_pages=9, page_size=4, n_slots=3, max_pages=4)
    cfg = _smoke_cfg()
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    slot = pool.admit(10)  # 3 pages
    k = jnp.ones((L, 10, KV, hd), jnp.float32)
    pool.write_span(slot, 0, 10, k, k)  # 3 pages claimed via span write?
    assert pool.length(slot) == 10
    pages_before = pool.pages_in_use
    # rollback into the middle of page 2: page 3 is wholly invalid
    assert pool.truncate(slot, 6) == 1
    assert pool.length(slot) == 6
    assert pool.pages_in_use == pages_before - 1
    # rollback that only shrinks length within a kept page frees nothing
    assert pool.truncate(slot, 5) == 0
    assert pool.length(slot) == 5
    # growing via truncate is rejected
    with pytest.raises(ValueError):
        pool.truncate(slot, 7)
    # rollback to zero keeps one page mapped (admit's minimum)
    assert pool.truncate(slot, 0) == 1
    assert pool.length(slot) == 0
    pool.release(slot)
    assert pool.pages_in_use == 0


def test_pool_spec_write_rollback_cow_shared_tail():
    """A speculative write + rollback on a lane whose tail page is
    prefix-cache-shared: the write triggers copy-on-write (the cached
    page is NEVER mutated), and the rollback only unmaps the lane's
    private view — refcounts stay exact and LRU reclaim still works."""
    cfg = _smoke_cfg()
    pool = _prefix_pool()
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    toks = np.arange(8, dtype=np.int32)
    k = jnp.arange(L * 8 * KV * hd, dtype=jnp.float32).reshape(L, 8, KV, hd)
    a = pool.admit(8, tokens=toks)
    pool.write_span(a, 0, 8, k, -k)
    pool.register_prefix(a, toks)
    b = pool.admit(12, tokens=toks)  # partial hit: both pages shared
    assert pool.length(b) == 8 and pool.shared_pages == 2
    # roll b back INTO the shared tail page (a replayed/evicted lane) —
    # truncate itself must not mutate or free the shared page
    dropped = pool.truncate(b, 6)
    assert pool.length(b) == 6
    assert pool.shared_pages == 2  # page 1 still shared (trie + a + b)
    cow0 = pool.cow_copies
    # speculative verify writes [last, d1, d2] at positions 6..8 (the
    # engine claims draft pages first): the write path must COW the
    # shared page 1 before the scatter
    assert pool.extend(b, 9)
    kv_new = jnp.full((L, 3, KV, hd), 99.0)
    pool.write_span(b, 6, 3, kv_new, kv_new)
    assert pool.cow_copies >= cow0 + 1
    # rollback the rejected tail (keep only position 6)
    pool.truncate(b, 7)
    assert pool.length(b) == 7
    # the cached/shared page kept its ORIGINAL content: a fresh hit still
    # maps bit-identical K/V
    ga, _ = pool.gather([a])
    np.testing.assert_array_equal(np.asarray(ga[:, 0, :8]), np.asarray(k))
    c = pool.admit(10, tokens=toks)
    gc_, _ = pool.gather([c])
    np.testing.assert_array_equal(
        np.asarray(gc_[:, 0, :8]), np.asarray(k)
    )
    # release everything; trie-held pages reclaim under pressure as before
    for s in (a, b, c):
        pool.release(s)
    slots = [pool.admit(16) for _ in range(3)]
    assert all(s is not None for s in slots)
    assert pool.cached_pages == 0
    for s in slots:
        pool.release(s)
    assert pool.pages_in_use == 0


def test_scheduler_charges_on_accept_not_propose():
    """Accepted speculative extras debit the NEXT step's prefill budget;
    rejected drafts never touch it (no double charge on the retry tick)."""
    from repro.serve.scheduler import Request, RequestState, TokenBudgetFCFS

    class _FakePool:
        def admit(self, n, tokens=None):
            return None  # nothing admissible: isolate the budget math

        def length(self, slot):
            return 0

    sched = TokenBudgetFCFS(token_budget=8, prefill_chunk=4)
    running = []
    for _ in range(2):
        r = Request(prompt=np.arange(4, dtype=np.int32), max_new=4)
        r.state = RequestState.PREFILL
        r.prefill_pos = 0
        running.append(r)
    # no debt: 8 budget -> two 4-token chunks
    plan = sched.plan(running, _FakePool())
    assert [n for _, n in plan.prefill] == [4, 4]
    # 5 accepted extras last tick -> only 3 budget left for prefill
    sched.charge_accepted(5)
    plan = sched.plan(running, _FakePool())
    assert sum(n for _, n in plan.prefill) == 3
    # the debt was settled, not carried: next plan is back to full budget
    plan = sched.plan(running, _FakePool())
    assert sum(n for _, n in plan.prefill) == 8
    with pytest.raises(ValueError):
        sched.charge_accepted(-1)


def test_engine_speculative_fp_token_and_logits_parity():
    """Greedy speculative decode (device selection) emits exactly the
    one-token dense reference's tokens AND logits, while actually
    accepting drafts and rolling back rejected K/V."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _spec_prompts()
    gen = 12
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        paged_decode=True, speculative_k=4, device_sample=True,
    )
    s = engine.summary()
    assert s["spec_ticks"] > 0
    assert s["accepted_tokens"] > 0  # cyclic prompts: drafts really land
    assert s["rolled_back_tokens"] > 0  # and some really get rolled back
    assert engine.pool.pages_in_use == 0
    ref = np.asarray(greedy_generate(model, params, jnp.asarray(prompts), gen))
    full = np.concatenate([np.asarray(prompts), ref], axis=1)
    hidden, _ = model.forward(params, {"tokens": jnp.asarray(full)})
    ref_logits = np.asarray(model.logits(params, hidden))
    S = prompts.shape[1]
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])
        np.testing.assert_allclose(
            np.stack(r.step_logits), ref_logits[i, S - 1 : S - 1 + gen],
            rtol=2e-3, atol=2e-3,
        )


def test_engine_speculative_host_sample_path_matches():
    """--host-sample debugging path: the verify dispatch returns logits
    and the host re-selects/accepts — same greedy stream."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _spec_prompts()
    gen = 10
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        paged_decode=True, speculative_k=3, device_sample=False,
    )
    ref = np.asarray(greedy_generate(model, params, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_speculative_quantized_matches_recompute(quantized_smoke):
    from repro.launch.serve import quantized_generate

    cfg, qm, _ = quantized_smoke
    prompts = _spec_prompts()
    gen = 8
    _, reqs = _run_engine(
        CachedDecoder.from_quantized(qm), prompts, gen,
        paged_decode=True, speculative_k=4, device_sample=True,
    )
    ref = np.asarray(quantized_generate(qm, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_speculative_int8_matches_sequential_int8():
    """int8 pages: the verify dispatch round-trips the chunk K/V through
    the page quantizer with the fp diagonal override, so speculative
    decode is token-identical to the sequential int8 paged engine."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _spec_prompts()
    gen = 10
    runs = []
    for k in (0, 4):
        eng, reqs = _run_engine(
            CachedDecoder.from_model(model, params), prompts, gen,
            paged_decode=True, speculative_k=k, device_sample=True,
            kv_int8=True, record_logits=False,
        )
        runs.append([np.asarray(r.out_tokens) for r in reqs])
        if k:
            assert eng.summary()["accepted_tokens"] > 0
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)


def test_engine_speculative_eviction_under_page_pressure():
    """Speculative lanes under page pressure: drafts are opportunistic
    (never evict anyone), eviction/replay still reproduces exact tokens,
    and every page is back on the free list at drain."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _spec_prompts(reps=4)  # 16-token prompts
    gen = 12
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        n_slots=3, page_size=4, n_pages=14, paged_decode=True,
        speculative_k=4, device_sample=True, record_logits=False,
    )
    assert engine.stats["evictions"] > 0
    assert engine.pool.pages_in_use == 0
    ref = np.asarray(greedy_generate(model, params, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])


def test_engine_speculative_prefix_cache_cow_and_parity():
    """Speculative decode + prefix cache: shared prompt pages are mapped,
    speculative writes COW instead of mutating cached pages, and the
    stream still matches the dense reference."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _spec_prompts()
    gen = 10
    engine, reqs = _run_engine(
        CachedDecoder.from_model(model, params), prompts, gen,
        arrival_gap=0.2, paged_decode=True, paged_prefill=True,
        prefix_cache=True, speculative_k=4, device_sample=True,
    )
    s = engine.summary()
    assert s["prefix_hit_tokens"] > 0
    assert s["accepted_tokens"] > 0
    ref = np.asarray(greedy_generate(model, params, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])
    # cached prompt pages survived speculative COW traffic intact: a new
    # identical-prompt engine admission still decodes the same stream
    assert s["cached_pages"] > 0


def test_engine_speculative_stop_token_mid_acceptance():
    """A stop token inside an accepted draft run finishes the request at
    the stop emission; later accepted tokens are discarded and their
    pages released."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _spec_prompts(n=1)
    gen = 12
    ref = np.asarray(greedy_generate(model, params, jnp.asarray(prompts), gen))
    stop = int(ref[0, 5])
    engine = Engine(
        CachedDecoder.from_model(model, params),
        EngineConfig(max_seq_len=prompts.shape[1] + gen, n_slots=2,
                     page_size=4, token_budget=32, prefill_chunk=8,
                     paged_decode=True, speculative_k=4,
                     device_sample=True),
    )
    r = engine.submit(np.asarray(prompts[0]), max_new=gen,
                      stop_tokens=(stop,))
    engine.run()
    want = list(ref[0, : list(ref[0]).index(stop) + 1])
    np.testing.assert_array_equal(np.asarray(r.out_tokens), want)
    assert engine.pool.pages_in_use == 0


def test_engine_speculative_interpret_kernel_end_to_end():
    """The verify dispatch through the actual chunked-prefill Pallas
    kernel (interpret mode) — including the diagonal-override int8 path —
    not just the jnp oracle."""
    from repro.launch.serve import greedy_generate

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _spec_prompts(n=2)
    gen = 4
    _, reqs = _run_engine(
        CachedDecoder.from_model(model, params, paged_interpret=True),
        prompts, gen, n_slots=2, paged_decode=True, speculative_k=2,
        device_sample=True,
    )
    ref = np.asarray(greedy_generate(model, params, jnp.asarray(prompts), gen))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.out_tokens), ref[i])
    # int8: kernel (interpret) must agree with the jnp-oracle engine
    runs = []
    for interpret in (False, True):
        _, reqs = _run_engine(
            CachedDecoder.from_model(model, params,
                                     paged_interpret=interpret),
            prompts, gen, n_slots=2, paged_decode=True, speculative_k=2,
            device_sample=True, kv_int8=True, record_logits=False,
        )
        runs.append([np.asarray(r.out_tokens) for r in reqs])
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)


def test_engine_speculative_requires_paged():
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    adapter = CachedDecoder.from_model(model, params)
    with pytest.raises(ValueError):
        Engine(adapter, EngineConfig(max_seq_len=16, speculative_k=2))
    with pytest.raises(ValueError):
        Engine(adapter, EngineConfig(max_seq_len=16, device_sample=True))
    with pytest.raises(ValueError):
        Engine(adapter, EngineConfig(max_seq_len=16, speculative_k=-1,
                                     paged_decode=True))


# ---------------------------------------------------------------------------
# On-device sampling (fused softmax/top-p draw, fold_in keys)
# ---------------------------------------------------------------------------


def test_device_sampling_greedy_matches_host_greedy():
    """device_sample with temperature 0 is the exact argmax: identical
    tokens to the host-selection paged engine."""
    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=10, seed=3).tokens
    gen = 6
    runs = []
    for dev in (False, True):
        _, reqs = _run_engine(
            CachedDecoder.from_model(model, params), prompts, gen,
            paged_decode=True, device_sample=dev,
        )
        runs.append([np.asarray(r.out_tokens) for r in reqs])
    for a, b in zip(*runs):
        np.testing.assert_array_equal(a, b)


def test_device_sampling_reproducible_across_batching():
    """fold_in(seed, emission_index) keys: the sampled stream of a request
    does not depend on batch composition — and the speculative engine
    draws the exact stream sequential decode draws."""
    from repro.serve.scheduler import SamplingParams

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    adapter = CachedDecoder.from_model(model, params)
    prompts = _spec_prompts()
    gen = 8
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=17)

    def run(batch, spec_k=0):
        engine = Engine(adapter, EngineConfig(
            max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
            token_budget=32, prefill_chunk=8, paged_decode=True,
            device_sample=True, speculative_k=spec_k,
        ))
        reqs = [
            engine.submit(np.asarray(prompts[i]), max_new=gen, sampling=sp)
            for i in batch
        ]
        engine.run()
        return {i: np.asarray(r.out_tokens) for i, r in zip(batch, reqs)}

    solo = run([0])
    batched = run([0, 1, 2])
    np.testing.assert_array_equal(solo[0], batched[0])
    # speculative grouping draws the same stream as sequential decode
    spec = run([0, 1, 2], spec_k=4)
    for i in range(3):
        np.testing.assert_array_equal(batched[i], spec[i])
    # a different seed gives a different stream (the draw is real)
    sp2 = SamplingParams(temperature=0.8, top_p=0.9, seed=18)
    engine = Engine(adapter, EngineConfig(
        max_seq_len=prompts.shape[1] + gen, n_slots=4, page_size=4,
        token_budget=32, prefill_chunk=8, paged_decode=True,
        device_sample=True,
    ))
    r = engine.submit(np.asarray(prompts[0]), max_new=gen, sampling=sp2)
    engine.run()
    assert not np.array_equal(np.asarray(r.out_tokens), solo[0])


def test_device_sample_tokens_top_p_and_temperature():
    """Unit checks on the fused sampler: greedy lanes take the argmax, a
    near-zero top-p collapses to the argmax, and draws land only inside
    the nucleus."""
    from repro.serve.adapter import sample_tokens

    V = 16
    logits = jnp.asarray(
        np.linspace(0, 3, V, dtype=np.float32)[None, None, :]
    )  # monotone: argmax is V-1
    args = lambda t, p: (
        jnp.asarray([t], jnp.float32), jnp.asarray([p], jnp.float32),
        jnp.asarray([3], jnp.int32), jnp.asarray([0], jnp.int32),
    )
    assert int(sample_tokens(logits, *args(0.0, 1.0))[0, 0]) == V - 1
    assert int(
        sample_tokens(logits, *args(0.0, 1.0), greedy_only=True)[0, 0]
    ) == V - 1
    assert int(sample_tokens(logits, *args(0.7, 1e-6))[0, 0]) == V - 1
    # with top_p = 0.5 over a peaked distribution only the top tokens can
    # be drawn; sweep draw indices to exercise many keys
    peaked = jnp.asarray(
        np.asarray([0, 0, 0, 8, 9], np.float32)[None, None, :]
    )
    for idx in range(24):
        tok = int(sample_tokens(
            peaked,
            jnp.asarray([1.0], jnp.float32), jnp.asarray([0.9], jnp.float32),
            jnp.asarray([5], jnp.int32), jnp.asarray([idx], jnp.int32),
        )[0, 0])
        assert tok in (3, 4)


def test_device_sampling_survives_eviction_replay():
    """A device-sampled request evicted mid-stream and replayed emits the
    exact stream of an uncontended run: every draw — including the
    prefill-boundary one — is the same pure function of
    (seed, emission_index)."""
    from repro.serve.scheduler import SamplingParams

    cfg = _smoke_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    adapter = CachedDecoder.from_model(model, params)
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8, seed=4).tokens
    gen = 8
    sps = [SamplingParams(temperature=0.9, top_p=0.9, seed=40 + i)
           for i in range(3)]

    def run(n_pages):
        engine = Engine(adapter, EngineConfig(
            max_seq_len=prompts.shape[1] + gen, n_slots=3, page_size=4,
            n_pages=n_pages, token_budget=32, prefill_chunk=8,
            paged_decode=True, device_sample=True,
        ))
        reqs = [engine.submit(np.asarray(p), max_new=gen, sampling=sp)
                for p, sp in zip(prompts, sps)]
        engine.run()
        return engine, [np.asarray(r.out_tokens) for r in reqs]

    _, calm = run(None)  # uncontended (no overcommit)
    engine, pressured = run(10)  # overcommitted: forces eviction/replay
    assert engine.stats["evictions"] > 0
    for a, b in zip(calm, pressured):
        np.testing.assert_array_equal(a, b)
