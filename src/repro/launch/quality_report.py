"""Render the quality section of a quantized artifact (DESIGN.md §13).

The quantize driver folds every layer's quality report (incoherence µ
before/after preprocessing, Hessian spectrum, absolute + H-relative proxy
loss, error norms, wall-clock) into the artifact manifest; this CLI is
the human surface over that section::

    python -m repro.launch.quality_report <artifact-dir>
    python -m repro.launch.quality_report <dir> --write-baseline base.json
    python -m repro.launch.quality_report <dir> --baseline base.json [--threshold 1.2]

``--write-baseline`` persists the per-layer proxy losses as the reference
a later ``serve.py --quality-baseline`` (or this CLI's ``--baseline``)
compares against; with ``--baseline`` the exit status is the number of
regressed layers, so CI can gate on it directly.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.checkpoint.store import latest_step
from repro.serve.quality import check_artifact_quality, load_baseline, write_baseline

__all__ = ["load_manifest", "main", "render_quality"]


def load_manifest(directory) -> dict:
    """Artifact metadata of the newest complete checkpoint under
    ``directory`` (the manifest's ``meta`` block — quality section,
    quip/arch configs) — no weight shards are touched."""
    directory = pathlib.Path(directory)
    step = latest_step(directory)
    if step is None:
        raise SystemExit(f"no complete checkpoint under {directory}")
    manifest = json.loads(
        (directory / f"step_{step:08d}" / "manifest.json").read_text()
    )
    return manifest.get("meta", {})


_COLS = (  # (header, stats key, format)
    ("proxy", "proxy_loss", "{:.4g}"),
    ("proxy_rel", "proxy_rel", "{:.3g}"),
    ("mu_w pre>post", None, None),  # rendered as a pair
    ("mu_h pre>post", None, None),
    ("h_cond", "h_cond", "{:.3g}"),
    ("frob_rel", "frob_rel_err", "{:.3g}"),
    ("wall_s", "wall_s", "{:.2f}"),
)


def render_quality(quality: dict) -> str:
    """Fixed-width per-layer table + aggregate footer."""
    layers = quality.get("layers", {})
    rows = [["layer"] + [h for h, _, _ in _COLS]]
    for key in sorted(layers, key=lambda k: (int(k.split("/")[0]), k)):
        st = layers[key]
        row = [key]
        for head, skey, fmt in _COLS:
            if skey is not None:
                row.append(fmt.format(st[skey]))
            elif head.startswith("mu_w"):
                row.append(f"{st['mu_w_pre']:.2f}>{st['mu_w_post']:.2f}")
            else:
                row.append(f"{st['mu_h_pre']:.2f}>{st['mu_h_post']:.2f}")
        rows.append(row)
    widths = [max(len(r[c]) for r in rows) for c in range(len(rows[0]))]
    lines = [
        "  ".join(cell.ljust(w) for cell, w in zip(row, widths)).rstrip()
        for row in rows
    ]
    agg = quality.get("aggregate", {})
    if agg:
        lines.append("")
        lines.append(
            "aggregate: "
            + "  ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in agg.items()
            )
        )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="render / baseline the quality section of a quantized "
                    "artifact manifest"
    )
    ap.add_argument("artifact", help="artifact directory (--out-dir of "
                                     "launch/quantize.py)")
    ap.add_argument("--baseline", default=None,
                    help="quality baseline JSON to compare against; exit "
                         "status = number of regressed layers")
    ap.add_argument("--threshold", type=float, default=1.2,
                    help="regression ratio: flag layers whose proxy loss "
                         "exceeds baseline x this (default 1.2)")
    ap.add_argument("--write-baseline", default=None,
                    help="persist this artifact's per-layer proxy losses "
                         "as a baseline JSON")
    ap.add_argument("--json", action="store_true",
                    help="dump the raw quality section instead of a table")
    args = ap.parse_args(argv)

    meta = load_manifest(args.artifact)
    quality = meta.get("quality")
    if not quality:
        raise SystemExit(
            f"{args.artifact} has no quality section (saved before quality "
            "manifests existed) — re-quantize with launch/quantize.py"
        )
    if args.json:
        print(json.dumps(quality, indent=1))
    else:
        print(f"[quality] {args.artifact}  "
              f"method={meta.get('quip_config', {}).get('method', '?')} "
              f"bits={meta.get('quip_config', {}).get('bits', '?')}")
        print(render_quality(quality))

    if args.write_baseline:
        write_baseline(args.write_baseline, quality, source=str(args.artifact))
        print(f"[quality] baseline written to {args.write_baseline}")

    if args.baseline:
        base = load_baseline(args.baseline)
        regressions = check_artifact_quality(
            quality, base, threshold=args.threshold
        )
        for r in regressions:
            if r["reason"] == "missing_layer":
                print(f"[quality] REGRESSION {r['layer']}: layer missing "
                      f"from artifact (baseline proxy={r['baseline']:.4g})")
            else:
                print(f"[quality] REGRESSION {r['layer']}: proxy "
                      f"{r['baseline']:.4g} -> {r['current']:.4g} "
                      f"({r['ratio']:.2f}x > {args.threshold:.2f}x)")
        if not regressions:
            print(f"[quality] OK: no layer regressed beyond "
                  f"{args.threshold:.2f}x baseline proxy loss")
        return len(regressions)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
