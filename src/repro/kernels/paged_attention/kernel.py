"""Pallas TPU kernels: GQA attention directly against the paged KV pool.

Two entry points share one grid shape and one online-softmax core:

* :func:`paged_attention_kernel` — decode: one query token per lane;
* :func:`paged_prefill_kernel` — chunked prefill: a ``(B, C)`` token chunk
  batch per dispatch.  The grid grows one trailing "arbitrary" step that
  folds the chunk's own K/V in with an intra-chunk causal mask, so a
  whole cross-request prefill chunk batch attends its paged prior context
  plus itself in a single pass (DESIGN.md §9).

The serving engine used to materialize a dense ``(L, B, Pmax*ps, KV, hd)``
copy of every context page per decode step (``PagedKVPool.gather``) — an
O(allocated-pages) HBM copy per emitted token that un-does the bandwidth win
2-bit weights buy (DESIGN.md §7).  This kernel reads the **physical page
pool in place**:

* the grid is ``(lane, kv_head, page)`` with the page dimension innermost
  ("arbitrary"), so the fp32 output tile is revisited as an online-softmax
  accumulator (running max ``m``, normalizer ``l``, unnormalized ``o``);
* per-lane **block tables** and **context lengths** ride in scalar-prefetch
  (SMEM) — the k/v BlockSpec index maps dereference ``bt[lane, page]`` to
  DMA exactly one physical page ``(ps, hd)`` slice per kv head per step.
  The page index is **clamped to the lane's last valid page**: block tables
  are bucketed to the longest live context in the batch, so a short lane
  would otherwise stream its trailing scratch/dead pages from HBM just to
  mask them — with the clamp, every grid step past the lane's end re-asks
  for the page already resident in VMEM and Mosaic skips the DMA (the
  revisited-block convention).  Positions past ``ctx_len`` are still masked
  out of the softmax, so clamped steps contribute exactly zero.  Dead
  block-table entries past a lane's last valid page are therefore never
  dereferenced — EXCEPT ``bt[lane, 0]``: an empty lane (``ctx_len == 0``)
  has no valid page to clamp to, so its steps all read entry 0, which
  must hold a real page id (the engine zero-fills block tables, and
  physical page 0 is the reserved scratch page);
* the layer index is baked into the index map, so the kernel addresses the
  full ``(L, P, ps, KV, hd)`` pool tensor without an XLA slice copy;
* int8 pages carry per-(token, head) fp32 scales (``(L, P, ps, KV)``),
  dequantized on the VPU right after the DMA — KV reads stay 1 byte/elem.

The new token's own K/V never touches the pool here: the wrapper (ops.py)
folds the self-attention term into the accumulator analytically and
normalizes, so decode needs no concat and no pre-scatter.  Outputs are the
*unnormalized* accumulator plus ``(m, l)`` statistics for that merge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

_NEG = float(jnp.finfo(jnp.float32).min)


def _online_update(s, valid, v, o_ref, m_ref, l_ref):
    """One online-softmax accumulation step over a key block.

    s (R, S) scores already NEG-filled outside ``valid``; v (S, hd).
    ``pmat`` is gated explicitly so a fully-masked block contributes
    exactly zero (``exp(NEG - NEG) == 1`` would poison the accumulator).
    """
    m_prev = m_ref[0, 0]  # (R, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    pmat = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # (R, S)
    l_ref[0, 0] = alpha * l_ref[0, 0] + jnp.sum(pmat, -1, keepdims=True)
    o_ref[0, 0] = o_ref[0, 0] * alpha + jax.lax.dot_general(
        pmat, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[0, 0] = m_new


def _pa_kernel(
    bt_ref,  # (B, Pa) int32 scalar-prefetch block table
    cl_ref,  # (B,)    int32 scalar-prefetch context lengths
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, 1, ps, 1, hd)
    v_ref,  # (1, 1, ps, 1, hd)
    *refs,  # [ks_ref (1,1,ps,1), vs_ref (1,1,ps,1)], o_ref, m_ref, l_ref
    page_size: int,
    int8_pages: bool,
):
    if int8_pages:
        ks_ref, vs_ref, o_ref, m_ref, l_ref = refs
    else:
        o_ref, m_ref, l_ref = refs
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
    k = k_ref[0, 0, :, 0].astype(jnp.float32)  # (ps, hd)
    v = v_ref[0, 0, :, 0].astype(jnp.float32)
    if int8_pages:
        k = k * ks_ref[0, 0, :, 0][:, None]
        v = v * vs_ref[0, 0, :, 0][:, None]
    hd = q.shape[-1]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (hd**-0.5)  # (G, ps)

    # positions covered by this physical page; everything at or past the
    # lane's ctx_len (incl. whole pages resolved to the scratch page) is
    # masked.
    pos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1
    )
    valid = pos < cl_ref[b]  # (1, ps), broadcasts over G
    s = jnp.where(valid, s, _NEG)
    _online_update(s, valid, v, o_ref, m_ref, l_ref)


def _check_operands(q, k_pages, v_pages, block_tables, ctx_len, layer,
                    k_scale, v_scale):
    if q.ndim != 4:
        raise ValueError(
            f"q must be (B, KV, G, hd) grouped queries, got shape {q.shape}"
        )
    B, KV, G, hd = q.shape
    if k_pages.ndim != 5 or v_pages.shape != k_pages.shape:
        raise ValueError(
            "k_pages/v_pages must both be (L, n_pages, page_size, KV, hd); "
            f"got k_pages {k_pages.shape}, v_pages {v_pages.shape}"
        )
    L, P, ps, KVp, hdp = k_pages.shape
    if (KVp, hdp) != (KV, hd):
        raise ValueError(
            f"page pool carries (KV={KVp}, hd={hdp}) but queries expect "
            f"(KV={KV}, hd={hd})"
        )
    if not 0 <= layer < L:
        raise ValueError(f"layer {layer} out of range for {L}-layer pool")
    if block_tables.ndim != 2 or block_tables.shape[0] != B:
        raise ValueError(
            f"block_tables must be (B={B}, pages_attended), got "
            f"{block_tables.shape}"
        )
    if ctx_len.shape != (B,):
        raise ValueError(f"ctx_len must be (B={B},), got {ctx_len.shape}")
    int8_pages = k_pages.dtype == jnp.int8
    if int8_pages:
        if k_scale is None or v_scale is None:
            raise ValueError("int8 pages require k_scale and v_scale")
        if k_scale.shape != (L, P, ps, KV) or v_scale.shape != (L, P, ps, KV):
            raise ValueError(
                f"page scales must be (L, P, ps, KV)={(L, P, ps, KV)}, got "
                f"k_scale {k_scale.shape}, v_scale {v_scale.shape}"
            )
    elif k_scale is not None or v_scale is not None:
        raise ValueError("page scales only apply to int8 pages")
    return int8_pages


@functools.partial(jax.jit, static_argnames=("layer", "interpret"))
def paged_attention_kernel(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    ctx_len: jax.Array,
    *,
    layer: int,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax decode attention of layer ``layer`` against the pool.

    q            (B, KV, G, hd) — grouped post-RoPE queries, one token/lane;
    k/v_pages    (L, P, ps, KV, hd) physical pool (fp, or int8 + scales);
    block_tables (B, Pa) int32 physical page per logical page (Pa is the
                 *attended* prefix of the lane's allocation, bucketed by the
                 caller — step cost scales with context, not allocation);
    ctx_len      (B,) int32 valid context tokens per lane.

    Returns ``(o, m, l)``: unnormalized accumulator (B, KV, G, hd) and the
    running max / normalizer (B, KV, G, 1), all fp32 — see ops.py for the
    self-token merge + normalization.
    """
    int8_pages = _check_operands(
        q, k_pages, v_pages, block_tables, ctx_len, layer, k_scale, v_scale
    )
    B, KV, G, hd = q.shape
    ps = k_pages.shape[2]
    Pa = block_tables.shape[1]

    def _page(bt, cl, b, p):
        # clamp to the lane's last valid page: grid steps past a short
        # lane's context re-DMA the block already in VMEM (Mosaic elides
        # the copy), instead of streaming dead/scratch pages to mask them.
        # An EMPTY lane (cl == 0) clamps to entry 0, which must be a valid
        # page id (engine convention: zero-fill -> scratch page 0)
        last = jnp.maximum(pl.cdiv(cl[b], ps) - 1, 0)
        return bt[b, jnp.minimum(p, last)]

    kv_spec = pl.BlockSpec(
        (1, 1, ps, 1, hd),
        lambda b, h, p, bt, cl: (layer, _page(bt, cl, b, p), 0, h, 0),
    )
    sc_spec = pl.BlockSpec(
        (1, 1, ps, 1),
        lambda b, h, p, bt, cl: (layer, _page(bt, cl, b, p), 0, h),
    )
    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda b, h, p, bt, cl: (b, h, 0, 0)),
        kv_spec,
        kv_spec,
    ]
    operands = [q, k_pages, v_pages]
    if int8_pages:
        in_specs += [sc_spec, sc_spec]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, Pa),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, p, bt, cl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, p, bt, cl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, p, bt, cl: (b, h, 0, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _pa_kernel, page_size=ps, int8_pages=int8_pages
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables, ctx_len, *operands)


def _prefill_kernel(
    bt_ref,  # (B, Pa) int32 scalar-prefetch block table
    cl_ref,  # (B,)    int32 scalar-prefetch PRIOR-context lengths
    q_ref,  # (1, 1, G*C, hd) chunk queries, rows G-major / chunk-pos-minor
    k_ref,  # (1, 1, ps, 1, hd) one physical context page
    v_ref,  # (1, 1, ps, 1, hd)
    kc_ref,  # (1, C, 1, hd) the chunk's own K (not yet in the pool)
    vc_ref,  # (1, C, 1, hd)
    *refs,  # [kself_ref, vself_ref (1,C,1,hd)], [ks_ref, vs_ref
    #         (1,1,ps,1)], o_ref, m_ref, l_ref
    page_size: int,
    chunk: int,
    int8_pages: bool,
    has_self: bool,
):
    refs = list(refs)
    kself_ref, vself_ref = (refs.pop(0), refs.pop(0)) if has_self else (None, None)
    if int8_pages:
        ks_ref, vs_ref, o_ref, m_ref, l_ref = refs
    else:
        o_ref, m_ref, l_ref = refs
    b, p = pl.program_id(0), pl.program_id(2)
    n_ctx = pl.num_programs(2) - 1  # trailing step is the chunk block

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G*C, hd)
    scale = q.shape[-1] ** -0.5

    @pl.when(p < n_ctx)
    def _ctx_page():
        # identical to the decode page step: every chunk token attends all
        # prior-context positions < cl, so the whole (G*C)-row tile shares
        # one page mask.
        k = k_ref[0, 0, :, 0].astype(jnp.float32)  # (ps, hd)
        v = v_ref[0, 0, :, 0].astype(jnp.float32)
        if int8_pages:
            k = k * ks_ref[0, 0, :, 0][:, None]
            v = v * vs_ref[0, 0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (G*C, ps)
        pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1
        )
        valid = pos < cl_ref[b]
        s = jnp.where(valid, s, _NEG)
        _online_update(s, valid, v, o_ref, m_ref, l_ref)

    @pl.when(p == n_ctx)
    def _chunk_block():
        # the chunk attends itself causally: row r is chunk position
        # r % chunk (G-major row layout), key column t valid iff t <= pos.
        # Every row keeps its self column, so the merged softmax is finite
        # even for ctx_len == 0 lanes and padded tail tokens.
        kc = kc_ref[0, :, 0].astype(jnp.float32)  # (C, hd)
        vc = vc_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, kc, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (G*C, C)
        rows = s.shape[0]
        row_pos = jax.lax.broadcasted_iota(jnp.int32, (rows, chunk), 0) % chunk
        col = jax.lax.broadcasted_iota(jnp.int32, (rows, chunk), 1)
        causal = col <= row_pos
        diag = col == row_pos
        if has_self:
            # diagonal override (speculative verify): each token's score
            # to ITSELF comes from the fp self K, not the chunk array
            kself = kself_ref[0, :, 0].astype(jnp.float32)  # (C, hd)
            s_self = jax.lax.dot_general(
                q, kself, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * scale
            s = jnp.where(diag, s_self, s)
        s = jnp.where(causal, s, _NEG)
        if not has_self:
            _online_update(s, causal, vc, o_ref, m_ref, l_ref)
        else:
            # _online_update with one extra term: the diagonal's value
            # contribution swaps from vc to the override
            m_prev = m_ref[0, 0]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            pmat = jnp.where(causal, jnp.exp(s - m_new), 0.0)  # (G*C, C)
            l_ref[0, 0] = alpha * l_ref[0, 0] + jnp.sum(
                pmat, -1, keepdims=True
            )
            acc = jax.lax.dot_general(
                pmat, vc, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            vd = vself_ref[0, :, 0].astype(jnp.float32) - vc
            acc = acc + jax.lax.dot_general(
                jnp.where(diag, pmat, 0.0), vd, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            o_ref[0, 0] = o_ref[0, 0] * alpha + acc
            m_ref[0, 0] = m_new
        o_ref[0, 0] = o_ref[0, 0] / l_ref[0, 0]  # normalize in place


def _check_prefill_operands(q, k_chunk, v_chunk, k_pages, v_pages,
                            block_tables, ctx_len, layer, k_scale, v_scale,
                            k_self=None, v_self=None):
    if q.ndim != 5:
        raise ValueError(
            f"q must be (B, KV, G, C, hd) grouped chunk queries, got shape "
            f"{q.shape}"
        )
    B, KV, G, C, hd = q.shape
    if k_chunk.shape != (B, C, KV, hd) or v_chunk.shape != k_chunk.shape:
        raise ValueError(
            f"k_chunk/v_chunk must both be (B={B}, C={C}, KV={KV}, hd={hd}); "
            f"got k_chunk {k_chunk.shape}, v_chunk {v_chunk.shape}"
        )
    if (k_self is None) != (v_self is None):
        raise ValueError("k_self and v_self must be given together")
    if k_self is not None and (
        k_self.shape != k_chunk.shape or v_self.shape != v_chunk.shape
    ):
        raise ValueError(
            f"k_self/v_self must match k_chunk {k_chunk.shape}; got "
            f"k_self {k_self.shape}, v_self {v_self.shape}"
        )
    # pool/table/scale checks are shared with the decode entry; a
    # single-chunk-position view of q has its (B, KV, G, hd) shape
    return _check_operands(
        q[:, :, :, 0], k_pages, v_pages, block_tables, ctx_len, layer,
        k_scale, v_scale,
    )


@functools.partial(jax.jit, static_argnames=("layer", "interpret"))
def paged_prefill_kernel(
    q: jax.Array,
    k_chunk: jax.Array,
    v_chunk: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    ctx_len: jax.Array,
    *,
    layer: int,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    k_self: jax.Array | None = None,
    v_self: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Causal chunked-prefill attention of layer ``layer`` against the pool.

    q            (B, KV, G, C, hd) grouped post-RoPE chunk queries — lane b's
                 chunk token t sits at absolute position ``ctx_len[b] + t``;
    k/v_chunk    (B, C, KV, hd) the chunk's own post-RoPE K/V (NOT yet
                 scattered into the pool);
    k/v_pages    (L, P, ps, KV, hd) physical pool (fp, or int8 + scales);
    block_tables (B, Pa) int32, bucketed to the longest PRIOR context;
    ctx_len      (B,) int32 valid prior-context tokens per lane (the chunk's
                 start position) — ragged, 0 for fresh admissions;
    k/v_self     optional (B, C, KV, hd) diagonal override: token t's
                 attention to ITSELF uses these instead of k/v_chunk (the
                 speculative verifier passes the fp pre-quantization K/V
                 here while the chunk arrays carry the int8 round-trip).

    Grid is ``(lane, kv_head, page+1)``: the context pages stream through
    the decode kernel's online-softmax step (index-map clamp included), and
    the one extra trailing step folds in the intra-chunk causal block and
    normalizes.  Returns the normalized output (B, KV, G, C, hd) fp32.
    """
    int8_pages = _check_prefill_operands(
        q, k_chunk, v_chunk, k_pages, v_pages, block_tables, ctx_len, layer,
        k_scale, v_scale, k_self, v_self,
    )
    has_self = k_self is not None
    B, KV, G, C, hd = q.shape
    ps = k_pages.shape[2]
    Pa = block_tables.shape[1]
    qf = q.reshape(B, KV, G * C, hd)

    def _page(bt, cl, b, p):
        # same clamp as decode: steps at/past a lane's last valid page
        # (including the whole trailing chunk step) re-ask for the page
        # already resident, and Mosaic elides the DMA.
        last = jnp.maximum(pl.cdiv(cl[b], ps) - 1, 0)
        return bt[b, jnp.minimum(p, last)]

    kv_spec = pl.BlockSpec(
        (1, 1, ps, 1, hd),
        lambda b, h, p, bt, cl: (layer, _page(bt, cl, b, p), 0, h, 0),
    )
    sc_spec = pl.BlockSpec(
        (1, 1, ps, 1),
        lambda b, h, p, bt, cl: (layer, _page(bt, cl, b, p), 0, h),
    )
    chunk_spec = pl.BlockSpec(
        (1, C, 1, hd), lambda b, h, p, bt, cl: (b, 0, h, 0)
    )
    in_specs = [
        pl.BlockSpec(
            (1, 1, G * C, hd), lambda b, h, p, bt, cl: (b, h, 0, 0)
        ),
        kv_spec,
        kv_spec,
        chunk_spec,
        chunk_spec,
    ]
    operands = [qf, k_pages, v_pages, k_chunk, v_chunk]
    if has_self:
        in_specs += [chunk_spec, chunk_spec]
        operands += [k_self, v_self]
    if int8_pages:
        in_specs += [sc_spec, sc_spec]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, Pa + 1),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec(
                (1, 1, G * C, hd), lambda b, h, p, bt, cl: (b, h, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, G * C, 1), lambda b, h, p, bt, cl: (b, h, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, G * C, 1), lambda b, h, p, bt, cl: (b, h, 0, 0)
            ),
        ],
    )
    o, _, _ = pl.pallas_call(
        functools.partial(
            _prefill_kernel, page_size=ps, chunk=C, int8_pages=int8_pages,
            has_self=has_self,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G * C, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G * C, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G * C, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables, ctx_len, *operands)
    return o.reshape(B, KV, G, C, hd)
