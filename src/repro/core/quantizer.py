"""QuIP Algorithm 3: full per-layer quantization pipeline + inference repr.

``quantize_layer`` = Alg.1 (incoherence pre-processing) → rounding method
(LDLQ et al.) → packing.  The result is a :class:`QuantizedLinear`: packed
2/3/4-bit integers plus O(√n)-sized transform factors regenerable from the
seed.  Inference never materializes the dequantized matrix:

    y = x·D^{-1} →(V)→ quant_matmul(packed) →(U^T)→ y

mirroring the paper's "multiply by W = U^T Ŵ V" factorization (Sec. 4.1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import incoherence as inc
from repro.core import packing
from repro.core.hessian import damp
from repro.core.methods import round_weights
from repro.core.proxy import proxy_loss

__all__ = [
    "QuipConfig",
    "QuantizedLinear",
    "quantize_layer",
    "linear_to_arrays",
    "linear_from_arrays",
]


@dataclasses.dataclass(frozen=True)
class QuipConfig:
    bits: int = 2
    method: str = "ldlq"  # near | stoch | ldlq | ldlq_stoch | ldlq_rg | greedy
    incoherence: bool = True
    transform: inc.TransformKind = "kronecker"  # | "hadamard" | "none"
    rho: float = 2.4
    alpha: float = 0.01
    rescale: bool = True
    permute: bool = True
    spectrum_range: Optional[bool] = None  # default: == incoherence
    greedy_passes: int = 10
    block: int = 128
    use_kernel: bool = True  # Pallas quant_matmul on the inference path

    @property
    def maxq(self) -> int:
        return 2**self.bits - 1

    def label(self) -> str:
        return f"{self.method}{'+incp' if self.incoherence else ''}@{self.bits}b"


@dataclasses.dataclass
class QuantizedLinear:
    """Inference-ready quantized linear layer: y = x @ W_eff^T.

    ``packed``: (packed_rows(n), m) int32 along the reduction dim.
    ``state``:  transforms + scales needed to apply/revert Alg. 2.
    """

    packed: jax.Array
    bits: int
    m: int
    n: int
    state: inc.PreprocessState
    use_kernel: bool = True

    def dequantize(self) -> jax.Array:
        """Materialize W_eff (m, n) — tests/export only."""
        Wq = packing.unpack(self.packed, self.bits, self.n).astype(jnp.float32)
        return inc.incoherence_postprocess(Wq, self.state)

    def __call__(
        self, x: jax.Array, *, use_kernel: Optional[bool] = None
    ) -> jax.Array:
        """y = x @ W_eff^T with x (..., n) — structured inference path.

        ``use_kernel`` overrides the layer default for this call: the
        serving engine's paged decode passes ``True`` so every projection
        dispatches through the Pallas ``quant_matmul`` path (jnp oracle
        off-TPU) regardless of how the layer was built.
        """
        st = self.state
        h = x if st.D is None else x / st.D
        h = inc.apply_transform(st.V, h)
        z = self._matmul(h, use_kernel=use_kernel)
        return inc.apply_transform(st.U, z, inverse=True)

    def _matmul(
        self, h: jax.Array, use_kernel: Optional[bool] = None
    ) -> jax.Array:
        """z = h @ deq(Wq)^T, deq(q) = (2s/maxq)·q − s."""
        uk = self.use_kernel if use_kernel is None else use_kernel
        if uk:
            from repro.kernels.quant_matmul import ops as qmm

            return qmm.quant_matmul(
                h, self.packed, self.bits, self.n, self.state.s, self.state.maxq
            )
        Wq = packing.unpack(self.packed, self.bits, self.n)
        Wd = inc.from_grid(Wq.astype(h.dtype), self.state.s.astype(h.dtype), self.state.maxq)
        return h @ Wd.T


# ---------------------------------------------------------------------------
# Serialization hooks (repro.serve.artifacts)
#
# A QuantizedLinear persists as packed ints + the data-dependent scale
# factors; the orthogonal transforms are NOT stored — they regenerate
# bit-identically from (kind, n, seed), which is what makes shipping
# quantized checkpoints nearly free (Sec. 4.1).
# ---------------------------------------------------------------------------


def _transform_meta(t: inc.OrthogonalTransform) -> dict:
    return {
        "kind": t.kind,
        "n": t.n,
        "seed": t.seed,
        "permute": t.perm is not None,
    }


def linear_to_arrays(layer: QuantizedLinear) -> tuple[dict, dict]:
    """Split a layer into (arrays-to-store, json-able metadata)."""
    arrays = {"packed": layer.packed, "s": layer.state.s}
    if layer.state.D is not None:
        arrays["D"] = layer.state.D
    meta = {
        "bits": layer.bits,
        "m": layer.m,
        "n": layer.n,
        "maxq": layer.state.maxq,
        "use_kernel": layer.use_kernel,
        "U": _transform_meta(layer.state.U),
        "V": _transform_meta(layer.state.V),
    }
    return arrays, meta


def linear_from_arrays(arrays: dict, meta: dict) -> QuantizedLinear:
    """Rebuild a QuantizedLinear; transforms regenerate from their seeds."""
    m, n, bits = meta["m"], meta["n"], meta["bits"]
    packed = jnp.asarray(arrays["packed"], jnp.int32)
    if packed.shape != packing.packed_shape(m, n, bits):
        raise ValueError(
            f"packed weight shape {packed.shape} != expected "
            f"{packing.packed_shape(m, n, bits)} for ({m}, {n}) @ {bits}b"
        )
    mk = lambda d: inc.make_transform(
        d["kind"], d["n"], d["seed"], permute=d["permute"]
    )
    state = inc.PreprocessState(
        U=mk(meta["U"]),
        V=mk(meta["V"]),
        D=None if "D" not in arrays else jnp.asarray(arrays["D"], jnp.float32),
        s=jnp.asarray(arrays["s"], jnp.float32),
        maxq=meta["maxq"],
    )
    return QuantizedLinear(
        packed=packed, bits=bits, m=m, n=n, state=state,
        use_kernel=meta.get("use_kernel", False),
    )


def quantize_layer(
    W: jax.Array,
    H: jax.Array,
    cfg: QuipConfig,
    *,
    seed: int = 0,
    key: Optional[jax.Array] = None,
    collect_stats: bool = True,
) -> tuple[QuantizedLinear, dict]:
    """Algorithm 3 on one layer.  W: (m, n), H: (n, n) SPD proxy Hessian.

    With ``collect_stats`` the returned dict is a per-layer *quality
    report* (DESIGN.md §13): the incoherence µ(W)/µ(H) before and after
    preprocessing, the raw Hessian's spectrum extremes and condition
    number, the absolute and H-relative proxy loss, weight-error norms,
    and the wall-clock spent in this call.  These are the numbers QuIP's
    guarantees are stated in — recording them is what makes a bad
    Hessian or a silently-skipped transform visible at quantize time
    instead of at perplexity time.  The µ(H) measurements eigendecompose
    H twice; at smoke scale that is free, at cluster scale pass
    ``collect_stats=False`` on the hot path and audit a layer sample.
    """
    t0 = time.perf_counter()
    m, n = W.shape
    W = W.astype(jnp.float32)
    H = H.astype(jnp.float32)
    spectrum = (
        cfg.spectrum_range if cfg.spectrum_range is not None else cfg.incoherence
    )
    if cfg.incoherence:
        Wg, Ht, state = inc.incoherence_preprocess(
            W,
            H,
            bits=cfg.bits,
            seed=seed,
            rho=cfg.rho,
            alpha=cfg.alpha,
            kind=cfg.transform,
            rescale=cfg.rescale,
            permute=cfg.permute,
            spectrum_range=spectrum,
        )
    else:
        # Baseline processing: damping only, identity transforms.
        Ht = damp(H, cfg.alpha)
        s = (
            inc.quant_range(W, cfg.rho)
            if spectrum
            else jnp.max(jnp.abs(W))
        )
        state = inc.PreprocessState(
            U=inc.make_transform("none", m, 0),
            V=inc.make_transform("none", n, 0),
            D=None,
            s=s,
            maxq=cfg.maxq,
        )
        Wg = inc.to_grid(W, s, cfg.maxq)

    kw = {}
    if cfg.method in ("ldlq", "ldlq_stoch"):
        kw["block"] = cfg.block
    if cfg.method in ("ldlq_rg", "greedy"):
        kw["greedy_passes"] = cfg.greedy_passes
    if key is None:
        key = jax.random.PRNGKey(seed ^ 0x5EED)
    Wq = round_weights(cfg.method, Wg, Ht, cfg.maxq, key, **kw)

    packed = packing.pack(Wq.astype(jnp.int32), cfg.bits)
    layer = QuantizedLinear(
        packed=packed, bits=cfg.bits, m=m, n=n, state=state,
        use_kernel=cfg.use_kernel,
    )
    stats: dict = {}
    if collect_stats:
        What = layer.dequantize()
        err = What - W
        # post-incoherence W on its native scale: invert only the grid
        # map (to_grid is affine), leaving the U·W·Vᵀ conjugation in
        # place — µ of exactly what the rounding method saw
        W_post = inc.from_grid(Wg, state.s, state.maxq)
        evals_pre, Q_pre = jnp.linalg.eigh(H)
        _, Q_post = jnp.linalg.eigh(Ht)
        lmin = float(jnp.min(evals_pre))
        lmax = float(jnp.max(evals_pre))
        ploss = float(proxy_loss(What, W, H))
        # H-relative proxy loss: normalize by tr(W H Wᵀ), the proxy value
        # of quantizing everything to zero — scale-free across layers
        whw = float(jnp.einsum("ij,jk,ik->", W, H, W))
        stats = {
            "proxy_loss": ploss,
            "proxy_rel": ploss / whw if whw > 0 else 0.0,
            "frob_rel_err": float(
                jnp.linalg.norm(err) / jnp.linalg.norm(W)
            ),
            "max_abs_err": float(jnp.max(jnp.abs(err))),
            "s": float(state.s),
            "mu_w_pre": float(inc.mu_weight(W)),
            "mu_w_post": float(inc.mu_weight(W_post)),
            "mu_h_pre": float(
                jnp.max(jnp.abs(Q_pre)) * jnp.sqrt(float(n))
            ),
            "mu_h_post": float(
                jnp.max(jnp.abs(Q_post)) * jnp.sqrt(float(n))
            ),
            "h_lambda_min": lmin,
            "h_lambda_max": lmax,
            "h_cond": lmax / max(lmin, 1e-30),
            "m": m,
            "n": n,
            "bits": cfg.bits,
            "method": cfg.label(),
            "wall_s": time.perf_counter() - t0,
        }
    return layer, stats
