from repro.kernels.kron_mul.ops import kron_mul

__all__ = ["kron_mul"]
