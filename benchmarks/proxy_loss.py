"""Tables 14/15 analogue: proxy loss by rounding method; biased vs unbiased.

Paper: at 2 bits LDLQ/LDLQ-RG/Greedy are roughly equivalent and all beat
Near (Table 14); unbiased (stochastic) rounding is WORSE than biased
nearest inside LDLQ, increasingly so at low bits (Table 15)."""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.methods import round_weights
from repro.core.hessian import damp
from repro.core.incoherence import to_grid, quant_range, from_grid
from repro.core.proxy import proxy_loss

from benchmarks.common import emit


def _setup(n=256, m=128, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    W = jax.random.normal(k1, (m, n)) * 0.02
    X = jax.random.normal(k2, (2048, n // 8))
    A = jax.random.normal(jax.random.PRNGKey(seed + 1), (n // 8, n))
    Xf = X @ A  # low-rank-ish activations
    H = damp(Xf.T @ Xf / 2048, 0.01)
    return W, H


def run(args) -> dict:
    W, H = _setup()
    results = {}
    for bits in (4, 3, 2):
        maxq = 2**bits - 1
        s = quant_range(W, 2.4)
        Wg = to_grid(W, s, maxq)
        for method in ("near", "stoch", "ldlq", "ldlq_stoch", "ldlq_rg", "greedy"):
            key = jax.random.PRNGKey(bits * 10)
            kw = {"greedy_passes": 3} if method in ("ldlq_rg", "greedy") else {}
            Wq = round_weights(method, Wg, H, maxq, key, **kw)
            l = float(proxy_loss(from_grid(Wq, s, maxq), W, H))
            results[f"{method}@{bits}b"] = l
            emit(f"proxy_loss/{method}@{bits}b", 0.0, f"proxy={l:.5g}")
    # Table 15 digest: unbiased - biased gap per bits
    for bits in (4, 3, 2):
        gap = results[f"ldlq_stoch@{bits}b"] - results[f"ldlq@{bits}b"]
        results[f"stoch_minus_near_gap@{bits}b"] = gap
        emit(f"proxy_loss/unbiased_gap@{bits}b", 0.0,
             f"gap={gap:.5g} (paper: positive, grows at low bits)")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/proxy_loss.json")
    args = ap.parse_args(argv)
    results = run(args)
    print(json.dumps(results, indent=1))
    if args.out:
        import pathlib

        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
