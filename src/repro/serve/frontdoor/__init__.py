"""Streaming front door: the asyncio HTTP/SSE server that drives the
engine tick loop and makes overload a first-class, tested regime
(DESIGN.md §14).

Layout
------
- :mod:`server`    — :class:`FrontDoor`: HTTP/1.1 + SSE on asyncio, owns
  the engine thread and the tick task, graceful drain on SIGTERM/SIGINT.
- :mod:`admission` — request validation, ``--tenants`` spec parsing, and
  the typed :class:`AdmissionRejected` → HTTP mapping (429/413 bodies,
  Retry-After).
- :mod:`streaming` — SSE encoding and the cursor-diff
  :class:`TokenStream` that fans tick results out to clients.
- :mod:`ladder`    — the load-shedding :class:`DegradationLadder`
  (shrink speculative K → disable speculation → shed lowest class).
- :mod:`drain`     — :class:`DrainReport` + the KV-pool leak gate.
- :mod:`wire`      — stdlib HTTP/1.1 wire helpers (request parsing,
  response framing, client-side ``open_http``) shared with the fleet
  router and supervisor probes (:mod:`repro.serve.fleet`).
"""
from repro.serve.frontdoor.admission import parse_tenants, rejection_response
from repro.serve.frontdoor.drain import DrainReport, leak_gate
from repro.serve.frontdoor.ladder import DegradationLadder, LadderConfig
from repro.serve.frontdoor.server import FrontDoor, run_server
from repro.serve.frontdoor.streaming import TokenStream, sse_event

__all__ = [
    "DegradationLadder",
    "DrainReport",
    "FrontDoor",
    "LadderConfig",
    "TokenStream",
    "leak_gate",
    "parse_tenants",
    "rejection_response",
    "run_server",
    "sse_event",
]
