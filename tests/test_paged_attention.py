"""Paged-attention kernel parity sweeps: interpret-mode Pallas kernels
(decode + chunked prefill) vs the dense gather oracles, across ragged
context lengths, page-boundary-straddling contexts/chunks, GQA group
sizes, and int8 pages — plus the ValueError shape-check contract for the
Pallas kernel entry points (usable errors under ``python -O``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (
    paged_attention_kernel,
    paged_gqa_decode,
    paged_gqa_decode_ref,
    paged_gqa_prefill,
    paged_gqa_prefill_ref,
    paged_prefill_kernel,
)


def _setup(
    *, L=2, P=9, ps=4, KV=2, G=2, hd=16, B=3, Pa=3, int8=False, seed=0
):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    H = KV * G
    if int8:
        kp = jax.random.randint(ks[0], (L, P, ps, KV, hd), -127, 128, jnp.int8)
        vp = jax.random.randint(ks[1], (L, P, ps, KV, hd), -127, 128, jnp.int8)
        k_sc = jnp.abs(jax.random.normal(ks[4], (L, P, ps, KV))) * 0.02 + 1e-3
        v_sc = jnp.abs(jax.random.normal(ks[5], (L, P, ps, KV))) * 0.02 + 1e-3
    else:
        kp = jax.random.normal(ks[0], (L, P, ps, KV, hd), jnp.float32)
        vp = jax.random.normal(ks[1], (L, P, ps, KV, hd), jnp.float32)
        k_sc = v_sc = None
    q = jax.random.normal(ks[2], (B, H, hd), jnp.float32)
    kn = jax.random.normal(ks[3], (B, KV, hd), jnp.float32) * 0.5
    vn = jax.random.normal(ks[3], (B, KV, hd), jnp.float32) * 0.5
    # every lane gets a distinct page permutation (physical != logical)
    rng = np.random.default_rng(seed)
    bt = jnp.asarray(
        np.stack([rng.permutation(np.arange(1, P))[:Pa] for _ in range(B)]),
        jnp.int32,
    )
    return q, kn, vn, kp, vp, bt, k_sc, v_sc


def _both(q, kn, vn, kp, vp, bt, cl, layer, k_sc=None, v_sc=None):
    out_k = paged_gqa_decode(
        q, kn, vn, kp, vp, bt, cl, layer=layer, k_scale=k_sc, v_scale=v_sc,
        interpret=True,
    )
    out_r = paged_gqa_decode_ref(
        q, kn, vn, kp, vp, bt, cl, layer=layer, k_scale=k_sc, v_scale=v_sc,
    )
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("G", [1, 2, 4])
def test_kernel_matches_oracle_gqa_groups(G):
    q, kn, vn, kp, vp, bt, *_ = _setup(G=G, seed=G)
    cl = jnp.array([7, 4, 11], jnp.int32)  # ragged, mid-page
    for layer in range(kp.shape[0]):
        _both(q, kn, vn, kp, vp, bt, cl, layer)


def test_kernel_page_boundary_straddles():
    """ctx_len exactly at page edges, one past, empty, and full."""
    q, kn, vn, kp, vp, bt, *_ = _setup(ps=4, Pa=3, seed=11)
    for cl in ([4, 8, 12], [5, 9, 1], [0, 3, 12], [1, 4, 5]):
        _both(q, kn, vn, kp, vp, bt, jnp.asarray(cl, jnp.int32), 1)


def test_kernel_int8_pages():
    q, kn, vn, kp, vp, bt, k_sc, v_sc = _setup(int8=True, seed=5)
    cl = jnp.array([6, 2, 9], jnp.int32)
    _both(q, kn, vn, kp, vp, bt, cl, 0, k_sc, v_sc)


def test_kernel_ignores_unattended_page_contents():
    """Pages past ctx_len (incl. scratch-page fill in the block table) must
    not leak into the output, whatever they contain."""
    q, kn, vn, kp, vp, bt, *_ = _setup(seed=7)
    # all lanes share one block table row so the attended/poisoned page
    # sets are disjoint across the batch
    bt = jnp.broadcast_to(bt[:1], bt.shape)
    cl = jnp.array([3, 4, 2], jnp.int32)  # only the first page matters
    out1 = paged_gqa_decode(
        q, kn, vn, kp, vp, bt, cl, layer=0, interpret=True
    )
    # poison every page the block tables point at beyond page 0 of each lane
    poisoned = kp.at[:, np.asarray(bt[0, 1:])].set(1e4)
    out2 = paged_gqa_decode(
        q, kn, vn, poisoned, vp, bt, cl, layer=0, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_short_lanes_never_dereference_dead_pages():
    """The index-map clamp: block tables are bucketed to the LONGEST live
    context, so short lanes carry dead trailing entries.  With the clamp
    those entries past entry 0 are never dereferenced (the grid step
    re-asks for the lane's last valid page and Mosaic skips the DMA) — so
    even garbage page ids past a lane's end must leave the output
    untouched.  An EMPTY lane clamps every step to entry 0, which must
    stay a valid page id (the engine's zero-fill/scratch convention)."""
    q, kn, vn, kp, vp, bt, *_ = _setup(P=9, ps=4, Pa=3, seed=13)
    cl = jnp.array([5, 0, 12], jnp.int32)  # lane 0 short, lane 1 EMPTY
    out1 = paged_gqa_decode(q, kn, vn, kp, vp, bt, cl, layer=1,
                            interpret=True)
    # rewrite every dead entry PAST entry 0 to an arbitrary other page:
    # lane 0 attends 2 pages (keeps bt[0,:2]), lane 1 attends none (its
    # entry 0 stays — the one slot an empty lane still reads), lane 2 all
    bt2 = np.asarray(bt).copy()
    bt2[0, 2:] = bt2[2, 0]
    bt2[1, 1:] = bt2[0, 0]
    out2 = paged_gqa_decode(q, kn, vn, kp, vp, jnp.asarray(bt2), cl,
                            layer=1, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # and the clamped kernel still matches the oracle on the ragged batch
    _both(q, kn, vn, kp, vp, bt, cl, 1)


def test_epilogue_self_attention_dominates_empty_context():
    """ctx_len = 0 lanes reduce to pure self-attention: out == v_new."""
    q, kn, vn, kp, vp, bt, *_ = _setup(seed=3)
    cl = jnp.zeros((3,), jnp.int32)
    out = paged_gqa_decode(q, kn, vn, kp, vp, bt, cl, layer=0, interpret=True)
    B, H, hd = out.shape
    KV = vn.shape[1]
    want = jnp.repeat(vn, H // KV, axis=1)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-6
    )


# ---------------------------------------------------------------------------
# Chunked-prefill kernel parity (interpret mode) vs the jnp oracle
# ---------------------------------------------------------------------------


def _setup_prefill(
    *, L=2, P=9, ps=4, KV=2, G=2, hd=16, B=3, Pa=3, C=5, int8=False, seed=0
):
    q, kn, vn, kp, vp, bt, k_sc, v_sc = _setup(
        L=L, P=P, ps=ps, KV=KV, G=G, hd=hd, B=B, Pa=Pa, int8=int8, seed=seed
    )
    ks = jax.random.split(jax.random.PRNGKey(seed + 100), 3)
    H = KV * G
    qc = jax.random.normal(ks[0], (B, C, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, C, KV, hd), jnp.float32) * 0.5
    vc = jax.random.normal(ks[2], (B, C, KV, hd), jnp.float32) * 0.5
    return qc, kc, vc, kp, vp, bt, k_sc, v_sc


def _both_prefill(q, kc, vc, kp, vp, bt, cl, layer, k_sc=None, v_sc=None):
    out_k = paged_gqa_prefill(
        q, kc, vc, kp, vp, bt, cl, layer=layer, k_scale=k_sc, v_scale=v_sc,
        interpret=True,
    )
    out_r = paged_gqa_prefill_ref(
        q, kc, vc, kp, vp, bt, cl, layer=layer, k_scale=k_sc, v_scale=v_sc,
    )
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("G", [1, 2, 4])
def test_prefill_kernel_matches_oracle_gqa_groups(G):
    q, kc, vc, kp, vp, bt, *_ = _setup_prefill(G=G, seed=G)
    cl = jnp.array([7, 4, 11], jnp.int32)  # ragged, mid-page
    for layer in range(kp.shape[0]):
        _both_prefill(q, kc, vc, kp, vp, bt, cl, layer)


def test_prefill_kernel_ragged_and_empty_contexts():
    """Fresh admissions (ctx 0), page-edge starts, one past the edge, and
    chunks straddling a page boundary mid-batch."""
    q, kc, vc, kp, vp, bt, *_ = _setup_prefill(ps=4, Pa=3, C=6, seed=11)
    for cl in ([0, 0, 0], [4, 8, 12], [5, 9, 1], [0, 3, 11]):
        _both_prefill(q, kc, vc, kp, vp, bt, jnp.asarray(cl, jnp.int32), 1)


def test_prefill_kernel_chunk_wider_than_page():
    """A chunk spanning multiple pages' worth of tokens (C > ps) keeps its
    intra-chunk causal structure."""
    q, kc, vc, kp, vp, bt, *_ = _setup_prefill(ps=4, Pa=4, P=17, C=9, seed=2)
    cl = jnp.array([3, 0, 7], jnp.int32)
    _both_prefill(q, kc, vc, kp, vp, bt, cl, 0)


def test_prefill_kernel_int8_pages():
    q, kc, vc, kp, vp, bt, k_sc, v_sc = _setup_prefill(int8=True, seed=5)
    cl = jnp.array([6, 2, 9], jnp.int32)
    _both_prefill(q, kc, vc, kp, vp, bt, cl, 0, k_sc, v_sc)


def test_prefill_kernel_single_token_chunk_matches_decode():
    """A C=1 chunk is exactly a decode step: the prefill kernel's causal
    block degenerates to the decode epilogue's self-token merge."""
    q, kn, vn, kp, vp, bt, *_ = _setup(seed=9)
    cl = jnp.array([7, 4, 11], jnp.int32)
    dec = paged_gqa_decode(
        q, kn, vn, kp, vp, bt, cl, layer=0, interpret=True
    )
    pre = paged_gqa_prefill(
        q[:, None], kn[:, None], vn[:, None], kp, vp, bt, cl, layer=0,
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(pre[:, 0]), np.asarray(dec), rtol=1e-5, atol=1e-5
    )


def test_prefill_kernel_ignores_unattended_page_contents():
    """Context pages past ctx_len must not leak into any chunk row."""
    q, kc, vc, kp, vp, bt, *_ = _setup_prefill(seed=7)
    bt = jnp.broadcast_to(bt[:1], bt.shape)
    cl = jnp.array([3, 4, 2], jnp.int32)  # only the first page matters
    out1 = paged_gqa_prefill(
        q, kc, vc, kp, vp, bt, cl, layer=0, interpret=True
    )
    poisoned = kp.at[:, np.asarray(bt[0, 1:])].set(1e4)
    out2 = paged_gqa_prefill(
        q, kc, vc, poisoned, vp, bt, cl, layer=0, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# ---------------------------------------------------------------------------
# shape-check contract (ValueError with named dims, survives python -O)
# ---------------------------------------------------------------------------


def test_paged_prefill_kernel_shape_errors():
    q, kc, vc, kp, vp, bt, *_ = _setup_prefill(C=4)
    cl = jnp.array([1, 1, 1], jnp.int32)
    B, C, H, hd = q.shape
    KV = kc.shape[2]
    qg = q.reshape(B, C, KV, H // KV, hd).transpose(0, 2, 3, 1, 4)
    with pytest.raises(ValueError, match="grouped chunk queries"):
        paged_prefill_kernel(q, kc, vc, kp, vp, bt, cl, layer=0,
                             interpret=True)
    with pytest.raises(ValueError, match="k_chunk"):
        paged_prefill_kernel(qg, kc[:, :2], vc, kp, vp, bt, cl, layer=0,
                             interpret=True)
    with pytest.raises(ValueError, match="layer"):
        paged_prefill_kernel(qg, kc, vc, kp, vp, bt, cl, layer=99,
                             interpret=True)
    with pytest.raises(ValueError, match="block_tables"):
        paged_prefill_kernel(qg, kc, vc, kp, vp, bt[:2], cl, layer=0,
                             interpret=True)
    with pytest.raises(ValueError, match="int8"):
        qq, kcc, vcc, kq, vq, btq, ksc, vsc = _setup_prefill(int8=True, C=4)
        qqg = qq.reshape(B, C, KV, H // KV, hd).transpose(0, 2, 3, 1, 4)
        paged_prefill_kernel(qqg, kcc, vcc, kq, vq, btq, cl, layer=0,
                             interpret=True)


def test_paged_kernel_shape_errors():
    q, kn, vn, kp, vp, bt, *_ = _setup()
    cl = jnp.array([1, 1, 1], jnp.int32)
    with pytest.raises(ValueError, match="KV"):
        paged_attention_kernel(
            q.reshape(3, 2, 2, 16)[:, :1], kp, vp, bt, cl, layer=0,
            interpret=True,
        )
    with pytest.raises(ValueError, match="layer"):
        paged_attention_kernel(
            q.reshape(3, 2, 2, 16), kp, vp, bt, cl, layer=99, interpret=True
        )
    with pytest.raises(ValueError, match="block_tables"):
        paged_attention_kernel(
            q.reshape(3, 2, 2, 16), kp, vp, bt[:2], cl, layer=0,
            interpret=True,
        )
    with pytest.raises(ValueError, match="ctx_len"):
        paged_attention_kernel(
            q.reshape(3, 2, 2, 16), kp, vp, bt, cl[:2], layer=0,
            interpret=True,
        )
    with pytest.raises(ValueError, match="int8"):
        qq, knn, vnn, kq, vq, btq, ksc, vsc = _setup(int8=True)
        paged_attention_kernel(
            qq.reshape(3, 2, 2, 16), kq, vq, btq, cl, layer=0, interpret=True
        )


def test_quant_matmul_kernel_shape_errors():
    from repro.core import packing
    from repro.kernels.quant_matmul.kernel import quant_matmul_kernel

    packed = packing.pack(jnp.zeros((128, 128), jnp.int32), 2)
    x = jnp.zeros((8, 128), jnp.float32)
    with pytest.raises(ValueError, match="reduction dim"):
        quant_matmul_kernel(
            x, packed[:4], bits=2, bB=8, bM=128, bK=128, interpret=True
        )
    with pytest.raises(ValueError, match="multiples of tiles"):
        quant_matmul_kernel(
            jnp.zeros((10, 128), jnp.float32), packed, bits=2, bB=8, bM=128,
            bK=128, interpret=True,
        )
    with pytest.raises(ValueError, match="vals-per-word"):
        quant_matmul_kernel(
            x, packed, bits=2, bB=8, bM=128, bK=8, interpret=True
        )


def test_other_kernel_entry_shape_errors():
    from repro.kernels.hadamard.kernel import hadamard_kernel, sylvester
    from repro.kernels.kron_mul.kernel import kron_mul_kernel
    from repro.kernels.ldlq.kernel import ldlq_block_kernel

    with pytest.raises(ValueError, match="power of two"):
        sylvester(12)
    with pytest.raises(ValueError, match="a\\*b"):
        hadamard_kernel(
            jnp.zeros((8, 64)), jnp.ones((64,)), jnp.ones((4, 4)),
            jnp.ones((8, 8)), a=4, b=8, bB=8, interpret=True,
        )
    with pytest.raises(ValueError, match="p\\*q"):
        kron_mul_kernel(
            jnp.zeros((8, 64)), jnp.ones((4, 4)), jnp.ones((8, 8)),
            p=4, q=8, bB=8, interpret=True,
        )
    with pytest.raises(ValueError, match="columns"):
        ldlq_block_kernel(
            jnp.zeros((8, 64)), jnp.zeros((8, 64)), jnp.zeros((128, 128)),
            nb=128, bM=8, interpret=True,
        )
