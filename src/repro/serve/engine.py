"""Continuous-batching engine: per-step batch assembly over paged KV.

Each :meth:`Engine.step`:

  1. moves arrived requests into the FCFS queue;
  2. plans the step under the token budget (decode-prioritized, chunked
     prefill with leftover budget; admission claims pages);
  3. ensures every decode lane has a page for its next token, evicting the
     newest running sequence under page pressure (evicted requests requeue
     and later re-prefill their prompt + generated prefix);
  4. executes the step's prefill group — one batched paged dispatch over
     all planned chunks (``paged_prefill``), or a B=1 gather-dense loop
     (the oracle) — and one batched decode forward (fixed ``n_slots``
     lanes, per-lane positions), writing new K/V into the pool and
     appending tokens — greedy by default, or per-request temperature/
     top-p sampling with stop-token support
     (:class:`repro.serve.scheduler.SamplingParams`).

Decode runs one of two adapter paths:

  * gather-dense (default off, reference oracle): materialize every
    context page into a dense ``(L, B, Pmax*ps, KV, hd)`` window, forward,
    scatter new K/V back — an O(allocated pages) copy per emitted token;
  * **paged** (``EngineConfig.paged_decode`` / ``CachedDecoder.paged``):
    hand the adapter per-lane block tables + context lengths and let the
    paged-attention kernel read the pool in place; the new token's K/V is
    scattered inside the same jitted dispatch (donated buffers).  Block
    tables are bucketed to the next power of two of the *attended* page
    count, so step cost tracks live context, not allocation (a handful of
    compiles per pool geometry, reused across steps).

Prefill mirrors the decode split (``EngineConfig.paged_prefill``): the
oracle path re-gathers a dense context window per B=1 chunk, while the
paged path assembles every chunk the scheduler planned this tick into one
padded ``(B, C)`` cross-request batch — lanes bucketed to a power of two,
block tables bucketed to the longest prior context — and runs it as a
single fused dispatch with a donated in-place scatter.  With
``EngineConfig.prefix_cache`` the pool additionally maps full pages of
previously-seen prompt prefixes into newly admitted slots (refcounted,
copy-on-write), so shared system prompts/few-shot headers are admitted at
``prefill_pos > 0`` and never recomputed.

Speculative multi-token decode (``EngineConfig.speculative_k = K > 0``,
paged path only): each decode tick, a host-side self-drafter
(:mod:`repro.serve.drafter` — n-gram prompt lookup over the lane's own
history, no second model) proposes up to K tokens per lane, and ONE fused
padded ``(B, K+1)`` dispatch (``CachedDecoder.verify_paged`` — the
chunked-prefill kernel reused as the verifier) scores every lane's
``[last_emitted, d_1 .. d_K]`` chunk, selects a token per position on
device, and accepts each lane's longest matching draft prefix — so a tick
emits 1 to K+1 tokens per lane for one weight pass.  The dispatch
scatters all fed tokens' K/V; the rejected tail is un-written afterwards
via ``pool.truncate`` (refcount-aware rollback — COW already resolved any
shared page at write time).  Greedy speculative decode is token-identical
to the one-token paged path; accepted extras are charged against the NEXT
step's budget (``TokenBudgetFCFS.charge_accepted`` — rejected drafts are
never charged).

Sampling runs on device by default on the paged path
(``EngineConfig.device_sample``): the softmax/top-p draw is fused into
the decode/verify dispatch with per-request keys
``fold_in(PRNGKey(seed), emission_index)``, making sampled streams
reproducible across batching, eviction/replay, and speculative grouping.
The host-side draw (``launch/serve.py --host-sample``) is kept for
debugging; both are exact argmax at temperature 0.

All device calls are shape-static per bucket: new requests join mid-flight
without recompilation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.adapter import CachedDecoder, sample_tokens
from repro.serve.drafter import make_drafter
from repro.serve.faults import AdmissionRejected, FaultInjected, FaultPlan
from repro.serve.kv_cache import page_bucket, pages_needed
from repro.serve.quality import ShadowSampler, canary_probe
from repro.serve.scheduler import (
    Request,
    RequestState,
    SamplingParams,
    StepPlan,
    TokenBudgetFCFS,
)
from repro.serve.telemetry import (
    NULL_TRACER,
    MetricsRegistry,
    Tracer,
    emit_metrics_line,
)

__all__ = ["Engine", "EngineConfig", "TickResult"]

# counters the engine bumps on the hot path, in reporting order; the
# legacy ``engine.stats`` mapping is a read view over exactly these
_STAT_COUNTERS = (
    "steps",
    "decode_tokens",
    "prefill_tokens",
    "evictions",
    "prefill_batches",
    "prefill_batch_size",  # widest co-batched prefill group seen
    "prefix_hit_tokens",  # prompt tokens admitted from the cache
    "spec_ticks",  # fused verify dispatches run
    "spec_lanes",  # lane-verifications (lanes summed over ticks)
    "draft_tokens",  # tokens the drafter proposed
    "accepted_tokens",  # proposed tokens the verifier accepted
    "rolled_back_tokens",  # rejected drafts un-written (truncate)
    "cancelled",  # requests reaching CANCELLED
    "failed",  # requests reaching FAILED (any reason)
    "deadline_missed",  # FAILED specifically for blowing deadline_s
    "quarantined_lanes",  # lanes the NaN/Inf screen pulled mid-batch
    "admission_rejected",  # submits refused with AdmissionRejected
    # ---- quality canaries (serve/quality.py, DESIGN.md §13) ----
    "canary_runs",  # out-of-band teacher-forced NLL probes run
    "shadow_samples",  # finished requests the drift sampler re-scored
    "shadow_tokens",  # emissions those samples covered
    "shadow_token_flips",  # emissions whose serving/oracle argmax differ
)


# device-cheap anomaly screen: ONE fused reduction over the step's logits
# produces a per-lane finite flag — the only thing shipped to the host is
# a (B,) bool, never the logits themselves
@jax.jit
def _lane_finite(logits):
    return jnp.all(jnp.isfinite(logits), axis=tuple(range(1, logits.ndim)))


@dataclasses.dataclass
class TickResult:
    """What one :meth:`Engine.tick` did — the contract between the pure
    tick function and whatever drives it (the front-door server, the
    in-process :func:`repro.serve.lifecycle.run_to_completion` loop, or
    a test).  ``emitted`` is every (request, token) emission of the tick
    in emission order; ``finished`` is every request that reached a
    terminal state since the previous tick's result was taken (including
    between-tick cancels)."""

    worked: bool
    t: float
    emitted: list  # [(Request, token), ...]
    finished: list  # [Request, ...] newly terminal


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_seq_len: int  # per-sequence token capacity (prompt + generation)
    n_slots: int = 8  # concurrent resident sequences (decode lanes)
    page_size: int = 16
    n_pages: Optional[int] = None  # default: no overcommit (+1 scratch)
    token_budget: int = 64  # tokens processed per step
    prefill_chunk: int = 32
    record_logits: bool = False  # keep per-emission logits (tests/--check)
    paged_decode: bool = False  # decode in place over the page pool
    paged_prefill: bool = False  # batched cross-request prefill over the pool
    prefix_cache: bool = False  # map cached prompt-prefix pages on admit
    kv_int8: bool = False  # int8 KV pages + per-(token, head) scales
    speculative_k: int = 0  # draft depth K (0 = one token per lane per tick)
    draft: str = "ngram"  # self-drafter kind (serve/drafter.py)
    draft_ngram: int = 3  # longest lookup pattern the ngram drafter tries
    device_sample: bool = False  # fuse the token draw into the paged dispatch
    # ---- failure domains (DESIGN.md §12) ----
    deadline_s: Optional[float] = None  # default per-request wall-clock
    #   deadline (from arrival), enforced at tick boundaries
    max_queue: Optional[int] = None  # bounded admission queue: submits past
    #   this many pending requests raise a retryable AdmissionRejected
    # ---- multi-tenant admission (serve/frontdoor, DESIGN.md §14) ----
    tenants: Optional[dict] = None  # tenant name -> scheduler.TenantPolicy
    #   (token-bucket rate limits + default priority class); None = every
    #   tenant unlimited at class 0 (exact legacy FCFS)
    aging_s: float = 2.0  # seconds of queue wait that promote a request
    #   one priority class (bounded-wait starvation freedom)
    max_evictions: Optional[int] = 8  # eviction-storm guard: a request
    #   evicted this many times FAILS ("eviction_storm") instead of
    #   replaying its prefix forever (None = legacy unbounded behavior)
    screen_logits: bool = False  # per-lane NaN/Inf screen on every step's
    #   logits; a poisoned lane is quarantined, co-batched lanes unharmed
    # ---- quality canaries (DESIGN.md §13; serve/quality.py) ----
    canary_every: Optional[float] = None  # seconds between teacher-forced
    #   NLL probes over the pinned canary set (attach_canary); one probe
    #   also fires at run start so the gauge exists from tick zero
    shadow_rate: float = 0.0  # fraction of requests re-scored against
    #   the dense oracle on finish (deterministic crc32 selection)
    shadow_seed: int = 0  # selection seed (stable across processes)

    @property
    def pages_per_seq(self) -> int:
        return pages_needed(self.max_seq_len, self.page_size)

    def total_pages(self) -> int:
        if self.n_pages is not None:
            return self.n_pages
        return self.n_slots * self.pages_per_seq + 1


class Engine:
    def __init__(self, adapter: CachedDecoder, ecfg: EngineConfig, dtype=None,
                 tracer: Optional[Tracer] = None,
                 faults: Optional[FaultPlan] = None):
        self.adapter = adapter
        self.ecfg = ecfg
        self.paged = ecfg.paged_decode or adapter.paged
        self.paged_prefill = ecfg.paged_prefill
        self.spec_k = ecfg.speculative_k
        if self.spec_k < 0:
            raise ValueError(f"speculative_k must be >= 0, got {self.spec_k}")
        if self.spec_k and not self.paged:
            raise ValueError(
                "speculative decode verifies drafts over the paged pool "
                "(the chunked-prefill kernel path); enable paged_decode"
            )
        if ecfg.device_sample and not self.paged:
            raise ValueError(
                "on-device sampling is fused into the paged dispatches; "
                "enable paged_decode (or keep host-side sampling)"
            )
        self.drafter = (
            make_drafter(ecfg.draft, self.spec_k, max_ngram=ecfg.draft_ngram)
            if self.spec_k else None
        )
        if ecfg.kv_int8:
            dtype = jnp.int8
        # the adapter owns pool construction so distributed adapters can
        # hand back a pool whose physical pages live sharded on their mesh
        self.pool = adapter.make_pool(
            n_pages=ecfg.total_pages(),
            page_size=ecfg.page_size,
            n_slots=ecfg.n_slots,
            max_pages_per_seq=ecfg.pages_per_seq,
            dtype=dtype,
            prefix_cache=ecfg.prefix_cache,
        )
        self.scheduler = TokenBudgetFCFS(
            token_budget=ecfg.token_budget, prefill_chunk=ecfg.prefill_chunk,
            max_queue=ecfg.max_queue, tenants=ecfg.tenants,
            aging_s=ecfg.aging_s,
        )
        self.running: list[Request] = []
        self.finished: list[Request] = []
        # per-tick result sinks (reset at every tick() entry): _note_emit
        # and _terminalize record into these so TickResult can hand the
        # server exactly what changed without diffing request state
        self._tick_emitted: list = []
        self._tick_finished: list = []
        # deterministic fault injection (serve/faults.py): the engine owns
        # the plan's dispatch context (tick, lane_rids) and points the
        # pool + adapter hooks at it.  Default: a fresh empty plan — every
        # hook short-circuits on the empty rule list.
        self.faults = faults if faults is not None else FaultPlan()
        self.pool.faults = self.faults
        adapter.faults = self.faults
        self._fault_log_pos = 0  # plan.log entries already reconciled
        # fast-path skip for deadline sweeps; flips on the first deadline
        self._deadlines = ecfg.deadline_s is not None
        # metrics: hot-path counters, pool gauges (live callbacks), and
        # the in-engine latency histograms (one percentile implementation
        # — benchmarks consume these instead of re-deriving latencies)
        self.metrics = MetricsRegistry()
        for name in _STAT_COUNTERS:
            self.metrics.counter(name)
        for name, fn in self.pool.metrics_gauges().items():
            self.metrics.gauge(name, fn=fn)
        self.metrics.gauge("finished", fn=lambda: len(self.finished))
        self.metrics.gauge("faults_injected", fn=lambda: len(self.faults.log))
        # tick-stall watchdog: seconds since the last COMPLETED tick.  A
        # dispatch that wedges inside tick() stops this advancing, so a
        # supervisor (or any external LB reading /healthz) can tell a
        # hung engine from a merely idle one — the tick loop keeps
        # ticking through idleness, so a healthy server's age stays
        # near the driver's sleep period.
        self._last_tick_t = 0.0
        self.metrics.gauge("last_tick_age_s", fn=self.last_tick_age_s)
        for name in ("ttft_s", "itl_s", "queue_s", "e2e_s"):
            self.metrics.histogram(name)
        # quality canaries: the shadow sampler re-scores a deterministic
        # fraction of finished requests against the adapter's dense
        # trunk; the canary probe needs a pinned prompt set, attached
        # via attach_canary (out-of-band — never touches the pool)
        self.shadow = (
            ShadowSampler(adapter, ecfg.shadow_rate, seed=ecfg.shadow_seed,
                          metrics=self.metrics, tracer=NULL_TRACER)
            if ecfg.shadow_rate > 0.0 else None
        )
        if self.shadow is not None:
            for name in ("shadow_max_abs_logit_diff", "shadow_flip_rate"):
                self.metrics.histogram(name)
        if ecfg.canary_every is not None and ecfg.canary_every <= 0:
            raise ValueError(
                f"canary_every must be > 0 seconds, got {ecfg.canary_every}"
            )
        self.canary_tokens: Optional[np.ndarray] = None
        # span tracing is OFF by default: NULL_TRACER's span() is a no-op
        # returning a shared context manager — the whole telemetry tax
        self.tracer = NULL_TRACER
        # engine-relative clock: the epoch is SET HERE (and again by
        # reset_clock) — arrival offsets submitted before the first step
        # are measured against construction time, not first use
        self._t0 = time.perf_counter()
        if tracer is not None:
            self.attach_tracer(tracer)

    # ---- submission -----------------------------------------------------

    def submit(
        self,
        prompt: np.ndarray,
        max_new: int,
        arrival: float = 0.0,
        sampling: Optional[SamplingParams] = None,
        stop_tokens: tuple = (),
        deadline_s: Optional[float] = None,
        tenant: str = "default",
        priority: Optional[int] = None,
        resume_tokens: tuple = (),
    ) -> Request:
        """Submit a request, or raise a typed :class:`AdmissionRejected`:
        non-retryable when the request can never fit this pool (per-
        sequence or total capacity), retryable when the bounded queue is
        full.  Total-capacity accounting discounts full prompt-prefix
        pages the prefix cache already holds — a cached prompt is not
        rejected for pages it will never claim.  The forecast is
        OPTIMISTIC: ``prompt + max_new`` is a ceiling (stop tokens can
        end generation early), so the discount gives a cached prompt the
        benefit of the doubt; a request whose prefix actually outgrows
        the pool fails cleanly later ("capacity", via the queue-head
        feasibility backstop) instead of wedging the engine.
        ``deadline_s`` overrides ``EngineConfig.deadline_s`` for this
        request.  ``tenant`` bills the submit against that tenant's
        token bucket (retryable ``rate_limited`` rejection with a
        retry-after hint when overdrawn); ``priority`` pins the class
        (None inherits the tenant policy's).

        ``resume_tokens`` seeds the request with tokens a PREVIOUS
        attempt already emitted (fleet failover, DESIGN.md §15): the
        request prefills over ``prompt + resume_tokens`` — the same
        replay machinery eviction uses — and continues emitting at
        emission index ``len(resume_tokens)``.  ``max_new`` keeps its
        original meaning (total generation budget including the resumed
        tokens), so a resumed request's stream is token-identical to an
        uninterrupted run for greedy decoding and, with on-device
        sampling, for seeded sampling too (the draw key folds in the
        emission index, which resumes where it left off; the host-side
        numpy sampler's generator state cannot be fast-forwarded, so
        only those two modes carry the identity guarantee)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        total = prompt.size + max_new
        if total > self.pool.seq_capacity_tokens():
            self.metrics.inc("admission_rejected")
            raise AdmissionRejected(
                "over_capacity", retryable=False,
                needed_pages=pages_needed(total, self.ecfg.page_size),
                available_pages=self.pool.max_pages_per_seq,
            )
        need = max(1, pages_needed(total, self.ecfg.page_size))
        # -1: even a full-prefix hit claims one private copy-on-admit page
        cached = min(self.pool.cached_prefix_pages(prompt), need - 1)
        if need - cached > self.pool.n_pages - 1:
            self.metrics.inc("admission_rejected")
            raise AdmissionRejected(
                "over_capacity", retryable=False,
                needed_pages=need - cached,
                available_pages=self.pool.n_pages - 1,
            )
        resume = [int(t) for t in resume_tokens]
        if resume:
            if len(resume) >= max_new:
                raise ValueError(
                    f"resume_tokens already meets max_new "
                    f"({len(resume)} >= {max_new}); nothing to resume")
            if resume[-1] in tuple(stop_tokens):
                raise ValueError(
                    "resume_tokens ends on a stop token; the original "
                    "stream already finished")
        req = Request(
            prompt=prompt, max_new=max_new, arrival=arrival,
            sampling=sampling or SamplingParams(),
            stop_tokens=tuple(stop_tokens),
            deadline_s=(self.ecfg.deadline_s if deadline_s is None
                        else deadline_s),
            tenant=tenant, priority=priority,
        )
        if resume:
            # seed the replay state exactly as an eviction would leave
            # it: out_tokens carries the prior emissions (prefill covers
            # req.prefix = prompt + resume), token_times backfills with
            # the arrival stamp so latency accounting stays aligned, and
            # ``resumed`` lets the stream layer skip re-sending them
            req.out_tokens = resume
            req.token_times = [arrival] * len(resume)
            req.resumed = len(resume)
        if self.shadow is not None:
            # decided at submit so the decode paths know to materialize
            # this request's emission logits (crc32 of (seed, rid) —
            # deterministic across processes and batch composition)
            req.shadow = self.shadow.selects(req.rid)
        try:
            self.scheduler.submit(req)
        except AdmissionRejected:
            self.metrics.inc("admission_rejected")
            raise
        if req.deadline_s is not None:
            self._deadlines = True
        return req

    def cancel(self, rid: int) -> bool:
        """Cancel a request by id from ANY live state — waiting, queued,
        mid-prefill, mid-decode, or mid-speculative-verify.  Pages and
        prefix-trie refcounts are released exactly as a finish would
        (the trie keeps its own refs on cached pages).  Returns whether
        a live request was found; terminal requests are left alone."""
        now = self.now()
        sch = self.scheduler
        for r in sch.waiting:
            if r.rid == rid:
                sch.waiting.remove(r)
                self._cancel(r, now)
                return True
        for r in sch.queue:
            if r.rid == rid:
                sch.queue.remove(r)
                self._cancel(r, now)
                return True
        for r in self.running:
            if r.rid == rid:
                self._cancel(r, now)  # _terminalize detaches from running
                return True
        return False

    # ---- telemetry ------------------------------------------------------

    @property
    def stats(self) -> dict:
        """Legacy read view: the hot-path counters as a plain dict (the
        registry is the source of truth; mutate via ``self.metrics``)."""
        return {n: self.metrics.counter(n).value for n in _STAT_COUNTERS}

    def attach_tracer(self, tracer: Tracer) -> None:
        """Wire a tracer through the whole stack: engine phase spans, the
        adapter's fused-dispatch spans, and the scheduler's lifecycle
        events all record into it.  The tracer's clock becomes the
        engine clock (span times share the request-arrival epoch), and
        ``sync=True`` tracers get a barrier that blocks on the pool
        buffers every fused dispatch donates and returns — so synced
        span durations are honest device time, not dispatch time."""
        tracer.clock = self.now
        if tracer.sync and tracer.sync_fn is None:
            tracer.sync_fn = self._sync_barrier
        tracer.tags.update(self.adapter.trace_tags())
        self.tracer = tracer
        self.adapter.tracer = tracer
        self.scheduler.tracer = tracer
        if self.shadow is not None:
            self.shadow.tracer = tracer

    def attach_canary(self, tokens: np.ndarray) -> None:
        """Pin the canary prompt set: (B, S) int32 token ids scored
        teacher-forced by every canary probe.  The set must stay FIXED
        for the gauge to be comparable across ticks/restarts — hence
        attached once, not sampled from traffic."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim == 1:
            tokens = tokens[None]
        if tokens.ndim != 2 or tokens.shape[1] < 2:
            raise ValueError(
                f"canary set must be (B, S>=2) token ids, got {tokens.shape}"
            )
        self.canary_tokens = tokens

    def _run_canary(self) -> None:
        """One out-of-band quality probe: teacher-forced NLL over the
        pinned canary set plus per-layer activation absmax / saturation,
        all published as gauges.  Runs the adapter's dense trunk against
        an EMPTY context — the KV pool is untouched, so live traffic
        stays token-identical with canaries on."""
        nll, act = canary_probe(self.adapter, self.canary_tokens)
        m = self.metrics
        m.gauge("canary_nll").set(nll)
        m.inc("canary_runs")
        absmax, sat = act["absmax"], act["sat"]
        m.gauge("act_absmax").set(float(absmax.max()))
        m.gauge("act_sat").set(float(sat.max()))
        for i in range(len(absmax)):
            m.gauge(f"act_absmax:{i}").set(float(absmax[i]))
            m.gauge(f"act_sat:{i}").set(float(sat[i]))
        self.tracer.event(
            "canary_probe", nll=nll,
            act_absmax=float(absmax.max()), act_sat=float(sat.max()),
            prompts=int(self.canary_tokens.shape[0]),
            tokens=int(self.canary_tokens.size),
        )

    def _sync_barrier(self) -> None:
        """Block until every enqueued device step has retired.  The pool
        K/V tensors are donated into and returned by every fused dispatch
        (and the oracle path's scatters), so blocking on them drains the
        per-device stream up to the last KV write."""
        jax.block_until_ready((self.pool.k, self.pool.v))

    # ---- main loop ------------------------------------------------------

    def now(self) -> float:
        """Engine-relative seconds.  Epoch: Engine construction, or the
        most recent :meth:`reset_clock` — request ``arrival`` offsets and
        all recorded span/lifecycle times share it."""
        return time.perf_counter() - self._t0

    def reset_clock(self) -> None:
        """Restart the engine-relative clock NOW (e.g. after a warm-up
        run, so arrival offsets of a measured workload start from zero).
        Takes effect immediately — not lazily on the next ``now()`` —
        so arrivals submitted before the next step share the epoch."""
        self._t0 = time.perf_counter()
        self._last_tick_t = 0.0

    def last_tick_age_s(self) -> float:
        """Seconds since the last completed :meth:`tick` (since the
        clock epoch if none has completed yet).  The tick-stall
        watchdog: a dispatch wedged INSIDE a tick stops this advancing
        past the stall threshold, which flips ``/healthz`` unhealthy so
        a fleet supervisor can hard-restart the replica."""
        return self.now() - self._last_tick_t

    def reset_stats(self) -> None:
        """Zero the cumulative counters and latency histograms (pairs
        with reset_clock after a warm-up run, so reported stats cover
        only the measured workload).  Live pool gauges are callbacks —
        they keep reporting current state — but the pool's high-water
        mark rebases to its current usage."""
        self.metrics.reset()
        self.pool.peak_pages_in_use = self.pool.pages_in_use

    # ---- lifecycle API (what a driver needs; DESIGN.md §14) -------------
    #
    # The engine does not own a loop: it exposes the pure ``tick()``
    # plus these predicates, and a driver — the in-process
    # ``lifecycle.run_to_completion`` (what ``run()`` delegates to) or
    # the front-door server's async tick task — decides when to tick,
    # when to sleep, and when to drain.

    @property
    def idle(self) -> bool:
        """No pending (waiting/queued) and no running work."""
        return not (self.scheduler.pending or self.running)

    def next_arrival(self) -> Optional[float]:
        """Arrival time of the earliest not-yet-arrived request (engine
        clock), or None — what an idle driver may sleep until."""
        return self.scheduler.waiting[0].arrival if self.scheduler.waiting \
            else None

    def live_requests(self) -> list[Request]:
        """Every non-terminal request: waiting, queued, and running."""
        sch = self.scheduler
        return [*sch.waiting, *sch.queue, *self.running]

    def cancel_all(self) -> list[Request]:
        """Cancel every live request (drain-deadline teardown).  Returns
        the requests cancelled; pages are released refcount-exactly."""
        victims = self.live_requests()
        for r in victims:
            self.cancel(r.rid)
        return victims

    def set_speculative_k(self, k: int) -> int:
        """Clamp the LIVE speculative draft depth to ``k`` (degradation
        ladder hook).  Can only shrink below — or restore up to — the
        configured ``EngineConfig.speculative_k`` (the drafter and the
        verify dispatch buckets were built for it); with 0 the engine
        falls back to plain one-token decode ticks.  Returns the depth
        actually in effect.  Reversible: calling with the configured K
        restores full speculation."""
        if k < 0:
            raise ValueError(f"speculative depth must be >= 0, got {k}")
        self.spec_k = min(k, self.ecfg.speculative_k)
        return self.spec_k

    def run(self, max_steps: Optional[int] = None,
            metrics_every: Optional[float] = None) -> list[Request]:
        """Drive until every submitted request is finished (delegates to
        :func:`repro.serve.lifecycle.run_to_completion` — the engine
        itself owns no loop)."""
        from repro.serve.lifecycle import run_to_completion

        return run_to_completion(
            self, max_steps=max_steps, metrics_every=metrics_every
        )

    _METRICS_LINE_KEYS = (
        "steps", "decode_tokens", "prefill_tokens", "evictions",
        "pages_in_use", "occupancy", "finished", "acceptance_rate",
        "ttft_s_p50", "ttft_s_p99", "itl_s_p50", "itl_s_p99",
        "e2e_s_p50", "e2e_s_p99", "canary_nll",
    )

    def _emit_metrics_snapshot(self) -> None:
        emit_metrics_line(
            self.summary(), t=self.now(), keys=list(self._METRICS_LINE_KEYS)
        )

    def step(self) -> bool:
        """One engine step; returns whether any token work was done.
        Compatibility wrapper over :meth:`tick`."""
        return self.tick().worked

    def tick(self) -> TickResult:
        """One engine tick — the pure unit of work a driver schedules.

        Returns a :class:`TickResult` carrying every ``(request, token)``
        emitted this tick (in emit order) and every request that reached
        a terminal state since the last tick ended, so a streaming front
        door can fan tokens out to clients without polling request
        objects.  Terminalizations that happen BETWEEN ticks (a server-
        side ``cancel()``) are reported by the next tick.

        Span taxonomy (telemetry, DESIGN.md §11): the whole tick is one
        ``step`` span; its direct children are ``schedule`` (arrival
        admission + planning + page claims/eviction), ``prefill``,
        and ``decode`` XOR ``verify`` (speculative) — adapter dispatch
        spans nest one level deeper inside those phases.
        """
        tr = self.tracer
        with tr.span("step"):
            now = self.now()
            with tr.span("schedule"):
                if self.faults.rules:
                    self.faults.tick = self.metrics.counter("steps").value
                    for rid in self.faults.cancel_rids():
                        self.cancel(rid)
                self.scheduler.admit_arrivals(now)
                if self._deadlines:
                    self._enforce_deadlines(now)
                plan = self.scheduler.plan(self.running, self.pool, now=now)
                self.metrics.inc("prefix_hit_tokens", plan.prefix_hit_tokens)
                decode = self._ensure_decode_pages(plan, now)
                self._check_queue_head(now)
                # drop chunks whose request the page-ensure pass evicted
                # (or a fault/cancel/deadline terminalized)
                chunks = [
                    (r, n) for r, n in plan.prefill
                    if r.state is RequestState.PREFILL
                ]
            worked = False
            if chunks:
                with tr.span(
                    "prefill", lanes=len(chunks),
                    tokens=sum(n for _, n in chunks),
                ):
                    if self.paged_prefill:
                        self._run_prefill_batch(chunks, now)
                    else:
                        for req, n in chunks:
                            self._run_prefill_chunk(req, n, now)
                worked = True
            if decode:
                if self.spec_k:
                    with tr.span("verify", lanes=len(decode)):
                        self._run_decode_spec(decode, now)
                else:
                    with tr.span("decode", lanes=len(decode)):
                        self._run_decode(decode, now)
                worked = True
            self.metrics.inc("steps")
            if self.faults.rules:
                self._reconcile_faults()
        result = TickResult(
            worked=worked, t=now,
            emitted=self._tick_emitted, finished=self._tick_finished,
        )
        # fresh sinks (not .clear()) so the returned lists stay valid
        self._tick_emitted = []
        self._tick_finished = []
        self._last_tick_t = self.now()  # watchdog: tick COMPLETED
        return result

    # ---- internals ------------------------------------------------------

    @staticmethod
    def _select_token(req: Request, logits: np.ndarray) -> int:
        """Pick the next token from last-position logits (host-side).

        Greedy (temperature 0) stays a bare argmax — the ``--check``
        oracle path.  Otherwise: temperature scale, nucleus (top-p)
        filter, then one draw from the request's own generator.
        """
        sp = req.sampling
        if sp.greedy:
            return int(np.argmax(logits))
        z = logits.astype(np.float64) / sp.temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        if sp.top_p < 1.0:
            order = np.argsort(-p)
            csum = np.cumsum(p[order])
            # smallest prefix with mass >= top_p (always keeps the head)
            keep = order[: int(np.searchsorted(csum, sp.top_p)) + 1]
            nucleus = np.zeros_like(p)
            nucleus[keep] = p[keep]
            p = nucleus / nucleus.sum()
        return int(req.rng.choice(p.size, p=p))

    def _evict(self, victim: Request, now: float) -> None:
        cap = self.ecfg.max_evictions
        if cap is not None and victim.n_evictions >= cap:
            # eviction-storm guard: a sequence thrashing in and out of
            # residency FAILS cleanly — freeing its pages for the asking
            # lane — instead of replaying its prefix forever (two near-
            # capacity requests can otherwise evict each other's progress
            # until the run-loop backstop trips)
            self._fail(victim, "eviction_storm", now)
            return
        self.pool.release(victim.slot)
        self.running.remove(victim)
        self.scheduler.requeue(victim)
        self.metrics.inc("evictions")
        self.tracer.event(
            "request_evicted", rid=victim.rid,
            generated=len(victim.out_tokens), n_evictions=victim.n_evictions,
        )

    def _ensure_decode_pages(self, plan: StepPlan, now: float) -> list[Request]:
        """Claim a page for each decode lane's next token, evicting under
        pressure.  Lanes are served best-class-oldest-first and the
        victim is always the worst-class NEWEST running request —
        possibly the asking lane itself — so requests already granted
        pages this step are never clawed back (strict-FCFS preemption
        within a class; low classes yield pages to high ones).  An armed
        ``alloc_fail`` rule makes the targeted lane's claim fail
        terminally (FAILED, "alloc_fail")."""
        active = []
        faults = self.faults if self.faults.rules else None
        lane_key = lambda r: (r.priority or 0, r.arrival, r.rid)
        for r in sorted(plan.decode, key=lane_key):
            if r.state is not RequestState.DECODE:
                continue  # evicted (or terminalized) as a side effect
            if faults is not None and faults.fire("alloc_fail", rid=r.rid):
                self._fail(r, "alloc_fail", now)
                continue
            while not self.pool.extend(r.slot, self.pool.length(r.slot) + 1):
                # victim: lowest class first (largest priority number),
                # then newest — with one class this is the legacy
                # strict-FCFS choice, so token identity is preserved
                victim = max(self.running, key=lane_key)
                self._evict(victim, now)
                if r.state is not RequestState.DECODE:
                    break  # r itself was evicted or stormed out
            else:
                active.append(r)
        return active

    def _enforce_deadlines(self, now: float) -> None:
        """Fail queued/running requests past their wall-clock deadline.
        Checked at tick boundaries: a mid-tick expiry fails at the next
        boundary — the tick in flight is never torn down."""
        sch = self.scheduler
        expired = [
            r for r in (*sch.queue, *self.running)
            if r.deadline_s is not None and now - r.arrival > r.deadline_s
        ]
        for r in expired:
            if r in sch.queue:
                sch.queue.remove(r)
            self.metrics.inc("deadline_missed")
            self._fail(r, "deadline", now)

    def _check_queue_head(self, now: float) -> None:
        """Fail a head-of-queue request that can NEVER be admitted: a
        decoding sequence needs its whole prefix resident at once, so a
        prefix needing more distinct pages than the pool owns is
        infeasible — cached or not (shared trie pages still occupy
        residency).  This is the exact backstop behind submit's
        OPTIMISTIC capacity forecast (``max_new`` is a ceiling; stop
        tokens can end generation early): a request whose generated
        prefix actually outgrows the pool fails cleanly here with reason
        "capacity".  Strict FCFS means an infeasible head would starve
        everything behind it and stall the run loop otherwise."""
        q = self.scheduler.queue
        if not q:
            return
        head = q[0]
        need = max(1, pages_needed(len(head.prefix), self.ecfg.page_size))
        if need > self.pool.n_pages - 1:
            q.popleft()
            self._fail(head, "capacity", now)

    def _reconcile_faults(self) -> None:
        """Turn this step's fault firings (plan.log) into telemetry: one
        dynamic ``fault:<kind>`` counter bump and one trace event each."""
        log = self.faults.log
        for entry in log[self._fault_log_pos:]:
            self.metrics.inc("fault:" + entry["kind"])
            self.tracer.event("fault_injected", **entry)
        self._fault_log_pos = len(log)

    def _screen_lanes(self, lanes: list[Request], logits, now: float) -> None:
        """Quarantine lanes whose logits carry NaN/Inf: ONE fused per-lane
        isfinite reduction on device (only a (B,) bool crosses to the
        host), then the poisoned lane FAILS ("nan_logits") while
        co-batched lanes keep their exact, untouched logit rows — blast
        radius is one request."""
        ok = np.asarray(_lane_finite(logits))
        for b, r in enumerate(lanes):
            if ok[b] or r.state.terminal:
                continue
            self.metrics.inc("quarantined_lanes")
            self._fail(r, "nan_logits", now)

    def _fail_dispatch(self, lanes, exc: FaultInjected, now: float) -> None:
        """A dispatch_error fault fired at the adapter entry: nothing ran,
        no pool length advanced.  Fail ONLY the targeted request; the
        surviving lanes retry next tick (recomputing the identical step)
        and stay token-identical to a fault-free run."""
        for r in lanes:
            if r is not None and r.rid == exc.rid and not r.state.terminal:
                self._fail(r, "dispatch_error", now)
                return

    def _note_emit(self, req: Request, now: float) -> None:
        """Post-emit lifecycle hook: mark the request's true first token
        (a replayed request keeps its original ``t_first``) and record
        the emission for this tick's :class:`TickResult`."""
        self._tick_emitted.append((req, req.out_tokens[-1]))
        if len(req.out_tokens) == 1:
            self.tracer.event(
                "first_token", rid=req.rid, ttft_s=now - req.arrival
            )

    def _terminalize(self, req: Request, state: RequestState, reason: str,
                     now: float) -> None:
        """Shared terminal transition (FINISHED/CANCELLED/FAILED): stamp
        state + finish_reason, release pages (refcount-correct from any
        live state — the prefix trie keeps its own refs), detach from
        ``running``, and count the reason (``finish:<reason>``)."""
        req.state = state
        req.finish_reason = reason
        req.t_finish = now
        if req.slot is not None:
            self.pool.release(req.slot)
            req.slot = None
        if req in self.running:
            self.running.remove(req)
        self.finished.append(req)
        self._tick_finished.append(req)
        self.metrics.inc("finish:" + reason)

    def _finish(self, req: Request, now: float) -> None:
        reason = (
            "stop" if req.out_tokens and req.out_tokens[-1] in req.stop_tokens
            else "length"
        )
        self._terminalize(req, RequestState.FINISHED, reason, now)
        # in-engine lifecycle latencies: one histogram implementation
        # (telemetry.Histogram) observes the same values an external
        # consumer would derive from (arrival, t_first, token_times).
        # FINISHED only — a cancelled/failed request has no honest e2e.
        m = self.metrics
        m.histogram("ttft_s").observe(req.t_first - req.arrival)
        m.histogram("e2e_s").observe(now - req.arrival)
        if req.t_admitted is not None:
            m.histogram("queue_s").observe(req.t_admitted - req.arrival)
        itl = m.histogram("itl_s")
        for a, b in zip(req.token_times, req.token_times[1:]):
            itl.observe(b - a)
        self.tracer.event(
            "request_finished", rid=req.rid, tokens=len(req.out_tokens),
            e2e_s=now - req.arrival, n_evictions=req.n_evictions,
        )
        if req.shadow and self.shadow is not None:
            # FINISHED only: a cancelled/failed stream has no complete
            # emission record to score against the oracle
            self.shadow.observe(req)

    def _cancel(self, req: Request, now: float) -> None:
        self._terminalize(req, RequestState.CANCELLED, "cancelled", now)
        self.metrics.inc("cancelled")
        self.tracer.event(
            "request_cancelled", rid=req.rid, tokens=len(req.out_tokens),
        )

    def _fail(self, req: Request, reason: str, now: float) -> None:
        if req in self.scheduler.queue:  # failed while queued (deadline,
            self.scheduler.queue.remove(req)  # capacity, storm requeue)
        self._terminalize(req, RequestState.FAILED, reason, now)
        self.metrics.inc("failed")
        self.tracer.event(
            "request_failed", rid=req.rid, reason=reason,
            tokens=len(req.out_tokens), n_evictions=req.n_evictions,
        )

    def _after_prefill_chunk(self, req: Request, n: int, last_logits,
                             now: float) -> None:
        """Shared chunk epilogue: advance, register cached prompt pages,
        and emit the first generated token when the prefix completes."""
        req.prefill_pos += n
        self.metrics.inc("prefill_tokens", n)
        if self.pool.prefix_cache:
            covered = min(req.prefill_pos, len(req.prompt))
            self.pool.register_prefix(req.slot, req.prompt[:covered])
        if req.prefill_pos == len(req.prefix):
            last = np.asarray(last_logits)
            if self.ecfg.screen_logits and not np.all(np.isfinite(last)):
                # poisoned boundary logits: quarantine before emitting
                self.metrics.inc("quarantined_lanes")
                self._fail(req, "nan_logits", now)
                return
            req.state = RequestState.DECODE
            req.emit(
                self._boundary_token(req, last), now,
                last if self.ecfg.record_logits or req.shadow else None,
            )
            self._note_emit(req, now)
            if req.done:
                self._finish(req, now)

    def _boundary_token(self, req: Request, logits: np.ndarray) -> int:
        """First-token selection at the prefill boundary.  With on-device
        sampling every draw must stay the same pure function of
        (seed, emission_index) the fused dispatches use — a host numpy
        draw here would fork a replayed (evicted) request's stream from
        its uncontended one — so non-greedy lanes run the identical
        ``sample_tokens`` math on the boundary logits."""
        sp = req.sampling
        if not self.ecfg.device_sample or sp.greedy:
            return self._select_token(req, logits)
        sel = sample_tokens(
            jnp.asarray(logits)[None, None],
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_p], jnp.float32),
            jnp.asarray([sp.seed], jnp.int32),
            jnp.asarray([len(req.out_tokens)], jnp.int32),
        )
        return int(sel[0, 0])

    def _run_prefill_chunk(self, req: Request, n: int, now: float) -> None:
        prefix = req.prefix
        start = req.prefill_pos
        C = self.ecfg.prefill_chunk
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n] = prefix[start : start + n]
        positions = (np.arange(C, dtype=np.int32) + start)[None]
        ctx_k, ctx_v = self.pool.gather([req.slot])
        if self.faults.rules:
            self.faults.lane_rids = (req.rid,)
            # only the boundary chunk's last logit is consumed; earlier
            # chunks' logits are discarded, so NaN there is unobservable
            self.faults.poison_rids = (
                (req.rid,) if start + n == len(prefix) else ())
        try:
            logits, k_new, v_new = self.adapter(
                jnp.asarray(chunk),
                jnp.asarray(positions),
                ctx_k,
                ctx_v,
                jnp.asarray([start], jnp.int32),
            )
        except FaultInjected as e:
            self._fail_dispatch([req], e, now)
            return  # prefill_pos unchanged: a surviving req replans as-is
        self.pool.write_span(req.slot, start, n, k_new[:, 0], v_new[:, 0])
        self._after_prefill_chunk(req, n, logits[0, n - 1], now)

    def _run_prefill_batch(self, chunks, now: float) -> None:
        """One fused dispatch over the step's whole co-batchable prefill
        group: lanes padded to a power of two (compile reuse across group
        sizes), chunk width fixed at ``prefill_chunk``, block tables
        bucketed to the longest prior context in the batch.  Padded lanes
        and padded chunk tails scatter to the scratch page."""
        C = self.ecfg.prefill_chunk
        # lane bucketing shares page_bucket so the pow2 rounding has one
        # source; group size is bounded by the token budget, never by it
        B = page_bucket(len(chunks), 1 << 16)
        tokens = np.zeros((B, C), np.int32)
        positions = np.tile(np.arange(C, dtype=np.int32), (B, 1))
        ctx_len = np.zeros((B,), np.int32)
        slots: list[Optional[int]] = [None] * B
        starts = [0] * B
        ns = [0] * B
        for b, (r, n) in enumerate(chunks):
            start = r.prefill_pos
            tokens[b, :n] = r.prefix[start : start + n]
            positions[b] += start
            ctx_len[b] = start
            slots[b], starts[b], ns[b] = r.slot, start, n
        pages, offs = self.pool.span_addresses(slots, starts, ns, C)
        bt = self.pool.block_table(slots)
        bt = bt[:, : self._active_pages(int(ctx_len.max(initial=1)))]
        if self.faults.rules:
            self.faults.lane_rids = tuple(r.rid for r, _ in chunks)
            self.faults.poison_rids = tuple(
                r.rid for r, n in chunks
                if r.prefill_pos + n == len(r.prefix))
        try:
            logits = self.adapter.prefill_paged(
                tokens, positions, bt, ctx_len, pages, offs, self.pool
            )
        except FaultInjected as e:
            # lengths never advanced (no note_span_written): surviving
            # chunks replan next tick and recompute the identical KV
            self._fail_dispatch([r for r, _ in chunks], e, now)
            return
        self.pool.note_span_written(slots, starts, ns)
        self.metrics.inc("prefill_batches")
        self.metrics.counter("prefill_batch_size").peak(len(chunks))
        for b, (r, n) in enumerate(chunks):
            self._after_prefill_chunk(r, n, logits[b, n - 1], now)

    def _active_pages(self, max_ctx: int) -> int:
        """Pages to attend this step: covers the longest live context,
        rounded up to a power of two so the paged dispatch compiles a
        handful of bucket shapes instead of one per context length."""
        return page_bucket(
            pages_needed(max_ctx, self.ecfg.page_size),
            self.pool.max_pages_per_seq,
        )

    def _sampling_arrays(self, reqs: list[Request], B: int):
        """(temps, top_ps, seeds, draws) per lane for the fused on-device
        draw; ``draws`` is each lane's emission count so far, so the draw
        key is a pure function of (request seed, emission index)."""
        temps = np.zeros(B, np.float32)
        top_ps = np.ones(B, np.float32)
        seeds = np.zeros(B, np.int32)
        draws = np.zeros(B, np.int32)
        for b, r in enumerate(reqs):
            temps[b] = r.sampling.temperature
            top_ps[b] = r.sampling.top_p
            seeds[b] = r.sampling.seed
            draws[b] = len(r.out_tokens)
        return temps, top_ps, seeds, draws

    def _run_decode(self, decode: list[Request], now: float) -> None:
        B = self.ecfg.n_slots
        assert len(decode) <= B
        slots: list[Optional[int]] = [None] * B
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B, 1), np.int32)
        ctx_len = np.zeros((B,), np.int32)
        for b, r in enumerate(decode):
            slots[b] = r.slot
            tokens[b, 0] = r.out_tokens[-1]
            ctx_len[b] = self.pool.length(r.slot)
            positions[b, 0] = ctx_len[b]
        pos_list = [int(p) for p in positions[:, 0]]
        sel_np = None
        if self.faults.rules:
            self.faults.lane_rids = tuple(r.rid for r in decode)
            self.faults.poison_rids = self.faults.lane_rids
        try:
            if self.paged:
                bt = self.pool.block_table(slots)
                bt = bt[:, : self._active_pages(int(ctx_len.max(initial=1)))]
                pages, offs = self.pool.addresses(slots, pos_list)
                if self.ecfg.device_sample:
                    sel, logits = self.adapter.decode_paged_sample(
                        tokens, positions, bt, ctx_len, pages, offs,
                        self._sampling_arrays(decode, B), self.pool,
                    )
                    sel_np = np.asarray(sel[:, 0])
                else:
                    logits = self.adapter.decode_paged(
                        tokens, positions, bt, ctx_len, pages, offs, self.pool
                    )
                self.pool.note_written(slots, pos_list)
            else:
                ctx_k, ctx_v = self.pool.gather(slots)
                logits, k_new, v_new = self.adapter(
                    jnp.asarray(tokens),
                    jnp.asarray(positions),
                    ctx_k,
                    ctx_v,
                    jnp.asarray(ctx_len),
                )
                self.pool.write(slots, pos_list, k_new[:, :, 0], v_new[:, :, 0])
        except FaultInjected as e:
            # nothing dispatched, lengths untouched: fail the target only;
            # surviving lanes redo the identical step next tick
            self._fail_dispatch(decode, e, now)
            return
        if self.ecfg.screen_logits:
            self._screen_lanes(decode, logits, now)
        with self.tracer.span("emit", lanes=len(decode)):
            logits_np = None
            if (sel_np is None or self.ecfg.record_logits
                    or any(r.shadow for r in decode)):
                logits_np = np.asarray(logits[:, 0])
            for b, r in enumerate(decode):
                if r.state.terminal:
                    continue  # quarantined by the screen this tick
                tok = (
                    int(sel_np[b]) if sel_np is not None
                    else self._select_token(r, logits_np[b])
                )
                r.emit(
                    tok, now,
                    logits_np[b] if self.ecfg.record_logits or r.shadow
                    else None,
                )
                self._note_emit(r, now)
                self.metrics.inc("decode_tokens")
                if r.done:
                    self._finish(r, now)

    def _run_decode_spec(self, decode: list[Request], now: float) -> None:
        """One speculative tick: draft up to K tokens per lane, verify
        every lane's ``[last_emitted, drafts...]`` chunk in ONE fused
        padded (B, K+1) dispatch, emit each lane's accepted prefix plus
        the bonus token, and roll back the rejected tail's K/V."""
        B, K = self.ecfg.n_slots, self.spec_k
        W = K + 1
        assert len(decode) <= B
        slots: list[Optional[int]] = [None] * B
        tokens = np.zeros((B, W), np.int32)
        positions = np.tile(np.arange(W, dtype=np.int32), (B, 1))
        ctx_len = np.zeros((B,), np.int32)
        drafts = np.zeros((B, K), np.int32)
        n_drafts = np.zeros((B,), np.int32)
        starts = [0] * B
        widths = [0] * B
        with self.tracer.span("draft", lanes=len(decode)):
            for b, r in enumerate(decode):
                slots[b] = r.slot
                length = self.pool.length(r.slot)
                # opportunistic draft: capped by the request's remaining
                # token budget, the slot's page capacity, and page
                # availability — drafting never evicts anyone (the
                # guaranteed +1 page was already claimed by
                # _ensure_decode_pages)
                room = min(
                    K,
                    r.max_new - len(r.out_tokens) - 1,
                    self.pool.seq_capacity_tokens() - (length + 1),
                )
                prop = (
                    self.drafter.propose(r.prefix, room)
                    if room > 0 else np.zeros(0, np.int32)
                )
                n = len(prop)
                while n > 0 and not self.pool.extend(r.slot, length + 1 + n):
                    n -= 1
                tokens[b, 0] = r.out_tokens[-1]
                tokens[b, 1 : 1 + n] = prop[:n]
                drafts[b, :n] = prop[:n]
                n_drafts[b] = n
                positions[b] += length
                ctx_len[b] = length
                starts[b], widths[b] = length, 1 + n
                self.metrics.inc("draft_tokens", n)
        pages, offs = self.pool.span_addresses(slots, starts, widths, W)
        bt = self.pool.block_table(slots)
        bt = bt[:, : self._active_pages(int(ctx_len.max(initial=1)))]
        sampling = (
            self._sampling_arrays(decode, B) if self.ecfg.device_sample
            # host-sample debugging path: zero temps make the device
            # selection pure greedy; the host re-selects from the logits
            else (np.zeros(B, np.float32), np.ones(B, np.float32),
                  np.zeros(B, np.int32), np.zeros(B, np.int32))
        )
        if self.faults.rules:
            self.faults.lane_rids = tuple(r.rid for r in decode)
            self.faults.poison_rids = self.faults.lane_rids
        try:
            sel, n_acc, logits = self.adapter.verify_paged(
                tokens, positions, bt, ctx_len, pages, offs, drafts, n_drafts,
                sampling, self.pool,
            )
        except FaultInjected as e:
            self._fail_dispatch(decode, e, now)
            # unmap the opportunistic draft page claims: lengths never
            # advanced, so surviving lanes re-draft from ctx_len next tick
            for b, r in enumerate(decode):
                if not r.state.terminal:
                    self.pool.truncate(r.slot, starts[b])
            return
        self.pool.note_span_written(slots, starts, widths)
        if self.ecfg.screen_logits:
            self._screen_lanes(decode, logits, now)
        self.metrics.inc("spec_ticks")
        self.metrics.inc("spec_lanes", len(decode))
        with self.tracer.span("emit", lanes=len(decode)):
            logits_np = None
            if (not self.ecfg.device_sample or self.ecfg.record_logits
                    or any(r.shadow for r in decode)):
                logits_np = np.asarray(logits)
            sel_np, n_acc_np = np.asarray(sel), np.asarray(n_acc)
            extra = 0
            for b, r in enumerate(decode):
                if r.state.terminal:
                    continue  # quarantined by the screen; slot already freed
                length = int(ctx_len[b])
                keep = self.ecfg.record_logits or r.shadow
                emitted = 0
                if self.ecfg.device_sample:
                    for i in range(int(n_acc_np[b]) + 1):
                        r.emit(
                            int(sel_np[b, i]), now,
                            logits_np[b, i] if keep else None,
                        )
                        self._note_emit(r, now)
                        emitted += 1
                        if r.done:
                            break
                else:
                    i = 0
                    while True:
                        tok = self._select_token(r, logits_np[b, i])
                        r.emit(
                            tok, now,
                            logits_np[b, i] if keep else None,
                        )
                        self._note_emit(r, now)
                        emitted += 1
                        if r.done or i >= n_drafts[b] or tok != drafts[b, i]:
                            break
                        i += 1
                self.metrics.inc("decode_tokens", emitted)
                self.metrics.inc("accepted_tokens", emitted - 1)
                self.metrics.inc("rolled_back_tokens", widths[b] - emitted)
                extra += emitted - 1
                if r.done:
                    self._finish(r, now)  # releases the slot — no rollback
                else:
                    # un-write the rejected tail: the last emitted token's
                    # KV is computed NEXT tick (it is the new
                    # last_emitted), so the valid length is ctx + emitted
                    self.pool.truncate(r.slot, length + emitted)
        # accepted extras beyond the planned one-per-lane charge the NEXT
        # step's budget; rejected drafts were never charged
        self.scheduler.charge_accepted(extra)

    # ---- reporting ------------------------------------------------------

    def summary(self) -> dict:
        """One metrics snapshot: every counter, every live pool gauge,
        the in-engine latency histograms (``ttft_s_p50`` / ``itl_s_p99``
        / ``queue_s_*`` / ``e2e_s_*`` — None until a request finished),
        and the derived speculative-health ratios."""
        s = self.metrics.snapshot()
        # speculative decode health: how often the drafter was right,
        # and how many tokens a verify tick emitted on average
        s["acceptance_rate"] = (
            s["accepted_tokens"] / max(1, s["draft_tokens"])
        )
        s["accepted_per_tick"] = (
            s["accepted_tokens"] / max(1, s["spec_ticks"])
        )
        # mean tokens ONE lane emits per verify it takes part in
        # (1.0 = no speculative benefit, K+1 = every draft accepted)
        s["tokens_per_lane_tick"] = (
            s["decode_tokens"] / max(1, s["spec_lanes"])
        ) if s["spec_ticks"] else 1.0
        return s
