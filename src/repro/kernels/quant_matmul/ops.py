"""Jit'd public wrapper around the quant_matmul Pallas kernel.

Handles: arbitrary leading batch dims, tile padding, the affine dequant
correction ``z = (2s/maxq)·acc − s·Σ_k x``, dtype restoration, and the
CPU fallback (interpret mode for tests / pure-jnp for speed).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.kernel import quant_matmul_kernel
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("bits", "n", "maxq", "interpret", "force_kernel")
)
def quant_matmul(
    x: jax.Array,
    packed: jax.Array,
    bits: int,
    n: int,
    s: jax.Array,
    maxq: int,
    *,
    interpret: bool = False,
    force_kernel: bool = False,
) -> jax.Array:
    """z = x @ deq(Wq)^T; x: (..., n); packed: (rows, m) int32 → (..., m).

    On non-TPU backends (this CPU container) dispatches to the jnp oracle
    unless ``interpret``/``force_kernel`` ask for the Pallas path.
    """
    if not (on_tpu() or interpret or force_kernel):
        return quant_matmul_ref(x, packed, bits, n, s, maxq)

    lead = x.shape[:-1]
    B = 1
    for d in lead:
        B *= d
    x2 = x.reshape(B, n)
    vals = 32 // bits
    rows, m = packed.shape

    bB = min(128, _ceil_to(B, 8))
    bM = min(128, _ceil_to(m, 128))
    # K tile must be a multiple of both vals-per-word and the 128 lane
    # width (3-bit → lcm(10,128)=640).
    unit = vals * 128 // math.gcd(vals, 128)
    bK = unit * max(1, 512 // unit)
    bK = min(bK, _ceil_to(n, unit))
    Bp, Mp, Kp = _ceil_to(B, bB), _ceil_to(m, bM), _ceil_to(n, bK)
    xp = jnp.pad(x2, ((0, Bp - B), (0, Kp - n)))
    pp = jnp.pad(packed, ((0, Kp // vals - rows), (0, Mp - m)))
    acc = quant_matmul_kernel(
        xp, pp, bits=bits, bB=bB, bM=bM, bK=bK, interpret=interpret
    )[:B, :m]
    hsum = jnp.sum(x2.astype(jnp.float32), axis=-1, keepdims=True)
    sf = jnp.float32(s)
    z = acc * (2.0 * sf / maxq) - sf * hsum
    return z.astype(x.dtype).reshape(*lead, m)
