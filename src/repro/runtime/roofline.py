"""Three-term roofline model from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16; 819 GB/s HBM;
~50 GB/s/link ICI (§Roofline contract).  HLO_FLOPs / HLO_bytes come from
``compiled.cost_analysis()``; collective bytes from
:mod:`repro.runtime.hlo_analysis`.

MODEL_FLOPS (useful work) is 6·N·D for dense training and 2·N·D for a
forward-only step (N = params, active params for MoE; D = tokens processed
by the step), giving the MODEL_FLOPS / HLO_FLOPs "usefulness" ratio that
catches remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.configs.base import ArchConfig, ShapeSpec

__all__ = ["HW", "RooflineTerms", "roofline_terms", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    link_bw: float = 50e9  # bytes/s per ICI link (per chip, one direction)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def hlo_flops_global(self) -> float:
        """hlo_flops is per-device (partitioned module); SPMD is symmetric."""
        return self.hlo_flops * self.chips

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO_FLOPs (remat & redundancy waste)."""
        g = self.hlo_flops_global
        return self.model_flops / g if g else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-bound step time."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops / (self.chips * HW().peak_flops * t)

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "hlo_flops_global": self.hlo_flops_global,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu,
            "chips": self.chips,
        }


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Useful FLOPs per step: 6·N_active·tokens (train), 2·N_active·tokens
    (forward-only prefill/decode)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def roofline_terms(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    chips: int,
    cfg: Optional[ArchConfig] = None,
    shape: Optional[ShapeSpec] = None,
    hw: HW = HW(),
    flops_are_global: bool = True,
) -> RooflineTerms:
    """cost_analysis reports per-program numbers; under SPMD the program is
    per-device, so set ``flops_are_global=False`` when the counts came from
    a partitioned executable."""
    div = chips if flops_are_global else 1
    mf = model_flops(cfg, shape) if (cfg and shape) else 0.0
    return RooflineTerms(
        compute_s=hlo_flops / div / hw.peak_flops,
        memory_s=hlo_bytes / div / hw.hbm_bw,
        collective_s=collective_bytes / div / hw.link_bw
        if flops_are_global
        else collective_bytes / hw.link_bw,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        model_flops=mf,
        chips=chips,
    )
