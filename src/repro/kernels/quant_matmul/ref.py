"""Pure-jnp oracle for the packed quantized matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.incoherence import from_grid


def quant_matmul_ref(
    x: jax.Array,
    packed: jax.Array,
    bits: int,
    n: int,
    s: jax.Array,
    maxq: int,
) -> jax.Array:
    """z = x @ deq(Wq)^T via explicit unpack + dense matmul (fp32)."""
    Wq = packing.unpack(packed, bits, n).astype(jnp.float32)  # (m, n)
    Wd = from_grid(Wq, jnp.float32(s), maxq)
    return (x.astype(jnp.float32) @ Wd.T).astype(x.dtype)


def grid_matmul_ref(x: jax.Array, packed: jax.Array, bits: int, n: int) -> jax.Array:
    """Integer-grid matmul only (what the kernel itself computes)."""
    Wq = packing.unpack(packed, bits, n).astype(jnp.float32)
    return x.astype(jnp.float32) @ Wq.T
