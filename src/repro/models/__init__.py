"""Model zoo: composable pure-JAX definitions for the assigned families."""
from repro.models.lm import Model, build_model

__all__ = ["Model", "build_model"]
