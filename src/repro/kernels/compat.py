"""Pallas API compatibility across the jax versions we run under.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` after
0.4.x; the pinned toolchain here still ships the old name.  Import
:data:`CompilerParams` from this module instead of from ``pltpu`` so the
kernels compile under either.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(
    _pltpu, "CompilerParams", getattr(_pltpu, "TPUCompilerParams", None)
)

if CompilerParams is None:  # fail loudly at the kernel, not with a
    def CompilerParams(*_a, **_k):  # NoneType-is-not-callable TypeError
        raise AttributeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams in this jax version"
        )

__all__ = ["CompilerParams"]
