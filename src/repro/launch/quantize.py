"""QuIP post-training quantization driver (paper Sec. 6 "Setup").

Quantization proceeds one transformer block at a time, exactly as the
paper does: (1) run calibration activations through the network quantized
SO FAR to the current block, (2) accumulate per-layer proxy Hessians
H = E[x x^T] at each linear's true input, (3) QuIP-quantize every linear
in the block, (4) the quantized block produces the inputs for the next.

This driver operates on smoke-scale dense models end-to-end on CPU (the
per-layer math is size-agnostic; at cluster scale the same schedule runs
layer-parallel over the model axis — DESIGN.md §3).

Hessian accumulation is **streaming**: calibration segments pass through
each block ``--calib-chunk`` segments at a time and feed
``HessianAccumulator.update`` per segment, so per-block activation memory
is O(chunk · seg_len · d_ff) instead of O(batch · seg_len · d_ff).  The
accumulator's fixed per-segment fold makes H bit-identical for every
chunk size, including the one-shot path (``--calib-chunk 0``), as long
as the backend's forward pass is batch-size-invariant — true for the CPU
calibration path this driver runs on (tests/test_drivers.py pins it);
on other backends the chunkings agree to reassociation error.

    PYTHONPATH=src python -m repro.launch.quantize --arch qwen3-14b --smoke \
        --bits 2 --method ldlq
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.quantizer import QuipConfig, QuantizedLinear, quantize_layer
from repro.data import make_calibration
from repro.models import build_model
from repro.models import layers as L

__all__ = ["quantize_dense_model", "QuantizedModel", "main"]

# the per-block linears we quantize for the dense family, with the params
# path and the activation tap that feeds each one
_DENSE_LINEARS = ("attn.wq", "attn.wk", "attn.wv", "attn.wo", "mlp.wi", "mlp.wg", "mlp.wo")


@dataclasses.dataclass
class QuantizedModel:
    """Dense decoder with every block linear replaced by a QuantizedLinear."""

    cfg: object
    embed: dict
    final_norm: dict
    blocks: list  # per layer: dict name -> QuantizedLinear, plus norms
    stats: list

    def forward_hidden(self, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = L.embed(self.embed, tokens)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        for blk in self.blocks:
            x = _quantized_block_forward(blk, x, cfg, positions)
        return L.norm_apply(self.final_norm, x, cfg)

    def logits(self, tokens: jax.Array) -> jax.Array:
        return L.lm_logits(self.embed, self.forward_hidden(tokens))

    def cached_decoder(self):
        """KV-cached prefill/decode path (repro.serve) through the packed
        D^-1 -> V -> quant_matmul -> U^T pipeline — the serving-time
        replacement for per-token ``logits`` recompute."""
        from repro.serve.adapter import CachedDecoder

        return CachedDecoder.from_quantized(self)


def _attn_forward_with_linears(blk, h, cfg, positions):
    """attention_full but routed through QuantizedLinear projections."""
    B, S, _ = h.shape
    q = blk["attn.wq"](h).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = blk["attn.wk"](h).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = blk["attn.wv"](h).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, blk["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, blk["k_norm"], cfg.norm_eps)
    from repro.models.layers import rope, _gqa_scores, _gqa_out

    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    s = _gqa_scores(q, k, cfg)
    m = positions[:, None] >= positions[None, :]
    s = jnp.where(m[None, None, None], s, -1e30)
    o = _gqa_out(jax.nn.softmax(s, axis=-1), v, cfg)
    o = o.astype(h.dtype).reshape(B, S, cfg.q_dim)
    return blk["attn.wo"](o)


def _quantized_block_forward(blk, x, cfg, positions):
    h = L.norm_apply(blk["ln1"], x, cfg)
    x = x + _attn_forward_with_linears(blk, h, cfg, positions)
    h = L.norm_apply(blk["ln2"], x, cfg)
    up = blk["mlp.wi"](h)
    if cfg.mlp == "swiglu":
        up = jax.nn.silu(up) * blk["mlp.wg"](h)
    else:
        up = jax.nn.gelu(up)
    return x + blk["mlp.wo"](up)


def _block_taps(lp, x, cfg, positions):
    """Run one fp block, returning the activation at each linear's input."""
    taps = {}
    h = L.norm_apply(lp["ln1"], x, cfg)
    taps["attn.wq"] = taps["attn.wk"] = taps["attn.wv"] = h
    a, (k, v) = L.attention_full(
        lp["attn"], h, cfg, positions=positions, causal=True, return_kv=True
    )
    # reconstruct the wo input (pre-projection attention output)
    # cheaper: recompute inside attention; here we tap via a second pass
    q = h @ lp["attn"]["wq"]
    B, S, _ = h.shape
    qh = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        qh = L.rms_norm(qh, lp["attn"]["q_norm"], cfg.norm_eps)
    from repro.models.layers import rope, _gqa_scores, _gqa_out

    qh = rope(qh, positions, cfg.rope_theta)
    s = _gqa_scores(qh, k, cfg)
    m = positions[:, None] >= positions[None, :]
    s = jnp.where(m[None, None, None], s, -1e30)
    o = _gqa_out(jax.nn.softmax(s, -1), v, cfg).astype(h.dtype)
    taps["attn.wo"] = o.reshape(B, S, cfg.q_dim)
    x = x + a
    h2 = L.norm_apply(lp["ln2"], x, cfg)
    taps["mlp.wi"] = taps["mlp.wg"] = h2
    up = h2 @ lp["mlp"]["wi"]
    if cfg.mlp == "swiglu":
        up = jax.nn.silu(up) * (h2 @ lp["mlp"]["wg"])
    else:
        up = jax.nn.gelu(up)
    taps["mlp.wo"] = up
    x = x + up @ lp["mlp"]["wo"]
    return x, taps


def _get_path(tree, path):
    for p in path.split("."):
        tree = tree[p]
    return tree


def _block_linears(cfg) -> tuple[str, ...]:
    return tuple(
        n for n in _DENSE_LINEARS if n != "mlp.wg" or cfg.mlp == "swiglu"
    )


def block_hessians(
    lp, x: jax.Array, cfg, positions: jax.Array, *, chunk: int = 0
) -> dict[str, jax.Array]:
    """Per-linear proxy Hessians for one block, streaming over segments.

    ``x`` (B, S, d) is the calibration activation entering the block;
    activations at each linear's input are materialized only ``chunk``
    segments at a time (``chunk <= 0``: the whole batch at once — the
    one-shot path).  Each segment is folded through
    ``HessianAccumulator.update`` individually, so the result is
    bit-identical for every chunk size.
    """
    from repro.core.hessian import HessianAccumulator

    B = x.shape[0]
    chunk = B if chunk <= 0 else min(chunk, B)
    accs: dict[str, HessianAccumulator] = {}
    for i0 in range(0, B, chunk):
        _, taps = _block_taps(lp, x[i0 : i0 + chunk], cfg, positions)
        for name in _block_linears(cfg):
            X = taps[name].astype(jnp.float32)
            acc = accs.get(name) or HessianAccumulator.create(X.shape[-1])
            accs[name] = acc.update_segments(X)
    return {name: acc.finalize() for name, acc in accs.items()}


def quantize_dense_model(
    params,
    cfg,
    qcfg: QuipConfig,
    calib_tokens: jax.Array,
    *,
    seed: int = 0,
    verbose: bool = True,
    calib_chunk: int = 8,
) -> QuantizedModel:
    """Block-by-block QuIP over a dense decoder (params from Model.init).

    ``calib_chunk``: calibration segments materialized at once per block
    (streaming Hessians; <= 0 keeps the whole batch resident — the
    one-shot path, bit-identical to any chunking).
    """
    from repro.models.transformer import unstack_layers

    n_layers = cfg.n_layers
    layer_params = unstack_layers(params)
    B = calib_tokens.shape[0]
    chunk = B if calib_chunk <= 0 else min(calib_chunk, B)
    positions = jnp.arange(calib_tokens.shape[1], dtype=jnp.int32)
    x = L.embed(params["embed"], calib_tokens)

    blocks = []
    all_stats = []
    for i, lp in enumerate(layer_params):
        t0 = time.time()
        # Hessians from the quantized-prefix activations (paper: H from the
        # quantized transformer up to this point), streamed chunk by chunk
        hessians = block_hessians(lp, x, cfg, positions, chunk=chunk)
        blk = {
            "ln1": lp["ln1"],
            "ln2": lp["ln2"],
        }
        if cfg.qk_norm:
            blk["q_norm"] = lp["attn"]["q_norm"]
            blk["k_norm"] = lp["attn"]["k_norm"]
        stats_blk = {}
        for name in _block_linears(cfg):
            W = _get_path(lp, name).T  # stored (in, out) -> quantize (out, in)
            # per-layer seed from the STABLE linear index — hash(name) varies
            # with PYTHONHASHSEED across processes, which would make saved
            # artifacts irreproducible (their transforms regenerate by seed)
            layer, st = quantize_layer(
                W, hessians[name], qcfg,
                seed=seed * 1000 + i * 10 + _DENSE_LINEARS.index(name),
            )
            blk[name] = layer
            stats_blk[name] = st
        blocks.append(blk)
        all_stats.append(stats_blk)
        # advance calibration activations through the QUANTIZED block, in
        # the same segment chunks (never the full batch's d_ff activations)
        x = jnp.concatenate([
            _quantized_block_forward(blk, x[i0 : i0 + chunk], cfg, positions)
            for i0 in range(0, B, chunk)
        ])
        if verbose:
            mean_proxy = float(
                np.mean([s["proxy_loss"] for s in stats_blk.values()])
            )
            print(
                f"[quantize] block {i}/{n_layers} proxy={mean_proxy:.4g} "
                f"({time.time()-t0:.1f}s)"
            )
    return QuantizedModel(
        cfg=cfg,
        embed=params["embed"],
        final_norm=params["final_norm"],
        blocks=blocks,
        stats=all_stats,
    )


def perplexity(logits_fn, tokens: jax.Array, batch: int = 8) -> float:
    """Next-token perplexity of a logits(tokens) function."""
    tot, cnt = 0.0, 0
    for i in range(0, tokens.shape[0], batch):
        tb = tokens[i : i + batch]
        logits = logits_fn(tb[:, :-1]).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, tb[:, 1:, None], -1)[..., 0]
        tot += float(jnp.sum(nll))
        cnt += nll.size
    return float(np.exp(tot / cnt))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--method", default="ldlq")
    ap.add_argument("--no-incoherence", action="store_true")
    ap.add_argument("--transform", default="kronecker",
                    choices=["kronecker", "hadamard", "none"])
    ap.add_argument("--calib-segments", type=int, default=16)
    ap.add_argument("--calib-len", type=int, default=128)
    ap.add_argument("--calib-chunk", type=int, default=8,
                    help="calibration segments materialized at once per "
                         "block (streaming Hessians; 0 = whole batch, the "
                         "one-shot path — bit-identical either way)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--out-dir", default=None,
                    help="persist the quantized model as a serving artifact "
                         "(packed ints + scales + transform seeds); serve "
                         "with launch/serve.py --load-quantized <dir>")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family not in ("dense",):
        raise SystemExit(
            "quantize driver drives the dense family end-to-end; "
            "per-layer quantization for other families goes through "
            "repro.core.quantize_layer directly"
        )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    calib = make_calibration(
        cfg.vocab, n_segments=args.calib_segments, seg_len=args.calib_len,
        seed=args.seed + 7,
    )
    qcfg = QuipConfig(
        bits=args.bits,
        method=args.method,
        incoherence=not args.no_incoherence,
        transform=args.transform,
        use_kernel=False,
    )
    qm = quantize_dense_model(params, cfg, qcfg, calib.tokens, seed=args.seed,
                              calib_chunk=args.calib_chunk)

    if args.out_dir:
        from repro.serve.artifacts import save_quantized
        from repro.serve.quality import build_quality_section

        # the quality section ships INSIDE the manifest, next to the
        # shard digests: the audit describes exactly the weights it
        # travels with (render with launch/quality_report.py)
        quality = build_quality_section(qm.stats)
        path = save_quantized(
            args.out_dir, qm, qcfg,
            extra_meta={"stats": qm.stats, "smoke": args.smoke,
                        "seed": args.seed, "quality": quality},
        )
        agg = quality["aggregate"]
        print(f"[quantize] artifact saved to {path}")
        if agg:
            print(
                f"[quantize] quality: layers={agg['n_layers']} "
                f"total_proxy={agg['total_proxy_loss']:.4g} "
                f"max_proxy_rel={agg['max_proxy_rel']:.4g} "
                f"max_mu_w_post={agg['max_mu_w_post']:.3g} "
                f"max_h_cond={agg['max_h_cond']:.3g}"
            )

    eval_tokens = make_calibration(
        cfg.vocab, n_segments=8, seg_len=args.calib_len, seed=args.seed + 99
    ).tokens
    ppl_fp = perplexity(
        lambda t: model.logits(params, model.forward(params, {"tokens": t})[0]),
        eval_tokens,
    )
    ppl_q = perplexity(qm.logits, eval_tokens)
    rec = {
        "arch": cfg.name, "bits": args.bits, "method": qcfg.label(),
        "ppl_fp16": ppl_fp, "ppl_quant": ppl_q,
        "mean_proxy": float(np.mean([
            s["proxy_loss"] for blk in qm.stats for s in blk.values()
        ])),
    }
    print(json.dumps(rec, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
