"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

Features exercised end-to-end (and by tests/test_train_driver.py):
  * deterministic (seed, step) data stream -> exact resume semantics;
  * CheckpointManager: atomic save-every-K, keep-k GC, auto-resume;
  * failure trap: any step exception restores the latest checkpoint and
    continues (``--fail-at`` injects a fault for testing);
  * elastic re-mesh on resume (runtime/elastic.py) — restore works onto
    whatever devices remain because checkpoints are logical;
  * optional int8 error-feedback gradient compression over the data axis
    (--compress-grads; shard_map psum on int8 payloads).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import token_batches
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.runtime.elastic import remesh
from repro.runtime.sharding import mesh_context, param_shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--keep", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (fault-tolerance test)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    opt = adamw(cosine_schedule(args.lr, args.steps, max(args.steps // 20, 1)))
    n_micro = max(1, args.global_batch // max(cfg.microbatch, 1))
    train_step = make_train_step(model, opt, n_micro=n_micro)

    ctx = remesh()  # best mesh for whatever devices exist (1 on this box)
    mgr = CheckpointManager(args.ckpt_dir, keep=args.keep,
                            save_every=args.save_every)

    with mesh_context(ctx.mesh, ctx.rules):
        params = model.init(jax.random.PRNGKey(args.seed))
        opt_state = opt.init(params)
        psh = param_shardings(ctx, jax.eval_shape(lambda: params), model.param_axes())
        start = 0
        state_like = {"params": params, "opt": opt_state}
        try:
            restored, step, meta = mgr.restore_latest(state_like)
            params, opt_state = restored["params"], restored["opt"]
            start = step
            print(f"[train] resumed from step {step}")
        except FileNotFoundError:
            print("[train] fresh start")

        jitted = jax.jit(train_step, donate_argnums=(0, 1))
        stream = token_batches(
            cfg.vocab, args.global_batch, args.seq_len,
            seed=args.seed, start_step=start,
        )

        step = start
        injected = False
        consecutive_failures = 0
        while step < args.steps:
            batch = next(stream)
            try:
                if step == args.fail_at and not injected:
                    injected = True
                    raise RuntimeError("injected node failure")
                t0 = time.time()
                params, opt_state, metrics = jitted(
                    params, opt_state, batch, jnp.int32(step)
                )
                if step % args.log_every == 0:
                    print(
                        f"[train] step {step} loss={float(metrics['loss']):.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"dt={time.time()-t0:.2f}s"
                    )
                step += 1
                consecutive_failures = 0
                mgr.maybe_save(step, {"params": params, "opt": opt_state})
            except Exception as e:  # failure trap: restore + continue
                consecutive_failures += 1
                if consecutive_failures > 3:
                    raise  # persistent failure: surface it, don't spin
                print(f"[train] step {step} FAILED ({e}); restoring…", flush=True)
                try:
                    restored, ck_step, _ = mgr.restore_latest(state_like)
                    params, opt_state = restored["params"], restored["opt"]
                    step = ck_step
                    stream = token_batches(
                        cfg.vocab, args.global_batch, args.seq_len,
                        seed=args.seed, start_step=step,
                    )
                    print(f"[train] restored to step {ck_step}, continuing")
                except FileNotFoundError:
                    print("[train] no checkpoint yet; restarting from scratch")
                    params = model.init(jax.random.PRNGKey(args.seed))
                    opt_state = opt.init(params)
                    step = 0
                    stream = token_batches(
                        cfg.vocab, args.global_batch, args.seq_len,
                        seed=args.seed, start_step=0,
                    )
        # final checkpoint
        from repro.checkpoint import save_checkpoint

        save_checkpoint(args.ckpt_dir, step, {"params": params, "opt": opt_state})
        print(f"[train] done at step {step}")
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
