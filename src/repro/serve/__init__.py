"""Serving subsystem: continuous batching over paged KV caches.

Pieces (each usable on its own):

  * :mod:`repro.serve.kv_cache`  — slot-based paged KV pool (admit/extend/
    evict page accounting + gather/scatter device ops) with an optional
    prompt-prefix cache (hash trie over full pages, refcounted
    copy-on-write sharing);
  * :mod:`repro.serve.adapter`   — dual-path cached forward over both the
    fp ``Model`` params and a QuIP ``QuantizedModel`` (packed
    ``D⁻¹ → V → quant_matmul → Uᵀ`` path, no per-token recompute):
    gather-dense reference oracle + fused paged decode AND fused batched
    cross-request prefill that read the page pool in place
    (``kernels/paged_attention``);
  * :mod:`repro.serve.scheduler` — request lifecycle + token-budget FCFS
    scheduling with chunked prefill (one co-batchable group per tick);
  * :mod:`repro.serve.engine`    — per-step batch assembly: new requests
    join the decode batch while others are mid-generation;
  * :mod:`repro.serve.artifacts` — persistent quantized checkpoints
    (packed ints + scales + regenerable transform seeds);
  * :mod:`repro.serve.distributed` — tensor-parallel runtime: packed
    weights, the physical page pool (over KV heads), and the paged
    decode dispatch all shard over the model mesh axis;
  * :mod:`repro.serve.telemetry` — off-by-default observability: ring-
    buffer span tracer (Perfetto/Chrome trace export, optional
    ``jax.profiler`` annotations), typed metrics registry, and per-
    request lifecycle latency histograms;
  * :mod:`repro.serve.faults`    — failure domains: typed admission /
    integrity / dispatch exceptions and a seeded deterministic
    fault-injection plan (``parse_fault_plan``) the engine, pool,
    adapter, and artifact loader all honour behind a no-op default;
  * :mod:`repro.serve.quality`   — quantization-quality observability:
    per-layer quality manifests (incoherence µ, Hessian spectrum, proxy
    loss) folded into artifacts, baseline regression checks at load, and
    online serving-quality canaries (teacher-forced NLL probe + shadow
    fp-oracle drift sampling) at serve time;
  * :mod:`repro.serve.lifecycle` — engine drivers: the engine exposes a
    pure ``tick()`` + lifecycle API, and a driver (the blocking
    ``run_to_completion`` here, or the front door's async tick task)
    owns the loop;
  * :mod:`repro.serve.frontdoor` — streaming HTTP/SSE front door:
    asyncio server that owns the engine thread, typed-admission → HTTP
    mapping (429/413 + Retry-After), per-tenant token buckets +
    priority classes, graceful SIGTERM/SIGINT drain through the KV leak
    gate, and a reversible load-shedding degradation ladder;
  * :mod:`repro.serve.fleet`     — data-parallel replica fleet: a
    supervisor (heartbeat + tick-stall watchdog, backoff restarts,
    give-up circuit breaker) and an HTTP router with sticky
    prefix-affinity balancing and journal-backed in-flight failover
    that resumes a crashed replica's stream token-identically on a
    survivor.
"""
from repro.serve.adapter import CachedDecoder
from repro.serve.artifacts import ArtifactCorruption, load_quantized, save_quantized
from repro.serve.distributed import DistributedCachedDecoder, make_serving_mesh
from repro.serve.engine import Engine, EngineConfig, TickResult
from repro.serve.faults import (
    AdmissionRejected,
    FaultInjected,
    FaultPlan,
    FaultRule,
    parse_fault_plan,
)
from repro.serve.kv_cache import PagedKVPool
from repro.serve.quality import (
    ShadowSampler,
    build_quality_section,
    canary_probe,
    check_artifact_quality,
    load_baseline,
    teacher_forced_nll,
    write_baseline,
)
from repro.serve.lifecycle import run_to_completion
from repro.serve.scheduler import (
    Request,
    RequestState,
    TenantPolicy,
    TokenBudgetFCFS,
)
from repro.serve.telemetry import (
    MetricsRegistry,
    Tracer,
    phase_breakdown,
    validate_chrome_trace,
)

__all__ = [
    "CachedDecoder",
    "DistributedCachedDecoder",
    "make_serving_mesh",
    "Engine",
    "EngineConfig",
    "TickResult",
    "PagedKVPool",
    "Request",
    "RequestState",
    "TenantPolicy",
    "TokenBudgetFCFS",
    "run_to_completion",
    "save_quantized",
    "load_quantized",
    "ArtifactCorruption",
    "AdmissionRejected",
    "FaultInjected",
    "FaultPlan",
    "FaultRule",
    "parse_fault_plan",
    "Tracer",
    "MetricsRegistry",
    "phase_breakdown",
    "validate_chrome_trace",
    "ShadowSampler",
    "build_quality_section",
    "canary_probe",
    "check_artifact_quality",
    "load_baseline",
    "teacher_forced_nll",
    "write_baseline",
]
