"""Primitive layers: norms, RoPE, attention (GQA/qk-norm/bias/cross),
MLP (SwiGLU/GeLU), and MoE with scatter-based token dispatch.

Everything is functional: ``init_*`` builds a params dict (+ a parallel
``*_axes`` dict of logical-axis tuples for sharding), ``apply``-style
functions consume it.  Sharding constraints go through
:func:`repro.runtime.sharding.constrain`, which is a no-op without an
active mesh context — so these run unchanged on one CPU device.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import zlib

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.runtime.sharding import constrain

# ---------------------------------------------------------------------------
# small utilities
# ---------------------------------------------------------------------------


def _key(key: jax.Array, *path: str) -> jax.Array:
    # crc32, NOT hash(): str hashing is salted per process
    # (PYTHONHASHSEED), which made init(seed) draw different params on
    # every run — breaking cross-process reproducibility of greedy
    # streams, benchmarks, and any test comparing two processes
    for p in path:
        key = jax.random.fold_in(key, zlib.crc32(p.encode()) & 0x7FFFFFFF)
    return key


def _init_dense(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_w(key, cfg: ArchConfig, shape, dtype, scale=None):
    """A projection weight: dense (in, out), or QuIP-packed when
    cfg.weight_bits > 0 (the paper's 2-bit serving path as a first-class
    model feature — §Perf iteration A4).

    Packed layout follows repro.core.packing: int32 (in/vals, out) along
    the reduction dim + a per-matrix scale; dequant is w = (2q/maxq - 1)*s.
    On TPU the unpack runs inside the quant_matmul Pallas kernel (VMEM);
    the XLA fallback materializes the dequantized tile.
    """
    W = _init_dense(key, shape, jnp.float32, scale)
    if not cfg.weight_bits:
        return W.astype(dtype)
    from repro.core import packing

    bits = cfg.weight_bits
    vals = 32 // bits
    assert shape[0] % vals == 0, (shape, bits)
    maxq = 2**bits - 1
    s = jnp.max(jnp.abs(W)) + 1e-8
    grid = jnp.clip(jnp.round((W.T / s + 1.0) * (maxq / 2.0)), 0, maxq)
    return {
        "packed": packing.pack(grid.astype(jnp.int32), bits),
        "scale": s.astype(jnp.float32),
    }


def w_axes(cfg: ArchConfig, axes: tuple):
    return {"packed": axes, "scale": ()} if cfg.weight_bits else axes


def apply_w(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """y = x @ W for dense or packed weights."""
    if isinstance(p, dict) and "packed" in p:
        from repro.core import packing

        bits = cfg.weight_bits
        vals = 32 // bits
        maxq = 2**bits - 1
        n = p["packed"].shape[0] * vals
        Wq = packing.unpack(p["packed"], bits, n).astype(x.dtype)  # (out, in)
        W = (Wq * (2.0 / maxq) - 1.0) * p["scale"].astype(x.dtype)
        return x @ W.T
    return x @ p


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_apply(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg: ArchConfig, dim: int, kind: str = "rms") -> dict:
    dt = jnp.dtype(cfg.dtype)
    p = {"scale": jnp.ones((dim,), dt)}
    if kind == "ln":
        p["bias"] = jnp.zeros((dim,), dt)
    return p


def norm_axes(kind: str = "rms") -> dict:
    ax = {"scale": ("norm",)}
    if kind == "ln":
        ax["bias"] = ("norm",)
    return ax


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (llama rotate-half convention).

    x: (..., S, H, hd); positions: (S,) or (B, S) int32.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (self / cross, full-sequence and cached decode)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, cross: bool = False) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    p = {
        "wq": init_w(_key(key, "wq"), cfg, (d, cfg.q_dim), dt),
        "wk": init_w(_key(key, "wk"), cfg, (d, cfg.kv_dim), dt),
        "wv": init_w(_key(key, "wv"), cfg, (d, cfg.kv_dim), dt),
        "wo": init_w(
            _key(key, "wo"), cfg, (cfg.q_dim, d), dt,
            scale=(cfg.q_dim**-0.5) / math.sqrt(2 * max(cfg.n_layers, 1)),
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dt)
    if cross:
        p["gate"] = jnp.zeros((), dt)  # tanh-gated cross-attn (llama-3.2)
    return p


def attention_axes(cfg: ArchConfig, cross: bool = False) -> dict:
    ax = {
        "wq": w_axes(cfg, ("embed", "heads")),
        "wk": w_axes(cfg, ("embed", "kv_heads")),
        "wv": w_axes(cfg, ("embed", "kv_heads")),
        "wo": w_axes(cfg, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        ax.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    if cfg.qk_norm:
        ax.update(q_norm=("norm",), k_norm=("norm",))
    if cross:
        ax["gate"] = ()
    return ax


def _project_qkv(p, cfg: ArchConfig, x, x_kv, pos_q, pos_kv, use_rope: bool):
    B, S, _ = x.shape
    Skv = x_kv.shape[1]
    q = apply_w(p["wq"], x, cfg)
    k = apply_w(p["wk"], x_kv, cfg)
    v = apply_w(p["wv"], x_kv, cfg)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, pos_q, cfg.rope_theta)
        k = rope(k, pos_kv, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, cfg: ArchConfig):
    """q: (B, Sq, H, hd), k: (B, Skv, KV, hd) -> (B, KV, G, Sq, Skv) fp32.

    Grouped einsum: the repeated-KV operand is never materialized.
    """
    B, Sq, H, hd = q.shape
    G = H // cfg.n_kv_heads
    qg = q.reshape(B, Sq, cfg.n_kv_heads, G, hd)
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
    )
    return s * (hd**-0.5)


def _gqa_out(probs, v, cfg: ArchConfig):
    """probs: (B, KV, G, Sq, Skv), v: (B, Skv, KV, hd) -> (B, Sq, H, hd).

    probs are cast DOWN to v's storage dtype for the PV matmul (fp32
    accumulation via preferred_element_type) — upcasting v would double
    the KV-cache read traffic (§Perf iteration A1)."""
    B, KV, G, Sq, Skv = probs.shape
    o = jnp.einsum(
        "bkgqs,bskd->bqkgd",
        probs.astype(v.dtype),
        v,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, Sq, cfg.n_heads, cfg.head_dim)


def attention_full(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    x_kv: Optional[jax.Array] = None,
    positions_kv: Optional[jax.Array] = None,
    q_chunk: Optional[int] = None,
    return_kv: bool = False,
):
    """Full-sequence attention, chunked over query blocks.

    x: (B, S, D).  ``x_kv`` switches to cross-attention.  Returns (B, S, D)
    (and the (k, v) tensors when ``return_kv`` for prefill cache building).
    """
    B, S, D = x.shape
    cross = x_kv is not None
    x_kv = x if x_kv is None else x_kv
    positions_kv = positions if positions_kv is None else positions_kv
    q, k, v = _project_qkv(
        p, cfg, x, x_kv, positions, positions_kv, use_rope=not cross
    )
    q = constrain(q, ("batch", "seq", "act_heads", None))
    k = constrain(k, ("batch", "seq", "act_heads", None))

    qc = min(q_chunk or cfg.attn_q_chunk, S)
    while S % qc:
        qc -= 1
    nq = S // qc

    def one_chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * qc, qc, axis=1)
        pq = jax.lax.dynamic_slice_in_dim(positions, i * qc, qc, axis=0)
        s = _gqa_scores(qs, k, cfg)  # (B, KV, G, qc, S)
        if causal:
            # additive bias, computed once per chunk WITHOUT the head dims —
            # a where() on the full score tensor materializes a pred array
            # broadcast over heads (§Perf iteration B2)
            bias = jnp.where(
                pq[:, None] >= positions_kv[None, :], 0.0, -1e30
            ).astype(jnp.float32)
            s = s + bias[None, None, None]
        if cfg.attn_bf16_probs:
            # flash-style: fp32 max/sum statistics, bf16 exp/probs tensors
            m = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - m).astype(jnp.bfloat16)
            denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
            probs = p / denom.astype(jnp.bfloat16)
        else:
            probs = jax.nn.softmax(s, axis=-1)
        return _gqa_out(probs, v, cfg)

    if nq == 1:
        o = one_chunk(0)
    else:
        o = jax.lax.map(one_chunk, jnp.arange(nq))  # (nq, B, qc, H, hd)
        o = jnp.moveaxis(o, 0, 1).reshape(B, S, cfg.n_heads, cfg.head_dim)
    o = o.astype(x.dtype).reshape(B, S, cfg.q_dim)
    out = apply_w(p["wo"], o, cfg)
    if "gate" in p:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out
    out = constrain(out, ("batch", "seq", "act_embed"))
    if return_kv:
        return out, (k, v)
    return out


# --- KV cache -------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dt = dtype or jnp.dtype(cfg.dtype)
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if dt == jnp.int8:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.float32),
            "v_scale": jnp.zeros(shape[:-1], jnp.float32),
        }
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def kv_cache_axes(int8: bool = False) -> dict:
    ax = {
        "k": ("batch", "seq_kv", None, None),
        "v": ("batch", "seq_kv", None, None),
    }
    if int8:
        ax["k_scale"] = ("batch", "seq_kv", None)
        ax["v_scale"] = ("batch", "seq_kv", None)
    return ax


def _quantize_kv(x: jax.Array):
    """Per-(token, head) symmetric int8: x (B, S, KV, hd)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return q.astype(jnp.int8), scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_store(cache: dict, k: jax.Array, v: jax.Array, index) -> dict:
    """Write k/v (B, S_new, KV, hd) at position ``index`` along seq."""
    if cache["k"].dtype == jnp.int8:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        return {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, index, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, index, 1),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks, index, 1
            ),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs, index, 1
            ),
        }
    return {
        "k": jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), index, 1
        ),
        "v": jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), index, 1
        ),
    }


def cache_read(cache: dict, dtype):
    if cache["k"].dtype == jnp.int8:
        return (
            _dequantize_kv(cache["k"], cache["k_scale"], dtype),
            _dequantize_kv(cache["v"], cache["v_scale"], dtype),
        )
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


def attention_decode(
    p: dict,
    x: jax.Array,
    cfg: ArchConfig,
    cache: dict,
    pos: jax.Array,
    *,
    cross: bool = False,
):
    """One-token attention against a cache.

    x: (B, 1, D); pos: scalar int32 current position (same for the batch).
    For cross-attention the cache holds the full encoder/vision KV and is
    not updated.  Returns (out (B, 1, D), new_cache).
    """
    B = x.shape[0]
    q = apply_w(p["wq"], x, cfg)
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    if not cross:
        k_new = apply_w(p["wk"], x, cfg)
        v_new = apply_w(p["wv"], x, cfg)
        if cfg.qkv_bias:
            k_new, v_new = k_new + p["bk"], v_new + p["bv"]
        k_new = k_new.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v_new = v_new.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
        q = rope(q, pos[None], cfg.rope_theta)
        k_new = rope(k_new, pos[None], cfg.rope_theta)
        cache = cache_store(cache, k_new, v_new, pos)
    k, v = cache_read(cache, x.dtype)
    S = k.shape[1]
    s = _gqa_scores(q, k, cfg)[:, :, :, 0, :]  # (B, KV, G, S)
    if not cross:
        bias = jnp.where(jnp.arange(S) <= pos, 0.0, -1e30).astype(jnp.float32)
        s = s + bias[None, None, None]
    probs = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgs,bskd->bkgd",
        probs.astype(v.dtype),
        v,
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(B, 1, cfg.q_dim).astype(x.dtype)
    out = apply_w(p["wo"], o, cfg)
    if "gate" in p:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out
    return out, cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    p = {
        "wi": init_w(_key(key, "wi"), cfg, (d, f), dt),
        "wo": init_w(
            _key(key, "wo"), cfg, (f, d), dt,
            scale=(f**-0.5) / math.sqrt(2 * max(cfg.n_layers, 1)),
        ),
    }
    if cfg.mlp == "swiglu":
        p["wg"] = init_w(_key(key, "wg"), cfg, (d, f), dt)
    if cfg.mlp_bias:
        p["bi"] = jnp.zeros((f,), dt)
        p["bo"] = jnp.zeros((d,), dt)
    return p


def mlp_axes(cfg: ArchConfig) -> dict:
    ax = {"wi": w_axes(cfg, ("embed", "ff")), "wo": w_axes(cfg, ("ff", "embed"))}
    if cfg.mlp == "swiglu":
        ax["wg"] = w_axes(cfg, ("embed", "ff"))
    if cfg.mlp_bias:
        ax.update(bi=("ff",), bo=("norm",))
    return ax


def mlp_apply(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = apply_w(p["wi"], x, cfg)
    if cfg.mlp_bias:
        h = h + p["bi"]
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(h) * apply_w(p["wg"], x, cfg)
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("batch", "seq", "act_ff"))
    out = apply_w(p["wo"], h, cfg)
    if cfg.mlp_bias:
        out = out + p["bo"]
    return constrain(out, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# MoE (scatter-based dispatch, expert-parallel friendly)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ArchConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": _init_dense(_key(key, "router"), (d, E), jnp.float32),
        "wi": _init_dense(_key(key, "ewi"), (E, d, f), dt),
        "wg": _init_dense(_key(key, "ewg"), (E, d, f), dt),
        "wo": _init_dense(
            _key(key, "ewo"), (E, f, d), dt,
            scale=(f**-0.5) / math.sqrt(2 * max(cfg.n_layers, 1)),
        ),
    }
    if cfg.dense_residual:
        p["dense"] = init_mlp(_key(key, "dense"), cfg)
    return p


def moe_axes(cfg: ArchConfig) -> dict:
    # expert weights use their OWN logical axes: they are already sharded
    # 16x by expert parallelism; FSDP-sharding their embed dim too makes
    # GSPMD partial-sum every expert matmul and all-reduce (E, C, F)
    # activations per microbatch — the dominant collective in the arctic
    # train profile (§Perf D3).  Default rules map expert_embed/expert_ff
    # to None (EP-only sharding).
    ax = {
        "router": ("embed", None),
        "wi": ("experts", "expert_embed", "expert_ff"),
        "wg": ("experts", "expert_embed", "expert_ff"),
        "wo": ("experts", "expert_ff", "expert_embed"),
    }
    if cfg.dense_residual:
        ax["dense"] = mlp_axes(cfg)
    return ax


def moe_capacity(cfg: ArchConfig, tokens: int) -> int:
    c = math.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(p: dict, x: jax.Array, cfg: ArchConfig):
    """x: (B, S, D) -> (y, aux_loss).

    Scatter/gather dispatch into an (E, C, D) buffer (NOT the O(T·E·C·D)
    dense-dispatch einsum): positions within each expert come from a cumsum
    over the one-hot routing matrix; tokens past capacity are dropped
    (standard capacity-factor semantics).
    """
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, D)
    logits = (xt.astype(jnp.float32)) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert
    e_flat = top_e.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    pos_flat = jnp.sum(pos_in_e * onehot, axis=-1)  # (T*k,)
    C = moe_capacity(cfg, T)
    keep = pos_flat < C

    x_rep = jnp.repeat(xt, k, axis=0)  # (T*k, D)
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[e_flat, jnp.where(keep, pos_flat, C - 1)].add(
        x_rep * keep[:, None].astype(x.dtype)
    )
    buf = constrain(buf, ("act_experts", None, None))

    # expert FFN (batched over E)
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    h = constrain(h, ("act_experts", None, None))
    y_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])

    # gather back and combine with gate weights
    y_tok = y_e[e_flat, jnp.where(keep, pos_flat, 0)]  # (T*k, D)
    y_tok = y_tok * (keep[:, None] * top_p.reshape(-1)[:, None]).astype(x.dtype)
    y = jnp.sum(y_tok.reshape(T, k, D), axis=1)

    if cfg.dense_residual and "dense" in p:
        y = y + mlp_apply(p["dense"], x, cfg).reshape(T, D)

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), 0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ArchConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    p = {"tok": _init_dense(_key(key, "tok"), (cfg.vocab, cfg.d_model), dt, 0.02)}
    if not cfg.tie_embeddings:
        p["head"] = _init_dense(
            _key(key, "head"), (cfg.d_model, cfg.vocab), dt, cfg.d_model**-0.5
        )
    return p


def embedding_axes(cfg: ArchConfig) -> dict:
    ax = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        ax["head"] = ("embed", "vocab")
    return ax


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    h = jnp.take(p["tok"], tokens, axis=0)
    return constrain(h, ("batch", "seq", "act_embed"))


def lm_logits(p: dict, h: jax.Array) -> jax.Array:
    w = p["head"] if "head" in p else p["tok"].T
    logits = h @ w
    return constrain(logits, ("batch", "seq", "act_ff"))
