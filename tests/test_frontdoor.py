"""Streaming front door (ISSUE 9): HTTP/SSE token identity vs the
in-process engine, typed-admission HTTP mapping (429/413 +
Retry-After), disconnect-triggered cancel, graceful drain through the
leak gate, the degradation ladder, and the network-layer fault hooks.

The HTTP tests run a real :class:`FrontDoor` on an ephemeral localhost
port with the server loop on a daemon thread (stdlib ``http.client``
as the client — the container has no aiohttp/requests)."""
from __future__ import annotations

import http.client
import json
import socket
import struct
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import make_calibration
from repro.models import build_model
from repro.serve import CachedDecoder, Engine, EngineConfig, TenantPolicy
from repro.serve.faults import AdmissionRejected, parse_fault_plan
from repro.serve.frontdoor import (
    DegradationLadder,
    FrontDoor,
    LadderConfig,
    leak_gate,
    parse_tenants,
)
from repro.serve.frontdoor.admission import (
    parse_generate_body,
    rejection_response,
)


# ---------------------------------------------------------------------------
# fixtures + helpers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fp_stack():
    cfg = get_smoke_config("qwen3-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=8,
                               seed=3).tokens
    return cfg, model, params, prompts


def _engine(model, params, *, gen=8, prompt_len=8, **kw):
    ecfg = dict(max_seq_len=prompt_len + gen, n_slots=4, page_size=4,
                token_budget=32, prefill_chunk=8)
    ecfg.update(kw)
    return Engine(CachedDecoder.from_model(model, params),
                  EngineConfig(**ecfg))


def _post(port, payload: dict, timeout=30):
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    c.request("POST", "/v1/generate", json.dumps(payload),
              {"Content-Type": "application/json"})
    return c, c.getresponse()


def _get_json(port, path, timeout=10):
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    c.request("GET", path)
    r = c.getresponse()
    body = json.loads(r.read())
    c.close()
    return r.status, body


def _parse_sse(raw: bytes):
    events = []
    for block in raw.decode().strip().split("\n\n"):
        lines = dict(ln.split(": ", 1) for ln in block.split("\n"))
        events.append((lines["event"], json.loads(lines["data"])))
    return events


def _gen_tokens(port, prompt, max_new, *, stream=True, **extra):
    """Run one generate call to completion; returns the token list."""
    payload = {"prompt": [int(t) for t in prompt], "max_new": max_new,
               "stream": stream, **extra}
    c, r = _post(port, payload)
    try:
        assert r.status == 200, (r.status, r.read())
        raw = r.read()
    finally:
        c.close()
    if not stream:
        return json.loads(raw)["tokens"]
    events = _parse_sse(raw)
    toks = [d["token"] for ev, d in events if ev == "token"]
    done = [d for ev, d in events if ev == "done"]
    assert len(done) == 1 and done[0]["tokens"] == toks
    assert done[0]["finish_reason"] in ("length", "stop")
    return toks


# ---------------------------------------------------------------------------
# token identity: HTTP/SSE == in-process, fp and quantized
# ---------------------------------------------------------------------------


def test_http_token_identity_fp(fp_stack):
    cfg, model, params, prompts = fp_stack
    gen = 8
    ref_eng = _engine(model, params, gen=gen)
    refs = [ref_eng.submit(np.asarray(p), max_new=gen) for p in prompts]
    ref_eng.run()
    ref = [[int(t) for t in r.out_tokens] for r in refs]

    fd = FrontDoor(_engine(model, params, gen=gen),
                   drain_timeout_s=5.0).start_in_thread()
    try:
        got_sse = [_gen_tokens(fd.port, p, gen) for p in prompts]
        got_buf = [_gen_tokens(fd.port, p, gen, stream=False)
                   for p in prompts]
    finally:
        report = fd.drain_and_join()
    assert got_sse == ref  # byte-identical streams over SSE
    assert got_buf == ref  # and over the buffered JSON path
    assert report.clean


def test_http_token_identity_quantized(fp_stack):
    from repro.core.quantizer import QuipConfig
    from repro.launch.quantize import quantize_dense_model

    cfg, model, params, prompts = fp_stack
    calib = make_calibration(cfg.vocab, n_segments=4, seg_len=32, seed=7)
    qm = quantize_dense_model(
        params, cfg, QuipConfig(bits=2, method="ldlq", use_kernel=False),
        calib.tokens, seed=0, verbose=False,
    )
    gen = 6
    ecfg = EngineConfig(max_seq_len=prompts.shape[1] + gen, n_slots=4,
                        page_size=4, token_budget=32, prefill_chunk=8)
    ref_eng = Engine(CachedDecoder.from_quantized(qm), ecfg)
    refs = [ref_eng.submit(np.asarray(p), max_new=gen) for p in prompts]
    ref_eng.run()
    ref = [[int(t) for t in r.out_tokens] for r in refs]

    fd = FrontDoor(Engine(CachedDecoder.from_quantized(qm), ecfg),
                   drain_timeout_s=5.0).start_in_thread()
    try:
        got = [_gen_tokens(fd.port, p, gen) for p in prompts]
    finally:
        report = fd.drain_and_join()
    assert got == ref
    assert report.clean


# ---------------------------------------------------------------------------
# typed admission -> HTTP semantics
# ---------------------------------------------------------------------------


def test_http_over_capacity_413(fp_stack):
    cfg, model, params, prompts = fp_stack
    fd = FrontDoor(_engine(model, params), drain_timeout_s=2.0
                   ).start_in_thread()
    try:
        c, r = _post(fd.port, {"prompt": [1, 2, 3], "max_new": 10_000})
        body = json.loads(r.read())
        c.close()
        assert r.status == 413
        assert body["error"] == "over_capacity"
        assert body["retryable"] is False
        assert body["needed_pages"] > body["available_pages"]
        assert "retry-after" not in {
            k.lower() for k in dict(r.getheaders())
        }
    finally:
        assert fd.drain_and_join().clean


def test_http_rate_limited_429_with_retry_after(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(
        model, params,
        tenants={"free": TenantPolicy(rate=0.001, burst=1, priority=0)},
    )
    fd = FrontDoor(eng, drain_timeout_s=5.0).start_in_thread()
    try:
        p = [int(t) for t in prompts[0]]
        assert _gen_tokens(fd.port, p, 4, tenant="free")  # burst admit
        c, r = _post(fd.port, {"prompt": p, "max_new": 4, "tenant": "free"})
        body = json.loads(r.read())
        headers = {k.lower(): v for k, v in r.getheaders()}
        c.close()
        assert r.status == 429
        assert body["error"] == "rate_limited"
        assert body["retryable"] is True
        assert body["tenant"] == "free"
        assert int(headers["retry-after"]) >= 1
    finally:
        assert fd.drain_and_join().clean


def test_http_queue_full_429_and_drain_under_traffic(fp_stack):
    """Overload behaves, not breaks: with one lane and a one-deep queue
    a third concurrent stream gets 429 queue_full + Retry-After, and a
    short-deadline drain under that live traffic cancels the in-flight
    lanes with zero leaked pages."""
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, gen=256, n_slots=1, max_queue=1,
                  token_budget=8)
    fd = FrontDoor(eng, drain_timeout_s=0.3).start_in_thread()
    p = [int(t) for t in prompts[0]]
    # stream A: read its SSE head so we know it was ADMITTED (running)
    ca, ra = _post(fd.port, {"prompt": p, "max_new": 256})
    assert ra.status == 200
    assert ra.read(1)  # first byte of the event stream
    # B parks in the queue (no free lane); its response arrives at drain
    results = {}

    def _b():
        try:
            cb, rb = _post(fd.port, {"prompt": p, "max_new": 256},
                           timeout=60)
            results["b_status"] = rb.status
            rb.read()
            cb.close()
        except (ConnectionError, OSError) as e:  # killed by drain: fine
            results["b_error"] = str(e)

    tb = threading.Thread(target=_b, daemon=True)
    tb.start()
    deadline = time.time() + 10
    while eng.scheduler.pending < 1 and time.time() < deadline:
        time.sleep(0.01)
    assert eng.scheduler.pending >= 1, "B never reached the queue"
    # C: queue full -> 429 queue_full, engine untouched and alive
    cc, rc = _post(fd.port, {"prompt": p, "max_new": 256})
    body = json.loads(rc.read())
    headers = {k.lower(): v for k, v in rc.getheaders()}
    cc.close()
    assert rc.status == 429
    assert body["error"] == "queue_full"
    assert body["retryable"] is True
    assert "retry-after" in headers
    status, health = _get_json(fd.port, "/healthz")
    assert status == 200 and health["status"] == "ok"

    report = fd.drain_and_join(reason="requested")
    ca.close()
    tb.join(10)
    assert report.clean and report.exit_code == 0
    assert report.deadline_hit  # 256-token lanes can't finish in 0.3s
    assert report.cancelled >= 1
    assert eng.metrics.counter("finish:cancelled").value >= 1


def test_http_bad_request_400(fp_stack):
    cfg, model, params, prompts = fp_stack
    fd = FrontDoor(_engine(model, params), drain_timeout_s=2.0
                   ).start_in_thread()
    try:
        for payload in (b"not json",
                        json.dumps({"max_new": 4}).encode(),
                        json.dumps({"prompt": [], "max_new": 4}).encode(),
                        json.dumps({"prompt": [1], "max_new": 4,
                                    "bogus": 1}).encode()):
            c = http.client.HTTPConnection("127.0.0.1", fd.port, timeout=10)
            c.request("POST", "/v1/generate", payload)
            r = c.getresponse()
            body = json.loads(r.read())
            c.close()
            assert r.status == 400
            assert body["error"] == "bad_request"
        status, _ = _get_json(fd.port, "/404-nope")
        assert status == 404
    finally:
        assert fd.drain_and_join().clean


# ---------------------------------------------------------------------------
# disconnect -> cancel, endpoints, shed gate
# ---------------------------------------------------------------------------


def test_mid_stream_disconnect_cancels_request(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, gen=128, token_budget=8)
    fd = FrontDoor(eng, drain_timeout_s=2.0).start_in_thread()
    p = [int(t) for t in prompts[0]]
    c, r = _post(fd.port, {"prompt": p, "max_new": 128})
    assert r.status == 200
    assert r.read(16)  # at least one token frame is in flight
    # client vanishes mid-stream: http.client already detached c.sock
    # (Connection: close), so pull the live socket from the response
    # and abort it with an RST (SO_LINGER 0) instead of a polite FIN
    sock = r.fp.raw._sock
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                    struct.pack("ii", 1, 0))
    r.close()  # drop the makefile io-ref so close() really closes
    sock.close()
    deadline = time.time() + 15
    while (eng.metrics.counter("finish:cancelled").value < 1
           and time.time() < deadline):
        time.sleep(0.02)
    assert eng.metrics.counter("finish:cancelled").value >= 1
    assert eng.metrics.counter("client_disconnects").value >= 1
    report = fd.drain_and_join()
    assert report.clean  # the dropped lane's pages all came back


def test_healthz_readyz_metricsz_and_drain_503(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, gen=256, token_budget=8)
    fd = FrontDoor(eng, drain_timeout_s=3.0).start_in_thread()
    status, h = _get_json(fd.port, "/healthz")
    assert status == 200 and h["status"] == "ok"
    status, rz = _get_json(fd.port, "/readyz")
    assert status == 200 and rz["ready"] is True and "ladder_level" in rz
    status, m = _get_json(fd.port, "/metricsz")
    assert status == 200
    assert "steps" in m and m["server"]["ladder_level"] == 0
    assert 0.0 <= m["server"]["pressure"] <= 1.0
    # park a long stream so the server stays draining long enough to probe
    p = [int(t) for t in prompts[0]]
    c, r = _post(fd.port, {"prompt": p, "max_new": 256})
    assert r.status == 200 and r.read(1)
    fd._loop.call_soon_threadsafe(fd.request_drain, "requested")
    deadline = time.time() + 5
    got_503 = False
    while time.time() < deadline:
        try:
            status, rz = _get_json(fd.port, "/readyz", timeout=2)
        except (ConnectionError, OSError):
            break  # server already shut down
        if status == 503 and rz["draining"]:
            got_503 = True
            break
        time.sleep(0.01)
    assert got_503, "readyz never reported draining"
    report = fd.drain_and_join()
    c.close()
    assert report.clean


def test_shed_gate_rejects_only_lowest_class(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(
        model, params,
        tenants={"paid": TenantPolicy(priority=0),
                 "free": TenantPolicy(priority=1)},
    )
    fd = FrontDoor(eng, drain_timeout_s=2.0).start_in_thread()
    try:
        fd.ladder.shedding = True  # force the shed rung
        p = [int(t) for t in prompts[0]]
        c, r = _post(fd.port, {"prompt": p, "max_new": 4, "tenant": "free"})
        body = json.loads(r.read())
        c.close()
        assert r.status == 429 and body["error"] == "shed"
        assert body["retryable"] is True
        # high class sails through while the shed rung is active
        assert len(_gen_tokens(fd.port, p, 4, tenant="paid")) == 4
        assert eng.metrics.counter("shed_requests").value == 1
    finally:
        assert fd.drain_and_join().clean


# ---------------------------------------------------------------------------
# degradation ladder (engine-thread unit tests, no HTTP)
# ---------------------------------------------------------------------------


def _pressurize(eng, n):
    """Park n requests in waiting (far-future arrival): pending rises,
    nothing ever runs."""
    return [eng.submit(np.arange(1, 5, dtype=np.int32), max_new=2,
                       arrival=1e9) for _ in range(n)]


def test_ladder_escalates_and_restores_spec_k(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, paged_decode=True, speculative_k=4,
                  max_queue=4)
    ladder = DegradationLadder(
        eng, LadderConfig(sustain_s=0.1, cooloff_s=0.1))
    assert ladder.actions == ["spec_half", "spec_off", "shed_low"]
    reqs = _pressurize(eng, 4)  # queue_frac = 4/4 = 1.0
    t = 0.0
    seen = []
    for _ in range(12):
        act = ladder.observe(t)
        if act:
            seen.append(act)
        t += 0.11
    assert seen == ["spec_half", "spec_off", "shed_low"]
    assert ladder.level == 3 and ladder.shedding and eng.spec_k == 0
    assert eng.metrics.counter("ladder_escalations").value == 3
    assert eng.metrics.gauge("ladder_level").value == 3
    for r in reqs:  # pressure clears -> every rung unwinds
        eng.cancel(r.rid)
    seen = []
    for _ in range(12):
        act = ladder.observe(t)
        if act:
            seen.append(act)
        t += 0.11
    assert seen == ["+shed_low", "+spec_off", "+spec_half"]
    assert ladder.level == 0 and not ladder.shedding
    assert eng.spec_k == 4  # fully restored
    assert eng.metrics.counter("ladder_deescalations").value == 3


def test_ladder_hysteresis_band_holds_level(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, max_queue=10)
    ladder = DegradationLadder(
        eng, LadderConfig(high_water=0.8, low_water=0.3, sustain_s=0.1,
                          cooloff_s=0.1))
    assert ladder.actions == ["shed_low"]  # non-speculative engine
    reqs = _pressurize(eng, 10)
    assert ladder.observe(0.0) is None  # first sight arms the timer
    assert ladder.observe(0.2) == "shed_low"
    for r in reqs[4:]:  # drop pressure into the band (6/10 = 0.6)
        eng.cancel(r.rid)
    for t in (0.4, 0.6, 0.8):
        assert ladder.observe(t) is None  # held, neither direction
    assert ladder.level == 1 and ladder.shedding


def test_set_speculative_k_clamps(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, paged_decode=True, speculative_k=4)
    assert eng.set_speculative_k(2) == 2
    assert eng.set_speculative_k(99) == 4  # clamped to the built depth
    assert eng.set_speculative_k(0) == 0
    with pytest.raises(ValueError):
        eng.set_speculative_k(-1)


# ---------------------------------------------------------------------------
# tick()/TickResult contract + lifecycle API
# ---------------------------------------------------------------------------


def test_tick_result_reports_emissions_and_finishes(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, gen=5)
    reqs = [eng.submit(np.asarray(p), max_new=5) for p in prompts]
    per_rid: dict[int, list] = {r.rid: [] for r in reqs}
    finished = []
    while not eng.idle:
        res = eng.tick()
        for req, tok in res.emitted:
            per_rid[req.rid].append(tok)
        finished.extend(res.finished)
    assert sorted(r.rid for r in finished) == sorted(r.rid for r in reqs)
    for r in reqs:  # TickResult emissions reconstruct each stream exactly
        assert per_rid[r.rid] == [int(t) for t in r.out_tokens]
    assert leak_gate(eng.pool) == (0, 0)


def test_between_tick_cancel_reported_by_next_tick(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, gen=64, token_budget=8)
    req = eng.submit(np.asarray(prompts[0]), max_new=64)
    while not req.out_tokens:
        eng.tick()
    assert eng.cancel(req.rid)  # between ticks, as the server does
    res = eng.tick()
    assert req in res.finished
    assert req.finish_reason == "cancelled"
    assert eng.idle and eng.next_arrival() is None
    assert eng.cancel_all() == []  # nothing live left


# ---------------------------------------------------------------------------
# satellites: AdmissionRejected detail, fault hooks, tenant spec
# ---------------------------------------------------------------------------


def test_admission_rejected_str_carries_detail():
    e = AdmissionRejected("over_capacity", retryable=False,
                          needed_pages=9, available_pages=4)
    s = str(e)
    assert "needs 9 pages, 4 available" in s and "not retryable" in s
    assert e.http_status == 413
    d = e.to_dict()
    assert d["error"] == "over_capacity" and d["retryable"] is False
    assert d["needed_pages"] == 9 and d["available_pages"] == 4

    e = AdmissionRejected("rate_limited", retryable=True, tenant="free",
                          retry_after_s=2.5)
    s = str(e)
    assert "tenant 'free'" in s and "retry after 2.5s" in s
    assert s.endswith("retryable")
    assert e.http_status == 429
    status, headers, body = rejection_response(e)
    assert status == 429 and ("Retry-After", "3") in headers
    assert json.loads(body)["retry_after_s"] == 2.5


def test_network_fault_rules_parse_and_fire():
    plan = parse_fault_plan(
        "slow_client@ms=50;disconnect@tokens=2;admission_burst@n=3")
    assert plan.stall_ms(rid=7) == 50
    assert plan.stall_ms(rid=7) is None  # consumed
    assert not plan.disconnect_after(5, 1)  # below the token threshold
    assert plan.disconnect_after(5, 2)
    assert not plan.disconnect_after(5, 3)  # consumed
    assert plan.admission_burst() == 3
    assert plan.admission_burst() == 0
    assert [e["kind"] for e in plan.log] == [
        "slow_client", "disconnect", "admission_burst"]
    with pytest.raises(ValueError):
        parse_fault_plan("slow_client")  # ms= is required
    with pytest.raises(ValueError):
        parse_fault_plan("admission_burst@n=0")


def test_disconnect_fault_injected_over_http(fp_stack):
    """The chaos path end-to-end: an armed disconnect rule drops the SSE
    stream server-side after 2 tokens and the request is cancelled."""
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params, gen=128, token_budget=8)
    eng.faults.rules.extend(parse_fault_plan("disconnect@tokens=2").rules)
    fd = FrontDoor(eng, drain_timeout_s=2.0).start_in_thread()
    p = [int(t) for t in prompts[0]]
    c, r = _post(fd.port, {"prompt": p, "max_new": 128})
    assert r.status == 200
    raw = b""
    try:
        while True:
            chunk = r.read(64)
            if not chunk:
                break
            raw += chunk
    except (ConnectionError, OSError, http.client.IncompleteRead):
        pass  # the fault aborts the transport mid-stream
    c.close()
    deadline = time.time() + 15
    while (eng.metrics.counter("finish:cancelled").value < 1
           and time.time() < deadline):
        time.sleep(0.02)
    assert eng.metrics.counter("finish:cancelled").value == 1
    report = fd.drain_and_join()
    assert report.clean


def test_parse_tenants_spec():
    t = parse_tenants("paid:inf:4:0,free:2.0:8:1,batch:0.5")
    assert t["paid"] == TenantPolicy(rate=None, burst=4, priority=0)
    assert t["free"] == TenantPolicy(rate=2.0, burst=8, priority=1)
    assert t["batch"] == TenantPolicy(rate=0.5, burst=4, priority=0)
    for bad in ("", ":1.0", "a:1:2:3:4", "dup:1,dup:2"):
        with pytest.raises(ValueError):
            parse_tenants(bad)


def test_parse_generate_body_validation():
    ok = parse_generate_body(json.dumps(
        {"prompt": [1, 2], "max_new": 4, "tenant": "t", "priority": 1,
         "stream": False, "temperature": 0.5, "top_p": 0.9, "seed": 3,
         "stop_tokens": [7], "deadline_s": 2.0}).encode())
    assert ok.max_new == 4 and ok.tenant == "t" and not ok.stream
    assert ok.sampling.temperature == 0.5 and ok.stop_tokens == (7,)
    for bad in (
        {"prompt": [1.5], "max_new": 4},
        {"prompt": [1], "max_new": 0},
        {"prompt": [1], "max_new": 4, "priority": -1},
        {"prompt": [1], "max_new": 4, "stream": "yes"},
        {"prompt": [1], "max_new": 4, "top_p": 0.0},
        {"prompt": [1], "max_new": 4, "deadline_s": -1},
    ):
        with pytest.raises(ValueError):
            parse_generate_body(json.dumps(bad).encode())
