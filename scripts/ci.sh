#!/usr/bin/env bash
# CI: unit tests + the end-to-end quantize -> artifact -> serve path.
#
#   scripts/ci.sh          # full run (installs hypothesis if a network is up)
#   CI_FAST=1 scripts/ci.sh  # skip the slow-marked driver tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# hypothesis is optional (property sweeps skip without it); best-effort install
python -c 'import hypothesis' 2>/dev/null \
  || python -m pip install -q hypothesis \
  || echo "[ci] hypothesis unavailable (offline?) — property sweeps will skip"

if [ "${CI_FAST:-0}" = "1" ]; then
  python -m pytest -q -m "not slow"
  # paged-attention kernel parity (interpret mode) must run even if the
  # trimmed selection above ever stops covering it — the fast path can't
  # be allowed to silently drift from the gather-dense oracle
  python -m pytest -q tests/test_paged_attention.py
else
  python -m pytest -q   # includes tests/test_paged_attention.py
fi

# end-to-end serving: fp engine, in-process quantize, and the persistent
# artifact path (quantize once -> serve without re-quantizing)
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --check

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --quantize --bits 4 --check

python -m repro.launch.quantize --arch qwen3-14b --smoke --bits 2 \
  --calib-segments 4 --calib-len 32 --out-dir "$tmp/artifact"

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --load-quantized "$tmp/artifact" --check

# paged fast path: token-identical to the oracle for fp, quantized-artifact,
# and int8-KV serving
python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --check

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --load-quantized "$tmp/artifact" \
  --paged --check

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --kv-int8 --check

# batched paged prefill + prefix cache: one fused cross-request prefill
# dispatch per tick, cached prompt-prefix pages mapped on admission —
# still token-identical to the dense oracle (the --check oracle always
# runs the dense path)
python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --paged-prefill \
  --prefix-cache --check

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --load-quantized "$tmp/artifact" \
  --paged --paged-prefill --prefix-cache --check

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --paged-prefill \
  --kv-int8 --check

# speculative decode: greedy draft-and-verify (one fused (B, K+1) verify
# dispatch per tick, ngram self-drafting, KV rollback) must stay
# token-identical to the dense oracle — fp, quantized artifact, and int8
# pages (whose oracle is the gather-dense int8 engine)
python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --speculative 4 --check

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --load-quantized "$tmp/artifact" \
  --paged --paged-prefill --speculative 2 --check

python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --paged-prefill \
  --speculative 4 --kv-int8 --check

# host-side sampling debug path stays token-identical too
python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --speculative 4 \
  --host-sample --check

# telemetry: a traced serve (sync barriers + periodic metrics + quality
# canaries + shadow sampling) must stay token-identical AND emit a
# schema-valid Chrome/Perfetto trace carrying the canary/drift events
python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --paged-prefill \
  --trace-out "$tmp/serve_trace.json" --trace-sync --metrics-every 0.5 \
  --canary-every 0.5 --shadow-rate 1.0 --check
python - "$tmp/serve_trace.json" <<'PY'
import json, sys
from repro.serve import validate_chrome_trace
obj = json.load(open(sys.argv[1]))
n = validate_chrome_trace(obj)
names = {e.get("name") for e in obj["traceEvents"]}
missing = {"canary_probe", "shadow_drift"} - names
assert not missing, f"quality events missing from trace: {missing}"
print(f"[ci] serve trace schema OK ({n} events, quality events present)")
PY

# quality observability smoke (serve/quality.py, DESIGN.md §13): the
# artifact written above carries a per-layer quality manifest — render
# it, pin it as a baseline, gate a serve on that baseline, and run
# online canaries + full-rate shadow drift sampling.  The canary NLL
# gauge must appear in the summary and the zero-leak gate still holds.
python -m repro.launch.quality_report "$tmp/artifact" \
  --write-baseline "$tmp/quality_base.json"
python -m repro.launch.quality_report "$tmp/artifact" \
  --baseline "$tmp/quality_base.json" --threshold 1.1
quality_out="$(python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --load-quantized "$tmp/artifact" \
  --quality-baseline "$tmp/quality_base.json" --quality-strict \
  --canary-every 0.5 --shadow-rate 1.0)"
echo "$quality_out"
echo "$quality_out" | grep -q "quality baseline OK" \
  || { echo "[ci] quality smoke: baseline check missing"; exit 1; }
echo "$quality_out" | grep -q "canary_nll=" \
  || { echo "[ci] quality smoke: canary NLL gauge missing"; exit 1; }
echo "$quality_out" | grep -q "flips=0" \
  || { echo "[ci] quality smoke: shadow drift reported flips"; exit 1; }

# chaos smoke (serve/faults.py): one allocator failure, one NaN lane,
# one mid-decode cancel injected into a checked paged run — targeted
# requests terminate with their own reasons, survivors stay
# token-identical to the fault-free oracle (prefix-match for the early-
# terminated ones), and the run exits nonzero if any KV page leaks
chaos_out="$(python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 6 --prompt-len 16 --gen 12 --paged --screen-logits \
  --fault-plan 'alloc_fail@rid=0;nan_logits@rid=2;cancel@rid=4,tick=6' \
  --check)"
echo "$chaos_out"
echo "$chaos_out" | grep -q "outcomes: finished=3 cancelled=1 failed=2" \
  || { echo "[ci] chaos smoke: unexpected outcome mix"; exit 1; }

# streaming front door (serve/frontdoor, DESIGN.md §14): boot the HTTP/
# SSE server, run two concurrent token streams over localhost, kill one
# client mid-stream (its lane must cancel and release its pages), then
# SIGTERM the server while the survivor is still streaming — graceful
# drain must exit 0 with zero leaked KV pages and the disconnect visible
# as a finish:cancelled counter
python - <<'PY'
import http.client, json, signal, socket, struct, subprocess, sys
import threading, time

s = socket.socket(); s.bind(("127.0.0.1", 0))
port = s.getsockname()[1]; s.close()
srv = subprocess.Popen(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-14b",
     "--smoke", "--http-port", str(port), "--prompt-len", "16",
     "--gen", "256", "--drain-timeout-s", "5"],
    stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
try:
    deadline = time.time() + 180
    while True:
        assert time.time() < deadline, "front door never came up"
        assert srv.poll() is None, "server died during startup"
        try:
            c = http.client.HTTPConnection("127.0.0.1", port, timeout=2)
            c.request("GET", "/healthz")
            ok = c.getresponse().status == 200
            c.close()
            if ok:
                break
        except OSError:
            time.sleep(0.2)

    results = []

    def stream(abort_after=None):
        body = json.dumps({"prompt": list(range(1, 17)), "max_new": 256})
        c = http.client.HTTPConnection("127.0.0.1", port, timeout=120)
        c.request("POST", "/v1/generate", body,
                  {"Content-Type": "application/json"})
        r = c.getresponse()
        assert r.status == 200, r.status
        n = 0
        for raw in r.fp:
            line = raw.decode("utf-8", "replace").rstrip("\n")
            if line.startswith("event: token"):
                n += 1
                if abort_after and n >= abort_after:
                    # vanish abruptly: RST, not a polite FIN
                    sk = r.fp.raw._sock
                    sk.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                                  struct.pack("ii", 1, 0))
                    r.close(); sk.close()
                    results.append(("aborted", n))
                    return
        results.append(("done", n))

    a = threading.Thread(target=stream)                      # survivor
    b = threading.Thread(target=stream, kwargs={"abort_after": 2})
    a.start(); b.start()
    b.join(60)
    assert ("aborted", 2) in results, results
    time.sleep(0.3)             # let the cancel land, keep A in flight
    srv.send_signal(signal.SIGTERM)   # drain under live traffic
    a.join(60)
    out, _ = srv.communicate(timeout=60)
    print(out)
    assert srv.returncode == 0, f"exit {srv.returncode}"
    assert "drain[sigterm]" in out
    assert "leak gate: clean" in out
    assert "finish:cancelled=" in out, "disconnect cancel not counted"
    assert any(kind == "done" and n > 0 for kind, n in results), results
    print(f"[ci] front-door smoke OK ({results})")
finally:
    if srv.poll() is None:
        srv.kill()
PY

# replica-fleet chaos smoke (serve/fleet, DESIGN.md §15): boot 3 replica
# subprocesses behind the supervised router, stream a reference, then
# kill -9 the exact replica serving a live stream mid-flight — the
# router must fail the stream over to a survivor and splice a token-
# identical continuation into the SAME SSE stream; finally SIGTERM the
# router: the coordinated fleet drain must exit 0 with every drained
# replica's leak gate clean
python - <<'PY'
import http.client, json, os, signal, socket, subprocess, sys
import threading, time

from repro.serve.fleet import prefix_key, rendezvous_rank

s = socket.socket(); s.bind(("127.0.0.1", 0))
port = s.getsockname()[1]; s.close()
srv = subprocess.Popen(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-14b",
     "--smoke", "--fleet", "3", "--router-port", str(port),
     "--prompt-len", "16", "--gen", "24", "--drain-timeout-s", "10"],
    stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)


def get_json(path):
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=5)
    try:
        c.request("GET", path)
        r = c.getresponse()
        return r.status, json.loads(r.read())
    finally:
        c.close()


try:
    deadline = time.time() + 600   # three parallel model builds
    while True:
        assert time.time() < deadline, "fleet never became healthy"
        assert srv.poll() is None, "router died during startup"
        try:
            status, fz = get_json("/fleetz")
            if status == 200 and all(
                    r["state"] == "healthy" for r in fz["replicas"]):
                break
        except OSError:
            pass
        time.sleep(0.5)

    GEN = 24
    prompts = {"a": list(range(1, 17)), "b": list(range(21, 37))}

    def stream(prompt, out, kill_at=None):
        body = json.dumps({"prompt": prompt, "max_new": GEN})
        c = http.client.HTTPConnection("127.0.0.1", port, timeout=300)
        try:
            c.request("POST", "/v1/generate", body,
                      {"Content-Type": "application/json"})
            r = c.getresponse()
            assert r.status == 200, r.status
            ev = None
            for raw in r.fp:
                line = raw.decode("utf-8", "replace").rstrip("\n")
                if line.startswith("event: "):
                    ev = line[len("event: "):]
                elif line.startswith("data: ") and ev == "token":
                    out.append(json.loads(line[len("data: "):])["token"])
                    if kill_at is not None and len(out) == 2:
                        kill_at()
        finally:
            c.close()

    # unkilled references through the fleet (same weights everywhere)
    refs = {}
    for k, p in prompts.items():
        refs[k] = []
        stream(p, refs[k])
        assert len(refs[k]) == GEN, (k, len(refs[k]))

    # the sticky-affinity target of prompt A is the replica that will be
    # serving it — that one takes the kill -9, mid-stream
    victim = rendezvous_rank(prefix_key(prompts["a"]), 3)[0]
    pid = next(r["pid"] for r in get_json("/fleetz")[1]["replicas"]
               if r["index"] == victim)
    killed = []

    def kill_victim():
        os.kill(pid, signal.SIGKILL)
        killed.append(pid)

    got = {"a": [], "b": []}
    ta = threading.Thread(target=stream,
                          args=(prompts["a"], got["a"], kill_victim))
    tb = threading.Thread(target=stream, args=(prompts["b"], got["b"]))
    ta.start(); tb.start()
    ta.join(180); tb.join(180)
    assert killed, "kill never fired"
    for k in ("a", "b"):
        assert got[k] == refs[k], (
            f"stream {k} diverged after replica kill: "
            f"{got[k][:6]}... vs {refs[k][:6]}...")
    _, fz = get_json("/fleetz")
    assert fz["router"]["failovers"] >= 1, fz["router"]
    assert fz["journal"]["live"] == 0, fz["journal"]

    srv.send_signal(signal.SIGTERM)   # coordinated fleet drain
    out, _ = srv.communicate(timeout=180)
    print(out)
    assert srv.returncode == 0, f"exit {srv.returncode}"
    assert "fleet drain[sigterm]" in out
    assert "fleet leak gates: clean on every drained replica" in out
    print(f"[ci] fleet chaos smoke OK (killed replica {victim} "
          f"pid {pid} mid-stream; streams token-identical)")
finally:
    if srv.poll() is None:
        srv.kill()
PY

# tensor-parallel serving (serve/distributed.py) on a forced multi-device
# CPU host: the full distributed test file, then a 2-way model-parallel
# serve that must be token-identical to the single-device oracle
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  python -m pytest -q tests/test_distributed.py

XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --paged-prefill \
  --prefix-cache --mesh 1,2 --check

# TP speculative decode under shard_map: still token-identical
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  python -m repro.launch.serve --arch qwen3-14b --smoke \
  --requests 4 --prompt-len 16 --gen 8 --paged --paged-prefill \
  --speculative 4 --mesh 1,2 --check

# keep the PR-over-PR serving baseline on the unchanged workload (now
# with --trace: engine-native percentiles are cross-checked against the
# external computation and the span phase breakdown lands in the
# record); the prefix-heavy batched-prefill run is a separate labeled
# record
PYTHONPATH=src python benchmarks/serving_load.py --smoke --requests 8 \
  --paged --trace --out "$tmp/BENCH_serving.json"
PYTHONPATH=src python benchmarks/serving_load.py --smoke --requests 8 \
  --paged --paged-prefill --prefix-cache --prefix-len 16 \
  --out "$tmp/BENCH_serving_prefix.json"
# tail latency under cancellation churn: seeded mid-run cancels, p99
# measured over the surviving requests (cancelled/failed counts in the
# record; the run itself asserts telemetry/external agreement)
PYTHONPATH=src python benchmarks/serving_load.py --smoke --requests 8 \
  --paged --cancel-rate 0.25 --deadline-s 60 \
  --out "$tmp/BENCH_serving_cancel.json"
# over-the-wire baseline: the same open-loop workload through the HTTP/
# SSE front door, client-side ttft/itl, plus a mid-run overload volley
# that must shed (429/413) rather than crash, and a leak-gated drain
PYTHONPATH=src python benchmarks/serving_load.py --smoke --requests 8 \
  --paged --http --max-queue 8 --overload-burst 8 \
  --out "$tmp/BENCH_serving_http.json"
# replica-fleet record: two replica subprocesses behind the router,
# SIGKILL the busiest one mid-run — fails unless every admitted stream
# still completed and every drained replica's leak gate was clean
PYTHONPATH=src python benchmarks/serving_load.py --smoke --requests 10 \
  --rate 6 --gen 12 --http --fleet 2 --kill-mid-run \
  --out "$tmp/BENCH_fleet.json"
PYTHONPATH=src python benchmarks/decode_microbench.py --smoke --reps 5 \
  --out "$tmp/BENCH_decode.json"
# speculative draft-and-verify vs one-token decode (repetitive + random
# workloads; asserts token identity internally)
PYTHONPATH=src python benchmarks/speculative_microbench.py --smoke \
  --out "$tmp/BENCH_speculative.json"
PYTHONPATH=src python benchmarks/prefill_microbench.py --smoke \
  --requests 1 4 --reps 2 --out "$tmp/BENCH_prefill.json"
# TP scaling record (token parity + per-device pool bytes ≈ 1/mp)
PYTHONPATH=src python benchmarks/serving_tp.py --smoke --requests 6 \
  --out "$tmp/BENCH_tp.json"

echo "[ci] OK"
