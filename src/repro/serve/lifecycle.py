"""Engine drivers: the loops that decide WHEN to tick.

The engine itself owns no loop — :meth:`Engine.tick` is a pure unit of
work and the lifecycle API (``idle``, ``next_arrival``, ``cancel_all``)
exposes the predicates a driver needs (DESIGN.md §14).  This module
holds the in-process driver:

- :func:`run_to_completion` — the classic blocking drive used by the
  CLI and benchmarks: tick until every submitted request is terminal,
  sleeping across virtual-arrival gaps, with a runaway-loop backstop
  and optional periodic metrics/canary emission.

The asynchronous driver lives in :mod:`repro.serve.frontdoor.server`,
where the tick loop shares an event loop with HTTP/SSE I/O.  One level
up, :mod:`repro.serve.fleet` drives N such servers as a supervised
replica fleet — each replica still runs this same tick contract, which
is what makes crash failover resumable (any replica can replay
prompt + emitted tokens and continue the stream token-identically).
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.serve.engine import Engine
    from repro.serve.scheduler import Request

__all__ = ["run_to_completion"]


def run_to_completion(
    engine: "Engine",
    max_steps: Optional[int] = None,
    metrics_every: Optional[float] = None,
) -> list["Request"]:
    """Drive ``engine`` until every submitted request is finished.

    ``max_steps`` bounds ticks that DID work (a runaway-loop backstop);
    idle iterations waiting on future arrivals don't consume it — an
    open-loop workload may spend arbitrarily long between arrivals.
    ``metrics_every`` (seconds) emits a one-line metrics snapshot to
    stderr at that period while the loop runs.
    """
    sch = engine.scheduler
    todo = sch.pending + len(engine.running)
    budget_tokens = sum(
        r.max_new + len(r.prefix)
        for r in (*sch.waiting, *sch.queue, *engine.running)
    )
    max_steps = max_steps or 1000 + 20 * budget_tokens
    done0 = len(engine.finished)
    worked_steps = stalls = 0
    next_metrics = (
        engine.now() + metrics_every if metrics_every else float("inf")
    )
    # canary cadence mirrors next_metrics, plus one immediate probe so
    # the gauge exists from tick zero (short smoke runs still canary)
    canary_on = (
        engine.ecfg.canary_every is not None
        and engine.canary_tokens is not None
    )
    if canary_on:
        engine._run_canary()
    next_canary = (
        engine.now() + engine.ecfg.canary_every if canary_on else float("inf")
    )
    while not engine.idle:
        if engine.tick().worked:
            worked_steps, stalls = worked_steps + 1, 0
            if worked_steps > max_steps:
                raise RuntimeError(
                    f"engine did not drain in {max_steps} working steps"
                )
        else:
            arrival = engine.next_arrival()
            if arrival is not None:
                # idle until the next virtual arrival
                time.sleep(max(0.0, min(0.01, arrival - engine.now())))
            else:
                stalls += 1  # arrived work exists but nothing progressed
                if stalls > 10_000:
                    raise RuntimeError(
                        "engine stalled: pending requests but no step "
                        "makes progress (pool misconfigured?)"
                    )
        if engine.now() >= next_metrics:
            engine._emit_metrics_snapshot()
            next_metrics = engine.now() + metrics_every
        if engine.now() >= next_canary:
            engine._run_canary()
            next_canary = engine.now() + engine.ecfg.canary_every
    assert len(engine.finished) - done0 == todo
    return engine.finished[done0:]
