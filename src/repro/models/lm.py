"""Unified Model facade: one API over all assigned families.

    model = build_model(cfg)
    params = model.init(rng)                  # concrete (smoke tests)
    aparams = model.abstract_params(rng)      # ShapeDtypeStructs (dry-run)
    hidden, aux = model.forward(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, tokens, cache, pos)

``batch`` is a dict: {"tokens"} for LM families, plus {"frames"} (encdec) or
{"patches"} (vlm) stub embeddings per the assignment.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import multimodal as MM
from repro.models import recurrent as R
from repro.models import transformer as T

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    _init: Callable
    _axes: Callable
    _forward: Callable
    _prefill: Callable
    _decode: Callable
    _init_cache: Callable
    _cache_axes: Callable

    # ---- params ----
    def init(self, rng: jax.Array):
        return self._init(rng, self.cfg)

    def abstract_params(self, rng: Optional[jax.Array] = None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self._init(k, self.cfg), rng)

    def param_axes(self):
        return self._axes(self.cfg)

    # ---- compute ----
    def forward(self, params, batch: dict):
        """-> (hidden (B, S, D), aux_loss)."""
        return self._forward(params, batch, self.cfg)

    def logits(self, params, hidden):
        return L.lm_logits(params["embed"], hidden)

    def loss(self, params, batch: dict, aux_coef: float = 0.01):
        """Mean next-token cross entropy (+ MoE aux)."""
        hidden, aux = self.forward(params, batch)
        targets = batch.get("targets")
        if targets is None:
            targets = jnp.roll(batch["tokens"], -1, axis=-1)
        logits = self.logits(params, hidden).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        # ignore the final position (no next token)
        mask = jnp.ones_like(nll).at[:, -1].set(0.0)
        ce = jnp.sum(nll * mask) / jnp.sum(mask)
        return ce + aux_coef * aux, {"ce": ce, "aux": aux}

    def prefill(self, params, batch: dict, kv_dtype=None, max_len=None):
        return self._prefill(params, batch, self.cfg, kv_dtype, max_len)

    def decode_step(self, params, tokens, cache, pos):
        return self._decode(params, tokens, self.cfg, cache, pos)

    def init_cache(self, batch: int, max_len: int, kv_dtype=None):
        return self._init_cache(self.cfg, batch, max_len, kv_dtype)

    def cache_axes(self, int8: bool = False):
        return self._cache_axes(self.cfg, int8)

    def abstract_cache(self, batch: int, max_len: int, kv_dtype=None):
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, max_len, kv_dtype)
        )


# --- family adapters (normalize batch-dict vs tokens-only signatures) ----


def _tok_fwd(fn):
    def wrapped(params, batch, cfg):
        return fn(params, batch["tokens"], cfg)

    return wrapped


def _tok_prefill(fn):
    def wrapped(params, batch, cfg, kv_dtype, max_len=None):
        return fn(params, batch["tokens"], cfg, kv_dtype, max_len)

    return wrapped


_FAMILIES: dict[str, dict[str, Any]] = {
    "dense": dict(
        init=T.init_decoder, axes=T.decoder_axes,
        forward=_tok_fwd(T.decoder_forward),
        prefill=_tok_prefill(T.decoder_prefill),
        decode=T.decoder_decode_step,
        init_cache=T.init_decoder_cache, cache_axes=T.decoder_cache_axes,
    ),
    "moe": dict(
        init=T.init_decoder, axes=T.decoder_axes,
        forward=_tok_fwd(T.decoder_forward),
        prefill=_tok_prefill(T.decoder_prefill),
        decode=T.decoder_decode_step,
        init_cache=T.init_decoder_cache, cache_axes=T.decoder_cache_axes,
    ),
    "rwkv": dict(
        init=R.init_rwkv_lm, axes=R.rwkv_lm_axes,
        forward=_tok_fwd(R.rwkv_forward),
        prefill=_tok_prefill(R.rwkv_prefill),
        decode=R.rwkv_decode_step,
        init_cache=R.init_rwkv_cache, cache_axes=R.rwkv_cache_axes,
    ),
    "hybrid": dict(
        init=R.init_hybrid, axes=R.hybrid_axes,
        forward=_tok_fwd(R.hybrid_forward),
        prefill=_tok_prefill(R.hybrid_prefill),
        decode=R.hybrid_decode_step,
        init_cache=R.init_hybrid_cache, cache_axes=R.hybrid_cache_axes,
    ),
    "encdec": dict(
        init=MM.init_encdec, axes=MM.encdec_axes,
        forward=MM.encdec_forward,
        prefill=MM.encdec_prefill,
        decode=MM.encdec_decode_step,
        init_cache=MM.init_encdec_cache, cache_axes=MM.encdec_cache_axes,
    ),
    "vlm": dict(
        init=MM.init_vlm, axes=MM.vlm_axes,
        forward=MM.vlm_forward,
        prefill=MM.vlm_prefill,
        decode=MM.vlm_decode_step,
        init_cache=MM.init_vlm_cache, cache_axes=MM.vlm_cache_axes,
    ),
}


def build_model(cfg: ArchConfig) -> Model:
    fam = _FAMILIES[cfg.family]
    return Model(
        cfg=cfg,
        _init=lambda k, c: fam["init"](k, c),
        _axes=lambda c: fam["axes"](c),
        _forward=fam["forward"],
        _prefill=fam["prefill"],
        _decode=fam["decode"],
        _init_cache=fam["init_cache"],
        _cache_axes=fam["cache_axes"],
    )
