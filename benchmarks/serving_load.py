"""Serving load benchmark: Poisson arrivals against the batching engine.

    PYTHONPATH=src:benchmarks python benchmarks/serving_load.py --smoke

Synthetic open-loop workload: request arrival times are drawn from a
Poisson process (``--rate`` req/s), prompt lengths jittered around
``--prompt-len``.  Reports throughput (tok/s), time-to-first-token and
inter-token latency percentiles (p50/p99), and peak KV-page occupancy —
the numbers that matter for a continuous-batching deployment.  The record
is written to ``BENCH_serving.json`` (``--out``) so perf regressions are
visible PR-over-PR.  ``--paged`` decodes in place over the page pool
(paged-attention path); ``--paged-prefill`` batches each tick's prefill
chunks into one fused cross-request dispatch; ``--kv-int8`` stores int8
KV pages.  ``--prefix-len N`` switches to a prefix-heavy workload: every
prompt opens with the same N-token header (system prompt / few-shot
block), which ``--prefix-cache`` then serves from cached pages instead of
recomputing (``prefix_hit_tokens`` in the record).  ``--speculative K``
(with ``--paged``) turns decode ticks into draft-and-verify ticks; the
record then carries acceptance_rate / accepted_per_tick /
tokens_per_lane_tick so drafting health is tracked alongside latency.
``--trace`` records per-tick spans during the measured run and adds the
span-derived per-phase time breakdown (+ coverage) to the record;
``--trace-out PATH`` also writes the Chrome/Perfetto trace JSON.

``--cancel-rate F`` cancels a seeded fraction of the measured requests at
deterministic ticks mid-run (engine fault plan, serve/faults.py) and
``--deadline-s`` arms per-request wall-clock deadlines — the record then
carries cancelled / failed / deadline_missed counts, and the ttft/itl p99
columns measure tail latency UNDER cancellation churn: surviving requests
pay for the page releases and batch-shape changes the cancels cause.

``--http`` runs the same open-loop workload OVER THE WIRE through the
streaming front door (``serve/frontdoor``): the engine lives behind an
asyncio HTTP/SSE server and every client measures latency at its own
socket, so ttft/itl include HTTP framing, the tick loop, and scheduling.
``--overload-burst N`` fires a synchronized mid-run volley and records
the 200/429/413 admission split plus degradation-ladder transitions —
the record is written to ``BENCH_serving_http.json`` by convention, and
the run fails if the graceful drain leaks a single KV page.

``--fleet N`` (with ``--http``) serves the same workload through N
data-parallel replica SUBPROCESSES behind the supervised fleet router
(``serve/fleet``): the record gains per-replica balance, the affinity
hit rate, and failover counts, and is written to ``BENCH_fleet.json``.
``--kill-mid-run`` SIGKILLs the busiest replica at the workload
midpoint — the ttft/itl percentiles then measure client-visible tail
latency UNDER crash failover (the router resubmits each orphaned
stream's prompt + journaled tokens to a survivor and splices the
continuation), and the run fails unless every admitted stream still
completed and every drained replica's leak gate was clean.

Latency percentiles (in-process mode) come from the engine's OWN
lifecycle histograms
(``Engine.summary()``), asserted equal to an external recomputation from
raw request timestamps — the benchmark cross-checks the telemetry it
reports.  Both observe FINISHED requests only: a cancelled request's
partial stream is not a latency sample.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_smoke_config
from repro.core.quantizer import QuipConfig
from repro.data import make_calibration
from repro.models import build_model
from repro.serve import CachedDecoder, Engine, EngineConfig


def pctl(xs, q):
    """Percentile of ``xs``, or None when empty — None stays valid JSON
    (NaN does not survive strict parsers) and sorts honestly as "no
    samples" instead of a poisoned number."""
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else None


def rnd(x, n):
    return None if x is None else round(x, n)


def _sse_events(resp):
    """Incrementally parse SSE frames off a live ``http.client`` response,
    yielding ``(t_received, event, payload)`` per complete frame."""
    ev, data = None, None
    for raw in resp.fp:
        line = raw.decode("utf-8", "replace").rstrip("\n")
        if line.startswith("event: "):
            ev = line[len("event: "):]
        elif line.startswith("data: "):
            data = line[len("data: "):]
        elif not line and ev is not None:
            yield time.perf_counter(), ev, json.loads(data)
            ev, data = None, None


def _measured_client(port, prompt_tokens, gen, t0, arrival):
    """One open-loop client: sleep to its arrival time, POST a streaming
    generate, and timestamp every SSE frame at the socket.  Transport
    failures (a dropped stream the router could not rescue) come back as
    status 0 so they count as incomplete, never as a crash of the
    benchmark itself."""
    import http.client

    t_due = t0 + float(arrival)
    delay = t_due - time.perf_counter()
    if delay > 0:
        time.sleep(delay)
    body = json.dumps({
        "prompt": [int(t) for t in prompt_tokens],
        "max_new": gen,
        "stream": True,
    })
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=300)
    try:
        c.request("POST", "/v1/generate", body,
                  {"Content-Type": "application/json"})
        r = c.getresponse()
        if r.status != 200:
            r.read()
            return {"status": r.status}
        token_times, done = [], None
        for t_ev, ev, payload in _sse_events(r):
            if ev == "token":
                token_times.append(t_ev)
            elif ev == "done":
                done = payload
        return {"status": 200, "t_due": t_due,
                "token_times": token_times, "done": done}
    except (ConnectionError, OSError, http.client.HTTPException):
        return {"status": 0, "done": None, "token_times": []}
    finally:
        c.close()


def run_fleet(args, cfg, prompts, lengths, arrivals):
    """N-replica fleet run: real ``launch/serve.py`` subprocesses behind
    the supervised router (serve/fleet); this process plays the clients
    AND — with ``--kill-mid-run`` — the chaos monkey.  Latency is
    measured at the client socket THROUGH the router, so a mid-run
    SIGKILL's failover splice shows up exactly where a user would feel
    it: one stretched inter-token gap, then the stream finishes."""
    import os
    import signal
    import threading

    from repro.serve.fleet import (
        FleetRouter,
        ProcessReplicaFactory,
        Supervisor,
    )

    # replica children import repro from source; make sure the tree is
    # on their path however this script itself was launched
    src = os.path.abspath("src")
    env_pp = os.environ.get("PYTHONPATH", "")
    if src not in env_pp.split(os.pathsep):
        os.environ["PYTHONPATH"] = (
            src + (os.pathsep + env_pp if env_pp else ""))

    replica_argv = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--smoke",
        # identical weights on every replica (same seed): failover
        # splices must be token-identical across incarnations
        "--seed", str(args.seed),
        "--prompt-len", str(args.prompt_len),
        "--gen", str(args.gen),
        "--slots", str(args.slots),
        "--page-size", str(args.page_size),
        "--token-budget", str(args.token_budget),
        "--prefill-chunk", str(args.prefill_chunk),
        "--drain-timeout-s", str(args.drain_timeout_s),
    ]
    if args.pages is not None:
        replica_argv += ["--pages", str(args.pages)]
    for flag, on in (("--paged", args.paged),
                     ("--paged-prefill", args.paged_prefill),
                     ("--prefix-cache", args.prefix_cache),
                     ("--kv-int8", args.kv_int8),
                     ("--host-sample", args.host_sample),
                     ("--quantize", args.quantize)):
        if on:
            replica_argv.append(flag)
    if args.quantize:
        replica_argv += ["--bits", str(args.bits)]

    factory = ProcessReplicaFactory(replica_argv)
    sup = Supervisor(factory, args.fleet, probe_interval_s=0.25,
                     start_timeout_s=600.0,
                     replica_drain_timeout_s=args.drain_timeout_s + 30.0)
    router = FleetRouter(sup, port=0,
                         drain_timeout_s=args.drain_timeout_s)
    router.start_in_thread()

    # warm EVERY replica's jit caches directly at its own port (prefix
    # affinity would funnel a router-side warm-up to one replica), so
    # compile time stays out of the measured ttft
    import http.client
    for h in sup.handles:
        c = http.client.HTTPConnection("127.0.0.1", h.port, timeout=300)
        try:
            c.request("POST", "/v1/generate", json.dumps({
                "prompt": [int(t) for t in prompts[0][:8]],
                "max_new": 2, "stream": False,
            }), {"Content-Type": "application/json"})
            c.getresponse().read()
        finally:
            c.close()

    results = [None] * args.requests
    t0 = time.perf_counter()

    def client(i):
        results[i] = _measured_client(
            router.port, prompts[i][: lengths[i]], args.gen, t0,
            arrivals[i])

    threads = [threading.Thread(target=client, args=(i,), daemon=True)
               for i in range(args.requests)]
    kill_info = {}
    if args.kill_mid_run:
        def killer():
            delay = (t0 + float(arrivals[len(arrivals) // 2])
                     - time.perf_counter())
            if delay > 0:
                time.sleep(delay)
            # the busiest healthy replica takes the SIGKILL: its live
            # streams are exactly the ones failover must rescue.  Wait
            # (bounded) for a replica to actually BE mid-stream first —
            # Poisson arrivals can cluster past the nominal midpoint,
            # and killing an idle replica exercises nothing
            deadline = time.perf_counter() + 30.0
            h = None
            while time.perf_counter() < deadline:
                busy = sorted(
                    (x for x in sup.handles
                     if x.state == "healthy" and x.inflight > 0),
                    key=lambda x: (-x.inflight, x.index))
                if busy:
                    h = busy[0]
                    break
                time.sleep(0.01)
            if h is None:  # workload already over: kill any survivor
                h = next(x for x in sup.handles if x.state == "healthy")
            kill_info.update(replica=h.index, pid=h.pid,
                             inflight_at_kill=h.inflight,
                             t_kill_s=round(time.perf_counter() - t0, 3))
            os.kill(h.pid, signal.SIGKILL)

        threads.append(threading.Thread(target=killer, daemon=True))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

    if kill_info:
        # let the killed slot finish its respawn before draining: the
        # record then shows the recovery, and the fresh incarnation's
        # leak gate is actually read (a replica still mid-model-build
        # has no gate yet and drains as None)
        h = sup.handles[kill_info["replica"]]
        deadline = time.perf_counter() + 180.0
        while time.perf_counter() < deadline and h.state != "healthy":
            time.sleep(0.25)
        kill_info["recovered"] = h.state == "healthy"
        kill_info["restarts"] = h.restarts

    counters = dict(router.counters)
    per_replica = [h.to_dict() for h in sup.handles]
    report = router.drain_and_join(reason="bench_complete")

    ok = [r for r in results if r and r["status"] == 200 and r["done"]]
    ttft = [r["token_times"][0] - r["t_due"]
            for r in ok if r["token_times"]]
    itl = [b - a for r in ok
           for a, b in zip(r["token_times"], r["token_times"][1:])]
    statuses = [r["status"] for r in results if r]
    total = sum(r["done"]["n_tokens"] for r in ok)
    hits = counters["affinity_hits"]
    fallbacks = counters["affinity_fallbacks"]
    rec = {
        "label": ("quip-%db" % args.bits) if args.quantize else "fp",
        "arch": cfg.name,
        "mode": "fleet",
        "transport": "http-sse",
        "decode_path": "paged" if args.paged else "gather-dense",
        "replicas": args.fleet,
        "requests": args.requests,
        "rate_req_s": args.rate,
        "kill_mid_run": bool(args.kill_mid_run),
        "kill": kill_info or None,
        "wall_s": round(wall, 3),
        "tok_s": round(total / wall, 2),
        # CLIENT-side percentiles through the router; with a mid-run
        # kill these ARE the tail-under-crash-failover figures
        "ttft_p50_s": rnd(pctl(ttft, 50), 4),
        "ttft_p99_s": rnd(pctl(ttft, 99), 4),
        "itl_p50_s": rnd(pctl(itl, 50), 4),
        "itl_p99_s": rnd(pctl(itl, 99), 4),
        "itl_max_s": rnd(max(itl), 4) if itl else None,
        "http_200": statuses.count(200),
        "http_503": statuses.count(503),
        "http_other": len([s for s in statuses if s not in (200, 503)]),
        "incomplete": args.requests - len(ok),
        "failovers": counters["failovers"],
        "failover_exhausted": counters["failover_exhausted"],
        "affinity_hit_rate": round(hits / max(1, hits + fallbacks), 3),
        "affinity_hits": hits,
        "affinity_fallbacks": fallbacks,
        "per_replica_served": [r["served"] for r in per_replica],
        "per_replica_routed": [r["routed"] for r in per_replica],
        "restarts": sum(r["restarts"] for r in per_replica),
        "completed": report.completed,
        "failed": report.failed,
        "aborted_streams": report.aborted_streams,
        "drain_clean": report.clean,
        "replica_exit_codes": [r["exit_code"] for r in report.replicas],
    }
    return rec


def run_http(args, cfg, engine, prompts, lengths, arrivals):
    """Over-the-wire run: the front door owns the engine; this process
    plays the clients.  Latency is measured where the user feels it —
    at the socket — so ttft/itl here include HTTP framing, the asyncio
    tick loop, and scheduling, on top of the engine's own numbers."""
    import http.client
    import threading

    from repro.serve.frontdoor import FrontDoor

    engine.reset_clock()
    engine.reset_stats()
    fd = FrontDoor(engine, drain_timeout_s=args.drain_timeout_s)
    fd.start_in_thread()
    results = [None] * args.requests
    t0 = time.perf_counter()

    def client(i):
        results[i] = _measured_client(
            fd.port, prompts[i][: lengths[i]], args.gen, t0, arrivals[i])

    threads = [threading.Thread(target=client, args=(i,), daemon=True)
               for i in range(args.requests)]
    for t in threads:
        t.start()

    burst_statuses = []
    if args.overload_burst:
        # overload probe: a synchronized volley mid-run — every response
        # must be a typed verdict (200 admitted, 429/413 shed), never a
        # connection error
        mid = float(arrivals[len(arrivals) // 2])
        lock = threading.Lock()

        def burst():
            delay = t0 + mid - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            body = json.dumps({
                "prompt": [int(t) for t in prompts[0][:8]],
                "max_new": 2, "stream": False, "tenant": "burst",
            })
            c = http.client.HTTPConnection(
                "127.0.0.1", fd.port, timeout=300)
            try:
                c.request("POST", "/v1/generate", body,
                          {"Content-Type": "application/json"})
                r = c.getresponse()
                r.read()
                with lock:
                    burst_statuses.append(r.status)
            finally:
                c.close()

        bts = [threading.Thread(target=burst, daemon=True)
               for _ in range(args.overload_burst)]
        for t in bts:
            t.start()
        threads += bts

    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    report = fd.drain_and_join(reason="bench_complete")

    ok = [r for r in results if r and r["status"] == 200 and r["done"]]
    ttft = [r["token_times"][0] - r["t_due"]
            for r in ok if r["token_times"]]
    itl = [b - a for r in ok
           for a, b in zip(r["token_times"], r["token_times"][1:])]
    statuses = [r["status"] for r in results if r] + burst_statuses
    total = sum(r["done"]["n_tokens"] for r in ok)
    m = engine.metrics

    def count(name):
        return m.counter(name).value

    rec = {
        "label": ("quip-%db" % args.bits) if args.quantize else "fp",
        "arch": cfg.name,
        "mode": "http",
        "transport": "http-sse",
        "decode_path": "paged" if args.paged else "gather-dense",
        "requests": args.requests,
        "rate_req_s": args.rate,
        "max_queue": args.max_queue,
        "overload_burst": args.overload_burst,
        "wall_s": round(wall, 3),
        "tok_s": round(total / wall, 2),
        # CLIENT-side percentiles, measured at the socket
        "ttft_p50_s": rnd(pctl(ttft, 50), 4),
        "ttft_p99_s": rnd(pctl(ttft, 99), 4),
        "itl_p50_s": rnd(pctl(itl, 50), 4),
        "itl_p99_s": rnd(pctl(itl, 99), 4),
        "http_200": statuses.count(200),
        "http_429": statuses.count(429),
        "http_413": statuses.count(413),
        "http_other": len([s for s in statuses
                           if s not in (200, 429, 413)]),
        "shed_requests": count("shed_requests"),
        "ladder_escalations": count("ladder_escalations"),
        "ladder_deescalations": count("ladder_deescalations"),
        "client_disconnects": count("client_disconnects"),
        "drain_clean": report.clean,
        "leaked_pages": report.leaked_pages,
        "served_total": report.served_total,
        "peak_kv_pages": engine.pool.peak_pages_in_use,
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=20.0, help="arrivals/s")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages", type=int, default=None)
    ap.add_argument("--token-budget", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--paged", action="store_true",
                    help="decode in place over the page pool (no per-step "
                         "dense KV gather)")
    ap.add_argument("--paged-prefill", action="store_true",
                    help="batch each tick's prefill chunks into one fused "
                         "cross-request dispatch over the page pool")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="map cached prompt-prefix pages on admission "
                         "(refcounted, copy-on-write) instead of "
                         "recomputing them")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="prefix-heavy workload: every prompt opens with "
                         "the same N-token header")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV pages with per-(token, head) scales")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="speculative decode depth (needs --paged): draft "
                         "up to K tokens per lane per tick, verify in one "
                         "fused dispatch")
    ap.add_argument("--draft", default="ngram", choices=("ngram",),
                    help="self-drafter for --speculative")
    ap.add_argument("--host-sample", action="store_true",
                    help="host-side token selection (default on the paged "
                         "path is the fused on-device draw)")
    ap.add_argument("--trace", action="store_true",
                    help="record per-tick spans during the measured run "
                         "and write the span-derived per-phase time "
                         "breakdown (schedule/prefill/decode/verify) "
                         "into the record")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="with --trace: also write the Chrome/Perfetto "
                         "trace-event JSON here")
    ap.add_argument("--cancel-rate", type=float, default=0.0, metavar="F",
                    help="cancel this fraction of the measured requests "
                         "at seeded deterministic ticks mid-run: the "
                         "latency percentiles then measure the tail "
                         "UNDER cancellation churn")
    ap.add_argument("--deadline-s", type=float, default=None, metavar="SECS",
                    help="per-request wall-clock deadline enforced at tick "
                         "boundaries; missed deadlines FAIL the request "
                         "(deadline_missed in the record)")
    ap.add_argument("--http", action="store_true",
                    help="over-the-wire mode: serve through the streaming "
                         "front door (serve/frontdoor) and measure CLIENT-"
                         "side SSE latency — ttft/itl include HTTP framing, "
                         "the asyncio tick loop, and the socket")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission queue (submits past it get "
                         "429 queue_full over HTTP)")
    ap.add_argument("--overload-burst", type=int, default=0, metavar="N",
                    help="with --http: fire N extra concurrent requests "
                         "mid-run and record the 200/429/413 admission "
                         "split — overload must shed, never crash")
    ap.add_argument("--fleet", type=int, default=None, metavar="N",
                    help="with --http: serve through N data-parallel "
                         "replica SUBPROCESSES behind the supervised "
                         "fleet router (serve/fleet) instead of one in-"
                         "process front door; the record gains per-"
                         "replica balance, affinity hit rate and "
                         "failover counts (BENCH_fleet.json by default)")
    ap.add_argument("--kill-mid-run", action="store_true",
                    help="with --fleet: SIGKILL the busiest replica at "
                         "the workload midpoint — ttft/itl then measure "
                         "the tail UNDER crash failover, and the record "
                         "carries failovers / restarts / recovery")
    ap.add_argument("--drain-timeout-s", type=float, default=10.0,
                    help="with --http: graceful-drain budget at shutdown")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    if args.speculative and not args.paged:
        ap.error("--speculative verifies drafts over the paged pool; "
                 "add --paged")
    if not 0.0 <= args.cancel_rate <= 1.0:
        ap.error("--cancel-rate is a fraction in [0, 1]")
    if args.http and (args.cancel_rate or args.trace):
        ap.error("--http measures the wire path; --cancel-rate/--trace "
                 "are in-process-run features")
    if args.overload_burst and not args.http:
        ap.error("--overload-burst needs --http")
    if args.fleet is not None:
        if not args.http:
            ap.error("--fleet serves over the wire; add --http")
        if args.fleet < 1:
            ap.error("--fleet needs >= 1 replica")
        if args.overload_burst:
            ap.error("--overload-burst probes the single front door's "
                     "admission ladder; drop --fleet")
        if args.kill_mid_run and args.fleet < 2:
            ap.error("--kill-mid-run needs >= 2 replicas to fail over to")
        if args.out == "BENCH_serving.json":
            args.out = "BENCH_fleet.json"
    elif args.kill_mid_run:
        ap.error("--kill-mid-run kills a fleet replica; add --fleet N")

    cfg = get_smoke_config(args.arch)
    if not args.smoke:
        print("[serving_load] full-scale arch on CPU is impractical; "
              "using the smoke config (pass --smoke to silence this)")
    if args.fleet is not None:
        # fleet parent never builds a model — each replica subprocess
        # builds its own (same seed, identical weights); this process
        # only generates the workload and plays the clients
        rng = np.random.default_rng(args.seed)
        arrivals = np.cumsum(
            rng.exponential(1.0 / args.rate, args.requests))
        prompts = make_calibration(
            cfg.vocab, n_segments=args.requests, seg_len=args.prompt_len,
            seed=args.seed + 3,
        ).tokens
        lengths = rng.integers(
            max(4, args.prompt_len // 2), args.prompt_len + 1,
            args.requests)
        if args.prefix_len:
            header = prompts[0][: min(args.prefix_len,
                                      args.prompt_len - 1)]
            lengths = np.maximum(lengths, len(header) + 1)
            prompts = np.concatenate(
                [np.tile(header, (args.requests, 1)),
                 prompts[:, len(header):]], axis=1)
        rec = run_fleet(args, cfg, prompts, lengths, arrivals)
        print(json.dumps(rec, indent=1))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(rec, f)
        # the run fails if any leak gate tripped or any admitted
        # stream was lost (failover exists precisely so it isn't)
        return 0 if rec["drain_clean"] and rec["incomplete"] == 0 else 1
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.quantize:
        from repro.launch.quantize import quantize_dense_model

        calib = make_calibration(cfg.vocab, n_segments=8, seg_len=64,
                                 seed=args.seed + 7)
        adapter = CachedDecoder.from_quantized(quantize_dense_model(
            params, cfg,
            QuipConfig(bits=args.bits, method="ldlq", use_kernel=False),
            calib.tokens, seed=args.seed, verbose=False,
        ))
    else:
        adapter = CachedDecoder.from_model(model, params)

    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    prompts = make_calibration(
        cfg.vocab, n_segments=args.requests, seg_len=args.prompt_len,
        seed=args.seed + 3,
    ).tokens

    engine = Engine(adapter, EngineConfig(
        max_seq_len=args.prompt_len + args.gen,
        n_slots=args.slots,
        page_size=args.page_size,
        n_pages=args.pages,
        token_budget=args.token_budget,
        prefill_chunk=args.prefill_chunk,
        paged_decode=args.paged,
        paged_prefill=args.paged_prefill,
        prefix_cache=args.prefix_cache,
        kv_int8=args.kv_int8,
        speculative_k=args.speculative,
        draft=args.draft,
        device_sample=args.paged and not args.host_sample,
        deadline_s=args.deadline_s,
        max_queue=args.max_queue,
    ))
    # warm the jit caches so compile time doesn't pollute latency stats
    warm = engine.submit(np.asarray(prompts[0]), max_new=2, arrival=0.0)
    engine.run()
    assert warm.done

    # jitter prompt lengths so prefill chunking/page claims are ragged
    lengths = rng.integers(
        max(4, args.prompt_len // 2), args.prompt_len + 1, args.requests
    )
    if args.prefix_len:
        # prefix-heavy workload: one shared header, per-request tails
        header = prompts[0][: min(args.prefix_len, args.prompt_len - 1)]
        lengths = np.maximum(lengths, len(header) + 1)
        prompts = np.concatenate(
            [np.tile(header, (args.requests, 1)), prompts[:, len(header):]],
            axis=1,
        )
    if args.http:
        rec = run_http(args, cfg, engine, prompts, lengths, arrivals)
        print(json.dumps(rec, indent=1))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(rec, f)
        return 0

    reqs = [
        engine.submit(np.asarray(prompts[i][: lengths[i]]), max_new=args.gen,
                      arrival=float(arrivals[i]))
        for i in range(args.requests)
    ]
    if args.cancel_rate:
        # seeded cancellation schedule: rids exist only after submission,
        # so the rules are armed on the engine's (inert) default plan
        from repro.serve.faults import FaultRule

        n_cancel = int(round(args.cancel_rate * args.requests))
        victims = rng.choice(args.requests, size=n_cancel, replace=False)
        for v in sorted(int(v) for v in victims):
            engine.faults.rules.append(FaultRule(
                kind="cancel", rid=reqs[v].rid,
                # steps counter restarts at 0 with reset_stats below, so
                # these ticks land inside the measured run
                tick=int(rng.integers(1, 2 * args.gen)),
            ))
    tracer = None
    if args.trace:  # attach AFTER warm-up: the trace covers only the
        from repro.serve import Tracer  # measured run, not compilation

        tracer = Tracer()
        engine.attach_tracer(tracer)
    engine.reset_clock()  # compile time and warm-up stats stay out of
    engine.reset_stats()  # the measured run
    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0

    # external latency computation from raw request timestamps — the
    # engine's own histograms (summary()'s ttft_s_*/itl_s_*) observe the
    # SAME (arrival, t_first, token_times) data at finish, so the two
    # must agree to float tolerance (checked below)
    from repro.serve import RequestState

    fin = [r for r in done if r.state is RequestState.FINISHED]
    ttft = [r.t_first - r.arrival for r in fin]
    itl = [
        b - a
        for r in fin
        for a, b in zip(r.token_times, r.token_times[1:])
    ]
    total = sum(len(r.out_tokens) for r in done)
    s = engine.summary()
    for name, ext in (("ttft_s", ttft), ("itl_s", itl)):
        for q in (50, 99):
            eng_v, ext_v = s[f"{name}_p{q}"], pctl(ext, q)
            if (eng_v is None) != (ext_v is None) or (
                eng_v is not None
                and not np.isclose(eng_v, ext_v, rtol=1e-9, atol=1e-9)
            ):
                raise AssertionError(
                    f"engine-native {name}_p{q} {eng_v!r} diverged from "
                    f"the external computation {ext_v!r}"
                )
    rec = {
        "label": ("quip-%db" % args.bits) if args.quantize else "fp",
        "arch": cfg.name,
        "decode_path": "paged" if args.paged else "gather-dense",
        "prefill_path": "paged-batch" if args.paged_prefill else "dense-b1",
        "prefix_cache": bool(args.prefix_cache),
        "prefix_len": args.prefix_len,
        "kv_pages": "int8" if args.kv_int8 else "fp",
        "requests": args.requests,
        "rate_req_s": args.rate,
        "wall_s": round(wall, 3),
        "tok_s": round(total / wall, 2),
        # engine-native lifecycle percentiles (summary() histograms);
        # asserted equal to the external computation above
        "ttft_p50_s": rnd(s["ttft_s_p50"], 4),
        "ttft_p99_s": rnd(s["ttft_s_p99"], 4),
        "itl_p50_s": rnd(s["itl_s_p50"], 4),
        "itl_p99_s": rnd(s["itl_s_p99"], 4),
        "queue_p50_s": rnd(s["queue_s_p50"], 4),
        "queue_p99_s": rnd(s["queue_s_p99"], 4),
        "e2e_p50_s": rnd(s["e2e_s_p50"], 4),
        "peak_kv_pages": s["peak_pages_in_use"],
        "peak_kv_occupancy": round(s["peak_occupancy"], 3),
        "evictions": s["evictions"],
        "engine_steps": s["steps"],
        "prefill_batch_size": s["prefill_batch_size"],
        "prefix_hit_tokens": s["prefix_hit_tokens"],
        "cached_pages": s["cached_pages"],
        "shared_pages": s["shared_pages"],
        "max_page_ref": s["max_page_ref"],
        "cow_copies": s["cow_copies"],
        # robustness-under-churn (0 when --cancel-rate/--deadline-s off);
        # with cancel_rate > 0 the ttft/itl p99 above ARE the
        # p99-under-cancellation figures
        "cancel_rate": args.cancel_rate,
        "deadline_s": args.deadline_s,
        "cancelled": s["cancelled"],
        "failed": s["failed"],
        "deadline_missed": s["deadline_missed"],
        # speculative decode health (0 when --speculative is off)
        "speculative_k": args.speculative,
        "acceptance_rate": round(s["acceptance_rate"], 3),
        "accepted_per_tick": round(s["accepted_per_tick"], 3),
        "tokens_per_lane_tick": round(s["tokens_per_lane_tick"], 3),
        "rolled_back_tokens": s["rolled_back_tokens"],
    }
    if tracer is not None:
        from repro.serve import phase_breakdown

        pb = phase_breakdown(tracer.spans)
        rec["trace_spans"] = len(tracer)
        rec["trace_dropped"] = tracer.dropped
        rec["trace_coverage"] = round(pb["coverage"], 3)
        rec["phase_s"] = {
            name: round(p["time_s"], 4)
            for name, p in sorted(pb["phases"].items())
        }
        if args.trace_out:
            tracer.export_chrome_trace(args.trace_out)
    print(json.dumps(rec, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
