"""Architecture config schema + input-shape registry.

One :class:`ArchConfig` covers all assigned families (dense / moe / rwkv /
hybrid / encdec / vlm) via family-specific optional fields.  Each
architecture module in this package exports ``CONFIG`` (the exact assigned
dims) and ``smoke()`` (a reduced same-family variant for CPU smoke tests).

Shapes (assigned): every LM cell is seq_len × global_batch; ``decode_*`` and
``long_*`` lower ``serve_step`` (one token against a seq_len KV/recurrent
state), not ``train_step``.  ``long_500k`` runs only for sub-quadratic
families (ssm / hybrid); the skip for pure full-attention archs is recorded
in DESIGN.md §Arch-applicability and in the roofline table.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "shapes_for"]

Family = Literal["dense", "moe", "rwkv", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    causal: bool = True

    # mlp options
    mlp: Literal["swiglu", "gelu"] = "swiglu"
    mlp_bias: bool = False

    # moe options
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25

    # ssm / hybrid options
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    shared_attn_period: int = 0  # hybrid: shared attn block every k layers

    # rwkv options
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # encdec options
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # vlm options
    cross_every: int = 0  # every k-th layer is a cross-attn layer
    n_patches: int = 1024  # stub image-patch count (frontend stubbed)

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    attn_q_chunk: int = 1024  # query-block size for chunked attention
    attn_bf16_probs: bool = False  # flash-style bf16 exp/probs (§Perf B6)
    weight_bits: int = 0  # 0 = dense bf16; 2/3/4 = QuIP-packed serving path

    # training defaults (overridable by the launcher)
    microbatch: int = 16  # global microbatch per grad-accum step
    remat: Literal["none", "full", "dots"] = "full"

    # which assigned shapes run for this arch (None = family default)
    shape_skips: tuple[str, ...] = ()

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # --- derived ---
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "rwkv":
            blk = 4 * d * d + d * d // 2 + 2 * d * f  # rough
            n_blocks = self.n_layers
        elif self.family == "hybrid":
            di, s = self.d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * s + self.ssm_heads) + di * d
            n_shared = max(1, self.n_layers // max(self.shared_attn_period, 1))
            blk = mamba
            n_blocks = self.n_layers - n_shared
            shared = attn + 3 * d * f
            return v * d * (1 if self.tie_embeddings else 2) + n_blocks * blk + shared
        elif self.family == "moe":
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
            if self.dense_residual:
                ffn += 3 * d * f
            blk = attn + ffn
            n_blocks = self.n_layers
        else:
            n_mlp = 3 if self.mlp == "swiglu" else 2
            blk = attn + n_mlp * d * f
            n_blocks = (
                self.n_enc_layers + self.n_dec_layers
                if self.family == "encdec"
                else self.n_layers
            )
            if self.family == "encdec":
                blk += attn  # decoder cross-attn (rough: count once per layer pair)
            if self.family == "vlm" and self.cross_every:
                pass  # cross layers already inside n_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        return emb + n_blocks * blk

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        ffn_total = self.n_layers * self.n_experts * 3 * d * f
        ffn_active = self.n_layers * self.top_k * 3 * d * f
        return full - ffn_total + ffn_active


def shapes_for(cfg: ArchConfig) -> list[ShapeSpec]:
    """The assigned shapes this arch runs (sub-quadratic gating applied)."""
    out = []
    for s in SHAPES.values():
        if s.name in cfg.shape_skips:
            continue
        if s.name == "long_500k" and cfg.family not in ("rwkv", "hybrid"):
            continue  # needs sub-quadratic attention (DESIGN.md §5)
        out.append(s)
    return out
