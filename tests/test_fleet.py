"""Replica fleet (ISSUE 10): supervised data-parallel FrontDoor
replicas behind the failover router — affinity/journal units, engine
resume-token determinism (the mechanism that makes crash failover
token-identical), the tick-stall watchdog, and router end-to-end:
balanced routing, typed-rejection pass-through, mid-stream failover
splicing (greedy AND device-sampled), replica-unavailable 503s,
supervisor restart with give-up circuit breaker, and coordinated
fleet drain through every replica's leak gate.

Replicas here are in-process: real FrontDoors on daemon threads (the
:class:`ThreadReplicaFactory` implements the supervisor's factory
protocol), with mid-stream death simulated by the ``disconnect``
transport fault — the router sees exactly what a ``kill -9`` produces
(EOF before the done frame).  Real-process crash drills live in
scripts/ci.sh.
"""
from __future__ import annotations

import http.client
import json
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import make_calibration
from repro.models import build_model
from repro.serve import CachedDecoder, Engine, EngineConfig
from repro.serve.faults import parse_fault_plan
from repro.serve.fleet import (
    FleetRouter,
    RequestJournal,
    Supervisor,
    prefix_key,
    rendezvous_rank,
)
from repro.serve.frontdoor import FrontDoor
from repro.serve.scheduler import SamplingParams

GEN = 8
PROMPT_LEN = 8


# ---------------------------------------------------------------------------
# fixtures + helpers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fp_stack():
    cfg = get_smoke_config("qwen3-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = make_calibration(cfg.vocab, n_segments=3, seg_len=PROMPT_LEN,
                               seed=3).tokens
    return cfg, model, params, prompts


def _engine(model, params, *, sampled=False, faults=None, **kw):
    ecfg = dict(max_seq_len=PROMPT_LEN + GEN, n_slots=4, page_size=4,
                token_budget=32, prefill_chunk=8)
    if sampled:
        # the identity guarantee for non-greedy needs the on-device
        # draw (fold_in(seed, emission_index) keys) — the paged path
        ecfg.update(paged_decode=True, device_sample=True)
    ecfg.update(kw)
    return Engine(CachedDecoder.from_model(model, params),
                  EngineConfig(**ecfg), faults=faults)


_SAMPLED = dict(temperature=0.8, top_p=0.9, seed=7)


def _reference(model, params, prompt, *, sampled=False):
    """Uninterrupted single-replica run: the token stream every fleet
    path must reproduce exactly."""
    eng = _engine(model, params, sampled=sampled)
    sp = SamplingParams(**_SAMPLED) if sampled else None
    req = eng.submit(np.asarray(prompt), max_new=GEN, sampling=sp)
    eng.run()
    return [int(t) for t in req.out_tokens]


class ThreadReplicaFactory:
    """The supervisor's factory protocol over in-process replicas: each
    'process' is a fresh engine (same weights) behind a FrontDoor on a
    daemon thread.  ``fault_for(index, generation)`` arms per-
    incarnation chaos, mirroring --replica-fault."""

    def __init__(self, model, params, *, sampled=False, fault_for=None):
        self.model = model
        self.params = params
        self.sampled = sampled
        self.fault_for = fault_for or (lambda i, g: None)
        self.spawns = []

    def spawn(self, handle):
        eng = _engine(self.model, self.params, sampled=self.sampled,
                      faults=self.fault_for(handle.index,
                                            handle.generation))
        fd = FrontDoor(eng, port=0, drain_timeout_s=2.0,
                       tick_stall_s=5.0).start_in_thread()
        handle.proc = fd
        handle.port = fd.port
        handle.generation += 1
        self.spawns.append((handle.index, fd))

    def alive(self, handle):
        return handle.proc is not None and handle.proc._thread.is_alive()

    def kill(self, handle):
        fd = handle.proc
        if fd is not None and fd._thread.is_alive():
            fd.drain_and_join("kill", timeout=30)

    def drain(self, handle, timeout_s):
        fd = handle.proc
        if fd is None:
            return None
        if not fd._thread.is_alive():
            return fd.report.exit_code if fd.report is not None else None
        return fd.drain_and_join("fleet", timeout=timeout_s).exit_code


def _fleet(model, params, n=2, *, sampled=False, fault_for=None,
           max_restarts=3, **router_kw):
    """Boot an n-replica thread fleet behind a router; returns the
    started router (callers drain it)."""
    factory = ThreadReplicaFactory(model, params, sampled=sampled,
                                   fault_for=fault_for)
    sup = Supervisor(factory, n, probe_interval_s=0.1,
                     fail_threshold=2, start_timeout_s=60,
                     max_restarts=max_restarts, backoff_base_s=0.05,
                     backoff_max_s=0.2, replica_drain_timeout_s=30)
    router = FleetRouter(sup, port=0, drain_timeout_s=10,
                         **router_kw)
    return router.start_in_thread()


def _post(port, payload: dict, timeout=60):
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    c.request("POST", "/v1/generate", json.dumps(payload),
              {"Content-Type": "application/json"})
    return c, c.getresponse()


def _get_json(port, path, timeout=10):
    c = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    c.request("GET", path)
    r = c.getresponse()
    body = json.loads(r.read())
    c.close()
    return r.status, body


def _parse_sse(raw: bytes):
    events = []
    for block in raw.decode().strip().split("\n\n"):
        lines = dict(ln.split(": ", 1) for ln in block.split("\n"))
        events.append((lines["event"], json.loads(lines["data"])))
    return events


def _gen_tokens(port, prompt, *, stream=True, **extra):
    payload = {"prompt": [int(t) for t in prompt], "max_new": GEN,
               "stream": stream, **extra}
    c, r = _post(port, payload)
    try:
        assert r.status == 200, (r.status, r.read())
        raw = r.read()
    finally:
        c.close()
    if not stream:
        return json.loads(raw)["tokens"]
    events = _parse_sse(raw)
    toks = [d["token"] for ev, d in events if ev == "token"]
    done = [d for ev, d in events if ev == "done"]
    assert len(done) == 1 and done[0]["tokens"] == toks
    # token frames must be contiguous global emission indices — a bad
    # failover splice would show up as a gap or repeat here
    assert [d["i"] for ev, d in events if ev == "token"] == \
        list(range(len(toks)))
    return toks


def _wait(pred, timeout=30, every=0.02):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if pred():
            return True
        time.sleep(every)
    return False


# ---------------------------------------------------------------------------
# units: affinity, journal, fault grammar
# ---------------------------------------------------------------------------


def test_rendezvous_rank_is_stable_permutation():
    for key in (0, 1, 0xDEADBEEF):
        r = rendezvous_rank(key, 5)
        assert sorted(r) == list(range(5))
        assert r == rendezvous_rank(key, 5)  # stable
    with pytest.raises(ValueError):
        rendezvous_rank(1, 0)


def test_rendezvous_minimal_disruption_and_spread():
    # removing the winner never reorders the rest (HRW's defining
    # property — each slot scores independently)
    for key in range(50):
        r = rendezvous_rank(key, 4)
        assert r[1:] == [i for i in rendezvous_rank(key, 4) if i != r[0]]
    # and keys spread over replicas (no slot starves)
    wins = [rendezvous_rank(k, 3)[0] for k in range(300)]
    assert all(wins.count(i) > 30 for i in range(3))


def test_prefix_key_header_granularity():
    head = list(range(100, 116))
    assert prefix_key(head + [1, 2]) == prefix_key(head + [3, 4, 5])
    assert prefix_key(head) != prefix_key([0] + head[1:])


def test_journal_records_and_resumes():
    j = RequestJournal()
    body = {"prompt": [1, 2], "max_new": 8, "seed": 7}
    e = j.open(body, stream=True)
    e.assign(0)
    e.record(0, 11)
    e.record(1, 12)
    with pytest.raises(ValueError):  # gap: splice out of sync
        e.record(3, 14)
    with pytest.raises(ValueError):  # repeat
        e.record(1, 12)
    e.assign(2)
    assert e.n_failovers == 1 and e.replica == 2
    rb = e.resume_body()
    assert rb["resume_tokens"] == [11, 12]
    assert body == {"prompt": [1, 2], "max_new": 8, "seed": 7}  # untouched
    j.note_failover(e)
    j.close(e, finish_reason="length")
    assert (len(j), j.opened, j.completed, j.failed, j.failovers) == \
        (0, 1, 1, 0, 1)
    e2 = j.open(body, stream=False)
    j.close(e2, finish_reason=None)
    assert j.failed == 1


def test_replica_fault_grammar_and_hook():
    plan = parse_fault_plan(
        "replica_kill@tick=5;replica_slow@ms=20,times=3;replica_hang")
    kinds = [r.kind for r in plan.rules]
    assert kinds == ["replica_kill", "replica_slow", "replica_hang"]
    with pytest.raises(ValueError):  # replica_slow needs ms=
        parse_fault_plan("replica_slow")
    # the hook honours tick pinning and consumes times
    plan = parse_fault_plan("replica_kill@tick=5")
    plan.tick = 4
    assert plan.replica_disruption() is None
    plan.tick = 5
    assert plan.replica_disruption().kind == "replica_kill"
    assert plan.replica_disruption() is None  # consumed


# ---------------------------------------------------------------------------
# the mechanism: resume-token replay is token-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampled", [False, True],
                         ids=["greedy", "sampled"])
@pytest.mark.parametrize("k", [0, 1, 5, GEN - 1])
def test_engine_resume_token_identity(fp_stack, sampled, k):
    """The failover contract at the engine level: submitting with
    ``resume_tokens=ref[:k]`` (on a FRESH engine — the survivor) must
    produce exactly ``ref`` — greedy because argmax is stateless,
    sampled because the device draw keys on fold_in(seed,
    emission_index) and the resumed request continues at emission
    index k."""
    cfg, model, params, prompts = fp_stack
    ref = _reference(model, params, prompts[0], sampled=sampled)
    assert len(ref) == GEN
    eng = _engine(model, params, sampled=sampled)
    sp = SamplingParams(**_SAMPLED) if sampled else None
    req = eng.submit(np.asarray(prompts[0]), max_new=GEN, sampling=sp,
                     resume_tokens=tuple(ref[:k]))
    assert req.resumed == k
    eng.run()
    assert [int(t) for t in req.out_tokens] == ref


def test_resume_token_validation(fp_stack):
    cfg, model, params, prompts = fp_stack
    eng = _engine(model, params)
    with pytest.raises(ValueError):  # resume must leave budget
        eng.submit(np.asarray(prompts[0]), max_new=4,
                   resume_tokens=(1, 2, 3, 4))
    with pytest.raises(ValueError):  # resume ending in a stop token
        eng.submit(np.asarray(prompts[0]), max_new=8, stop_tokens=(3,),
                   resume_tokens=(1, 2, 3))


# ---------------------------------------------------------------------------
# tick-stall watchdog
# ---------------------------------------------------------------------------


def test_healthz_watchdog_flips_on_wedged_executor(fp_stack):
    """Block the engine executor (the real wedge mode: a dispatch that
    never returns) — /healthz must flip to 503 'wedged' while the
    socket stays responsive, then recover when ticks resume."""
    cfg, model, params, prompts = fp_stack
    fd = FrontDoor(_engine(model, params), drain_timeout_s=2.0,
                   tick_stall_s=0.15).start_in_thread()
    try:
        status, h = _get_json(fd.port, "/healthz")
        assert status == 200 and h["status"] == "ok"
        assert "last_tick_age_s" in h and "inflight" in h
        fd._exec.submit(time.sleep, 1.0)  # wedge the engine thread
        assert _wait(lambda: _get_json(fd.port, "/healthz")[0] == 503,
                     timeout=5)
        status, h = _get_json(fd.port, "/healthz")
        if status == 503:  # may already have recovered
            assert h["status"] == "wedged"
            assert h["last_tick_age_s"] > 0.15
        assert _wait(lambda: _get_json(fd.port, "/healthz")[0] == 200,
                     timeout=5)
    finally:
        assert fd.drain_and_join().exit_code == 0
    # the gauge rides the metrics registry for scrapes too
    assert "last_tick_age_s" in fd.engine.summary()


# ---------------------------------------------------------------------------
# router end-to-end
# ---------------------------------------------------------------------------


def test_router_balances_and_stays_token_identical(fp_stack):
    cfg, model, params, prompts = fp_stack
    refs = [_reference(model, params, p) for p in prompts]
    router = _fleet(model, params, n=2)
    try:
        status, rz = _get_json(router.port, "/readyz")
        assert status == 200 and rz["available_replicas"] == 2
        got_sse = [_gen_tokens(router.port, p) for p in prompts]
        got_buf = [_gen_tokens(router.port, p, stream=False)
                   for p in prompts]
        assert got_sse == refs and got_buf == refs
        # sticky affinity: the same prompt lands on the same replica
        _, fz = _get_json(router.port, "/fleetz")
        assert fz["router"]["affinity_hits"] == 6
        assert fz["router"]["failovers"] == 0
        served = [r["served"] for r in fz["replicas"]]
        assert sum(served) == 6
    finally:
        report = router.drain_and_join()
    assert report.exit_code == 0 and report.completed == 6
    assert all(r["exit_code"] == 0 for r in report.replicas)


def test_router_passes_typed_rejections_through(fp_stack):
    cfg, model, params, prompts = fp_stack
    router = _fleet(model, params, n=2)
    try:
        # over-capacity: non-retryable 413, body verbatim from the
        # replica's typed AdmissionRejected mapping
        c, r = _post(router.port, {"prompt": [1, 2, 3],
                                   "max_new": 10_000})
        body = json.loads(r.read())
        c.close()
        assert r.status == 413
        assert body["error"] == "over_capacity"
        assert body["retryable"] is False
        # malformed body: the router 400s with the replica parser's
        # own message (never reaches a replica)
        c, r = _post(router.port, {"max_new": 4})
        body = json.loads(r.read())
        c.close()
        assert r.status == 400 and body["error"] == "bad_request"
        _, fz = _get_json(router.port, "/fleetz")
        assert fz["router"]["rejections_passed"] == 1
    finally:
        assert router.drain_and_join().exit_code == 0


def test_router_failover_splices_token_identically(fp_stack):
    """Headline greedy path: kill (disconnect) the serving replica
    mid-stream; the client's single SSE stream must carry the exact
    uninterrupted reference tokens, contiguous indices, one done."""
    cfg, model, params, prompts = fp_stack
    prompt = prompts[0]
    ref = _reference(model, params, prompt)
    victim = rendezvous_rank(prefix_key(prompt), 2)[0]

    def fault_for(index, generation):
        if index == victim and generation == 0:
            return parse_fault_plan("disconnect@tokens=3")
        return None

    router = _fleet(model, params, n=2, fault_for=fault_for)
    try:
        got = _gen_tokens(router.port, prompt)
        assert got == ref
        _, fz = _get_json(router.port, "/fleetz")
        assert fz["router"]["failovers"] == 1
        assert fz["journal"]["completed"] == 1
    finally:
        report = router.drain_and_join()
    assert report.exit_code == 0 and report.failovers == 1


def test_router_failover_token_identical_sampled(fp_stack):
    """The sampled half of the acceptance bar: device-sampled streams
    (per-request seed, emission-index key folding) survive failover
    token-identically too."""
    cfg, model, params, prompts = fp_stack
    prompt = prompts[1]
    ref = _reference(model, params, prompt, sampled=True)
    assert len(set(ref)) > 1 or len(ref) == GEN  # sanity: a real stream
    victim = rendezvous_rank(prefix_key(prompt), 2)[0]

    def fault_for(index, generation):
        if index == victim and generation == 0:
            return parse_fault_plan("disconnect@tokens=2")
        return None

    router = _fleet(model, params, n=2, sampled=True,
                    fault_for=fault_for)
    try:
        got = _gen_tokens(router.port, prompt, **_SAMPLED)
        assert got == ref
        _, fz = _get_json(router.port, "/fleetz")
        assert fz["router"]["failovers"] == 1
    finally:
        assert router.drain_and_join().exit_code == 0


def test_router_503_when_no_replica_available(fp_stack):
    cfg, model, params, prompts = fp_stack
    # max_restarts=0: first failure parks the slot as 'gone' (circuit
    # breaker), so killing both replicas leaves nothing to route to
    router = _fleet(model, params, n=2, max_restarts=0)
    sup = router.sup
    try:
        for h in sup.handles:
            h.proc.drain_and_join("chaos-kill")
        assert _wait(lambda: all(h.state == "gone"
                                 for h in sup.handles), timeout=15)
        status, rz = _get_json(router.port, "/readyz")
        assert status == 503 and rz["available_replicas"] == 0
        c, r = _post(router.port, {"prompt": [1, 2, 3], "max_new": 4})
        body = json.loads(r.read())
        assert r.status == 503
        assert body == {"error": "replica_unavailable",
                        "retryable": True}
        assert r.getheader("Retry-After") == "1"
        c.close()
    finally:
        report = router.drain_and_join()
    # gone slots have no live process (exit None) — nothing to leak
    assert report.exit_code == 0


def test_supervisor_restarts_crashed_replica(fp_stack):
    """Crash replica 0 (drain its thread = the process dies), wait for
    the probe loop to respawn it, and require the restarted replica to
    serve token-identical output — fresh engine, same weights."""
    cfg, model, params, prompts = fp_stack
    ref = _reference(model, params, prompts[2])
    router = _fleet(model, params, n=1, max_restarts=2)
    sup = router.sup
    h = sup.handles[0]
    try:
        first_port = h.port
        h.proc.drain_and_join("chaos-kill")
        assert _wait(lambda: h.state == "healthy" and h.restarts == 1,
                     timeout=30)
        assert h.generation == 2 and h.port != first_port
        assert _gen_tokens(router.port, prompts[2]) == ref
        # second crash: restart budget (2) still has room
        h.proc.drain_and_join("chaos-kill-2")
        assert _wait(lambda: h.state == "healthy" and h.restarts == 2,
                     timeout=30)
        # third crash trips the give-up circuit breaker
        h.proc.drain_and_join("chaos-kill-3")
        assert _wait(lambda: h.state == "gone", timeout=30)
        assert _get_json(router.port, "/readyz")[0] == 503
    finally:
        report = router.drain_and_join()
    assert report.exit_code == 0
    assert report.replicas[0]["restarts"] == 2
