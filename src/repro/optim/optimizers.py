"""Optimizers as (init, update) pairs over pytrees (optax-style, no dep).

Mixed precision: model params may be bf16; the optimizer keeps an fp32
master copy + fp32 moments and re-casts updated params to the model dtype
("params = cast(master)" invariant).  ``adafactor`` offers the low-memory
option for the biggest archs (factored second moment).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "sgd", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable  # params -> state
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw(
    lr: Callable | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        # copy=True: for fp32 params astype would ALIAS the param buffer,
        # and donating (params, opt_state) would then donate it twice
        f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
        return {
            "master": jax.tree.map(f32, params),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gn = global_norm(grads)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(g, m, v, master):
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mh = m2 / bc1
            vh = v2 / bc2
            new_master = master - lr_t * (
                mh / (jnp.sqrt(vh) + eps) + weight_decay * master
            )
            return m2, v2, new_master

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_ma = treedef.flatten_up_to(state["master"])
        out = [upd(g, m, v, ma) for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
        m2 = treedef.unflatten([o[0] for o in out])
        v2 = treedef.unflatten([o[1] for o in out])
        master2 = treedef.unflatten([o[2] for o in out])
        new_params = jax.tree.map(
            lambda ma, p: ma.astype(p.dtype), master2, params
        )
        return new_params, {"master": master2, "m": m2, "v": v2}, {"grad_norm": gn}

    return Optimizer(init=init, update=update)


def adafactor(
    lr: Callable | float,
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_norm: Optional[float] = 1.0,
) -> Optimizer:
    """Factored second moment for >=2D leaves (memory ~ O(m+n) per matrix)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def moment_shapes(p):
        if p.ndim >= 2:
            return (
                jnp.zeros(p.shape[:-1], jnp.float32),  # row
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col
            )
        return (jnp.zeros(p.shape, jnp.float32), None)

    def init(params):
        moments = jax.tree.map(moment_shapes, params)
        return {
            "master": jax.tree.map(
                lambda p: jnp.array(p, jnp.float32, copy=True), params
            ),
            "moments": moments,
        }

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gn = global_norm(grads)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, mom, master):
            row, col = mom
            g2 = g * g + eps
            if g.ndim >= 2:
                row2 = beta * row + (1 - beta) * jnp.mean(g2, axis=-1)
                col2 = beta * col + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    row2[..., None]
                    * col2[..., None, :]
                    / (jnp.mean(row2, axis=-1, keepdims=True)[..., None] + eps)
                )
                upd_val = g / (denom + 1e-9)
                new_mom = (row2, col2)
            else:
                row2 = beta * row + (1 - beta) * g2
                upd_val = g / (jnp.sqrt(row2) + 1e-9)
                new_mom = (row2, None)
            return new_mom, master - lr_t * upd_val

        flat_g, treedef = jax.tree.flatten(grads)
        flat_mom = treedef.flatten_up_to(state["moments"])
        flat_ma = treedef.flatten_up_to(state["master"])
        out = [upd(g, mo, ma) for g, mo, ma in zip(flat_g, flat_mom, flat_ma)]
        moments2 = treedef.unflatten([o[0] for o in out])
        master2 = treedef.unflatten([o[1] for o in out])
        new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), master2, params)
        return new_params, {"master": master2, "moments": moments2}, {"grad_norm": gn}

    return Optimizer(init=init, update=update)


def sgd(lr: Callable | float, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum:
            return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}
        return {}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        if momentum:
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads,
            )
            new_params = jax.tree.map(
                lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
                params, mom,
            )
            return new_params, {"mom": mom}, {"grad_norm": global_norm(grads)}
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr_t * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        return new_params, state, {"grad_norm": global_norm(grads)}

    return Optimizer(init=init, update=update)
