from repro.kernels.ldlq.ops import ldlq_pallas

__all__ = ["ldlq_pallas"]
