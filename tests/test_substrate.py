"""Substrate tests: checkpoint store, optimizers, data determinism,
sharding rules, elastic re-mesh, gradient compression."""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.data import make_calibration, token_batches
from repro.optim import (
    adafactor,
    adamw,
    cosine_schedule,
    ef_int8_compress,
    ef_int8_decompress,
    init_ef_state,
    sgd,
)
from repro.runtime.elastic import best_mesh_shape
from repro.runtime.sharding import default_rules, logical_to_pspec, serving_rules
from jax.sharding import PartitionSpec as P


# --- checkpoint -------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 5, t, extra_meta={"note": "x"})
    like = jax.tree.map(jnp.zeros_like, t)
    restored, step, meta = load_checkpoint(tmp_path, like)
    assert step == 5 and meta["note"] == "x"
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        restored, t,
    )


def test_checkpoint_latest_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, save_every=1)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.maybe_save(s, t)
    assert latest_step(tmp_path) == 4
    kept = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_checkpoint_crashed_writer_ignored(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    # simulate a crashed writer: stale tmp dir + a final dir w/o manifest
    (tmp_path / "step_00000009.tmp-123").mkdir()
    (tmp_path / "step_00000007").mkdir()
    assert latest_step(tmp_path) == 1
    restored, step, _ = load_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, t))
    assert step == 1


def test_checkpoint_atomicity_no_partial_state(tmp_path):
    t = _tree()
    p = save_checkpoint(tmp_path, 3, t)
    assert (p / "manifest.json").exists()
    assert not list(tmp_path.glob("*.tmp-*"))


# --- optimizers -------------------------------------------------------------


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(5e-2), lambda: adafactor(5e-2), lambda: sgd(1e-1, 0.9),
])
def test_optimizer_reduces_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([2.0, -3.0, 1.5])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    upd = jax.jit(opt.update)
    for step in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = upd(g, state, params, jnp.int32(step))
    assert float(loss(params)) < 0.2 * l0


def test_adamw_master_is_not_param_alias():
    """fp32 params must be COPIED into the master (donation safety)."""
    params = {"w": jnp.ones((4,), jnp.float32)}
    st_ = adamw(1e-2).init(params)
    assert st_["master"]["w"].unsafe_buffer_pointer() != params["w"].unsafe_buffer_pointer()


def test_bf16_params_fp32_master():
    opt = adamw(1e-2)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 0.5, jnp.bfloat16)}
    p2, s2, m = opt.update(g, state, params, jnp.int32(0))
    assert p2["w"].dtype == jnp.bfloat16
    assert float(m["grad_norm"]) > 0


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, 100, warmup_steps=10, final_frac=0.1)
    assert float(f(0)) < 0.2
    assert abs(float(f(10)) - 1.0) < 0.05
    assert abs(float(f(99)) - 0.1) < 0.05


# --- gradient compression ---------------------------------------------------


def test_ef_int8_error_feedback_converges():
    """Accumulated EF error stays bounded; mean compressed grad ~ true."""
    g = {"w": jnp.linspace(-1, 1, 256)}
    ef = init_ef_state(g)
    acc = jnp.zeros_like(g["w"])
    for _ in range(50):
        q, s, ef = ef_int8_compress(g, ef)
        acc = acc + ef_int8_decompress(q, s)["w"]
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g["w"]), atol=1e-3)


def test_ef_int8_payload_is_int8():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
    q, s, ef = ef_int8_compress(g, init_ef_state(g))
    assert q["w"].dtype == jnp.int8


# --- data -------------------------------------------------------------------


def test_token_stream_deterministic_and_resumable():
    a = token_batches(512, 4, 32, seed=3)
    b = token_batches(512, 4, 32, seed=3)
    for _ in range(3):
        x, y = next(a), next(b)
        np.testing.assert_array_equal(np.asarray(x["tokens"]), np.asarray(y["tokens"]))
    # resume: start_step replays the same step
    c = token_batches(512, 4, 32, seed=3, start_step=3)
    x3 = next(a)
    np.testing.assert_array_equal(
        np.asarray(next(c)["tokens"]), np.asarray(x3["tokens"])
    )


def test_targets_are_shifted_tokens():
    b = next(token_batches(128, 2, 16, seed=0))
    assert b["tokens"].shape == b["targets"].shape == (2, 16)


def test_calibration_deterministic():
    a = make_calibration(256, n_segments=4, seg_len=32, seed=5)
    b = make_calibration(256, n_segments=4, seg_len=32, seed=5)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


# --- sharding rules ---------------------------------------------------------


class _FakeMesh:
    shape = {"pod": 2, "data": 16, "model": 16}


def test_logical_to_pspec_divisibility_fallback():
    mesh = _FakeMesh()
    rules = default_rules(multi_pod=True)
    # 40 heads % 16 != 0 -> replicated; 1024 kv-dim divides -> sharded
    spec = logical_to_pspec(mesh, rules, ("embed", "heads"), (5120, 5120))
    assert spec == P("data", "model")
    spec = logical_to_pspec(mesh, rules, (None, "act_heads", None), (1, 40, 128))
    assert spec == P(None, None, None)
    # batch=256 divides pod*data=32
    spec = logical_to_pspec(mesh, rules, ("batch", "seq"), (256, 4096))
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k) falls back to replicated
    spec = logical_to_pspec(mesh, rules, ("batch", "seq"), (1, 524288))
    assert spec == P(None, None)


def test_mesh_axis_used_once_per_array():
    mesh = _FakeMesh()
    rules = default_rules(multi_pod=True)
    # experts and ff both map to 'model': second gets dropped
    spec = logical_to_pspec(mesh, rules, ("experts", "embed", "ff"), (128, 7168, 4864))
    assert spec == P("model", "data", None)


def test_serving_rules_drop_fsdp():
    assert serving_rules()["embed"] is None
    assert default_rules()["embed"] == "data"


# --- elastic ----------------------------------------------------------------


def test_best_mesh_shape_degradation():
    # full 2-pod cluster
    shape, axes = best_mesh_shape(512, model_parallelism=16)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    # lose one pod
    shape, axes = best_mesh_shape(256, model_parallelism=16)
    assert shape == (16, 16) and axes == ("data", "model")
    # lose 3 chips: keep model=16 groups, sacrifice data + idle remainder
    shape, axes = best_mesh_shape(253, model_parallelism=16)
    assert shape == (15, 16)
    # tiny host fallback
    shape, axes = best_mesh_shape(1, model_parallelism=16)
    assert shape == (1, 1)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 1024))
def test_property_best_mesh_never_exceeds_devices(n):
    shape, axes = best_mesh_shape(n)
    used = 1
    for s in shape:
        used *= s
    assert 0 < used <= n
    assert len(shape) == len(axes)
