"""Oracle: the XLA-only LDLQ implementations from repro.core."""
from repro.core.ldlq import ldlq as ldlq_ref, ldlq_blocked as ldlq_blocked_ref

__all__ = ["ldlq_ref", "ldlq_blocked_ref"]
