"""Algorithm 5: clamp-safe rounding via the convex program of Eq. (7).

    minimize    tr(H L^T L)
    over        L unit upper triangular
    subject to  e_i^T L^T L e_i <= 1 + c   for all i

solved with projected gradient descent (the constraint set is a product of
per-column norm balls on the strictly-upper part: ||L e_i||^2 = 1 +
||u_i||^2 <= 1 + c  <=>  ||u_i|| <= sqrt(c)), then QuIP rounding with
STOCHASTIC Q and U = L^{-1} - I in place of the LDL factor.

Theorem 7: with suitable (c, rho) all quantized weights stay in range
w.h.p. and the proxy loss is O~(tr(H^{1/2})^2 ||W||_F^2 / (n^2 4^b)).
As c -> inf the solution is the LDL factor and this reduces to base QuIP.
The paper (and we — Supplement C.9) found base QuIP preferable in
practice; this module exists to close the theory (tests verify it beats
clamped LDLQ on the Fig. 4 counterexample where clamping actually binds).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.ldlq import ldl_decomposition, quantize_stoch

__all__ = ["solve_clamp_safe_L", "clamp_safe_round"]


@functools.partial(jax.jit, static_argnames=("iters",))
def solve_clamp_safe_L(
    H: jax.Array, c: float, *, iters: int = 300, lr: Optional[float] = None
) -> jax.Array:
    """Projected gradient descent on Eq. (7).  Returns L (unit upper)."""
    n = H.shape[0]
    Hf = H.astype(jnp.float32)
    mask = jnp.triu(jnp.ones((n, n), jnp.float32), k=1)
    eye = jnp.eye(n, dtype=jnp.float32)

    # warm start from the (unconstrained) LDL solution, projected
    Udot, _ = ldl_decomposition(Hf)
    # L^{-1} = I + Udot  =>  L = (I + Udot)^{-1}; solve triangular system
    L0 = jax.scipy.linalg.solve_triangular(eye + Udot, eye, lower=False)
    U0 = (L0 - eye) * mask

    step = lr if lr is not None else 0.5 / (jnp.trace(Hf) / n + 1e-9)
    sqrt_c = jnp.sqrt(jnp.float32(c))

    def project(U):
        norms = jnp.sqrt(jnp.sum(U * U, axis=0) + 1e-12)  # per column
        scale = jnp.minimum(1.0, sqrt_c / norms)
        return U * scale[None, :]

    def body(_, U):
        L = eye + U
        grad = 2.0 * (L @ Hf) * mask  # d/dU tr(H L^T L), strictly-upper part
        return project(U - step * grad)

    U = jax.lax.fori_loop(0, iters, body, project(U0))
    return eye + U


def clamp_safe_round(
    W: jax.Array,
    H: jax.Array,
    maxq: int,
    key: jax.Array,
    *,
    c: float = 0.5,
    iters: int = 300,
) -> jax.Array:
    """Algorithm 5 rounding: stochastic Q with U = L^{-1} - I feedback.

    W on the grid domain [0, maxq]; returns the rounded grid weights.
    """
    n = H.shape[0]
    L = solve_clamp_safe_L(H, c, iters=iters)
    eye = jnp.eye(n, dtype=jnp.float32)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=False)
    U = (Linv - eye) * jnp.triu(jnp.ones((n, n), jnp.float32), k=1)

    keys = jax.random.split(key, n)

    def body(k, What):
        corr = (W - What) @ U[:, k]
        val = W[:, k] + corr
        return What.at[:, k].set(quantize_stoch(val, maxq, keys[k]))

    return jax.lax.fori_loop(0, n, body, W.astype(jnp.float32))
