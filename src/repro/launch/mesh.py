"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (v5e).  Multi-pod:
2 pods x 256 = 512 chips with a leading 'pod' axis extending data
parallelism (gradient reductions run hierarchically over ('pod', 'data')).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """Default production grids; ``shape`` re-slices the same chips
    (a per-arch §Perf knob: e.g. (256, 1) = pure-ZeRO for models whose
    sharded weights fit HBM without tensor parallelism)."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    assert len(shape) == len(axes)
    return jax.make_mesh(tuple(shape), axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for single-device smoke runs."""
    return jax.make_mesh((1, 1), ("data", "model"))
