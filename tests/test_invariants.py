"""System invariants across the whole zoo (property-style).

* causality: perturbing a future token never changes past logits;
* sharding-metadata congruence: param_axes / cache_axes trees are
  structurally identical to the params / cache trees (what the dry-run's
  in_shardings depend on — a mismatch is a launch-time crash at scale);
* roofline model sanity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, SHAPES
from repro.models import build_model
from repro.runtime.roofline import model_flops, roofline_terms

CAUSAL_ARCHS = [
    "mistral-large-123b", "qwen3-14b", "starcoder2-15b", "arctic-480b",
    "rwkv6-1.6b", "zamba2-7b",
]


def _batch(cfg, rng, B=2, S=12):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", CAUSAL_ARCHS)
def test_causality(arch):
    """logits[:, :j] must not depend on tokens[:, j+1:]."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    S = batch["tokens"].shape[1]
    j = S // 2
    h1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["tokens"] = batch["tokens"].at[:, j + 1 :].set(
        (batch["tokens"][:, j + 1 :] + 7) % cfg.vocab
    )
    h2, _ = model.forward(params, batch2)
    lg1 = model.logits(params, h1)[:, : j + 1]
    lg2 = model.logits(params, h2)[:, : j + 1]
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=1e-5)


def _same_structure(tree_a, axes_tree) -> bool:
    """axes leaves are tuples of str/None; compare container structure."""
    def is_axes(v):
        return isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v
        )

    paths_a = {
        tuple(str(p) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree_a)[0]
    }
    paths_b = {
        tuple(str(p) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(
            axes_tree, is_leaf=is_axes
        )[0]
    }
    return paths_a == paths_b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_structure_matches_params(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    aparams = model.abstract_params()
    assert _same_structure(aparams, model.param_axes()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_axes_structure_matches_cache(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    acache = model.abstract_cache(2, 16)
    assert _same_structure(acache, model.cache_axes()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_axes_rank_matches_param_rank(arch):
    """Every axes tuple must have exactly one entry per array dim."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    aparams = model.abstract_params()
    axes = model.param_axes()

    def is_axes(v):
        return isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v
        )

    flat_p = jax.tree_util.tree_flatten_with_path(aparams)[0]
    flat_a = {tuple(str(q) for q in path): ax
              for path, ax in jax.tree_util.tree_flatten_with_path(axes, is_leaf=is_axes)[0]}
    for path, leaf in flat_p:
        key = tuple(str(q) for q in path)
        assert len(flat_a[key]) == leaf.ndim, (arch, key, flat_a[key], leaf.shape)


def test_model_flops_ordering():
    cfg_small = get_config("rwkv6-1.6b")
    cfg_big = get_config("mistral-large-123b")
    assert model_flops(cfg_big, SHAPES["train_4k"]) > model_flops(
        cfg_small, SHAPES["train_4k"]
    )
    # decode << train per step
    assert model_flops(cfg_big, SHAPES["decode_32k"]) < model_flops(
        cfg_big, SHAPES["train_4k"]
    )
    # MoE active < total
    moe = get_config("arctic-480b")
    assert moe.active_param_count() < moe.param_count() / 5


def test_roofline_terms_consistency():
    t = roofline_terms(
        hlo_flops=1e12, hlo_bytes=1e12, collective_bytes=1e10, chips=256,
        cfg=get_config("qwen3-14b"), shape=SHAPES["train_4k"],
        flops_are_global=False,
    )
    assert t.dominant == "memory"
    assert t.step_time_s == t.memory_s
    assert t.mfu > 0  # synthetic inputs: only positivity is meaningful
    assert t.hlo_flops_global == 256e12
