"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block —
arXiv:2411.15242.

Layout: 81 layers = 13 superblocks of (5 Mamba2 + 1 shared attn+MLP
invocation) + 3 tail Mamba2 layers; the attention block is ONE weight copy
invoked 13 times with distinct KV caches (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,      # MHA in the shared block
    head_dim=112,       # 3584 / 32
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_period=6,
    mlp="swiglu",
    rope_theta=1e4,
    microbatch=32,
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b-smoke",
        family="hybrid",
        n_layers=7,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=32,
        shared_attn_period=3,
        mlp="swiglu",
        dtype="float32",
        microbatch=2,
        remat="none",
    )
