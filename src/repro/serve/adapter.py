"""Unified cached prefill/decode forward over fp and QuIP-quantized models.

A :class:`CachedDecoder` holds per-layer *blocks*: norm params plus one
callable per linear projection, keyed exactly like
``launch.quantize.QuantizedModel.blocks`` ("attn.wq", ..., "mlp.wo").  For
the fp ``Model`` the callables close over dense params (``layers.apply_w``);
for a ``QuantizedModel`` they ARE the :class:`QuantizedLinear` layers, so
every projection runs the packed ``D⁻¹ → V → quant_matmul → Uᵀ`` structured
path — this replaces the old per-token full-recompute serving loop with a
real KV-cached decode for quantized weights.

Two decode paths share the block structure:

  * **gather-dense (reference oracle)** — :meth:`__call__`: the engine
    gathers every context page into a dense ``(L, B, S, KV, hd)`` window
    and the forward concatenates new K/V.  Handles chunked prefill
    (``tokens (1, C)``) and batched decode (``tokens (B, 1)``).
  * **paged fast path** — :meth:`decode_paged`: one jitted dispatch that
    (1) runs every projection — routing ``QuantizedLinear`` through the
    Pallas ``quant_matmul`` kernel path instead of the XLA unpack
    fallback, (2) computes attention *in place* against the physical page
    pool via ``kernels.paged_attention`` (per-lane block tables + context
    lengths, self-token folded in analytically), and (3) scatters the new
    K/V into the donated pool tensors.  No per-step dense KV copy exists
    anywhere in this path.

Prefill has the same split: the gather-dense path runs one ``(1, C)``
chunk per request, while :meth:`prefill_paged` runs a whole padded
cross-request chunk batch ``(B, C)`` as one jitted dispatch — projections
through the ``quant_matmul`` kernel dispatch, causal chunk attention over
the page pool via ``kernels.paged_attention.paged_gqa_prefill`` (ragged
per-lane prior-context lengths), and a donated in-place scatter of every
chunk token's K/V (padded tails land on the scratch page).

Masking uses the same where-set convention as the quantized recompute path
so cached logits match it bit-for-bit up to matmul reassociation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.quantizer import QuantizedLinear
from repro.kernels.paged_attention.ops import (
    paged_gqa_decode,
    paged_gqa_prefill,
)
from repro.models import layers as L
from repro.models.transformer import unstack_layers
from repro.serve.kv_cache import PagedKVPool, quantize_kv_int8

__all__ = ["CachedDecoder"]


def _linear(p, cfg: ArchConfig, bias=None) -> Callable:
    if bias is None:
        return lambda x: L.apply_w(p, x, cfg)
    return lambda x: L.apply_w(p, x, cfg) + bias


def _fp_blocks(params, cfg: ArchConfig) -> list[dict]:
    blocks = []
    for lp in unstack_layers(params):
        at, mp = lp["attn"], lp["mlp"]
        blk = {
            "ln1": lp["ln1"],
            "ln2": lp["ln2"],
            "attn.wq": _linear(at["wq"], cfg, at.get("bq")),
            "attn.wk": _linear(at["wk"], cfg, at.get("bk")),
            "attn.wv": _linear(at["wv"], cfg, at.get("bv")),
            "attn.wo": _linear(at["wo"], cfg),
            "mlp.wi": _linear(mp["wi"], cfg, mp.get("bi")),
            "mlp.wo": _linear(mp["wo"], cfg, mp.get("bo")),
        }
        if cfg.mlp == "swiglu":
            blk["mlp.wg"] = _linear(mp["wg"], cfg)
        if cfg.qk_norm:
            blk["q_norm"] = at["q_norm"]
            blk["k_norm"] = at["k_norm"]
        blocks.append(blk)
    return blocks


@dataclasses.dataclass
class CachedDecoder:
    """KV-cached forward shared by the fp and quantized serving paths."""

    cfg: ArchConfig
    embed: dict
    final_norm: dict
    blocks: list
    paged: bool = False  # engine default: decode via the paged fast path
    paged_interpret: bool = False  # force the Pallas kernel (interpret) off-TPU

    def __post_init__(self):
        if self.cfg.family != "dense":
            raise ValueError(
                f"serving adapter supports the dense family, got {self.cfg.family}"
            )
        # blocks close over their params -> jit treats them as constants;
        # one compile per (adapter, tokens/ctx shape) pair.
        self._fwd = jax.jit(self._forward)
        # fused decode: pool tensors are donated and updated in place by
        # the trailing scatter — one dispatch per engine decode step.
        self._fwd_paged = jax.jit(self._forward_paged, donate_argnums=(6, 7))
        self._fwd_paged_q = jax.jit(
            self._forward_paged_q, donate_argnums=(6, 7, 8, 9)
        )
        # fused batched prefill: same donation contract, one dispatch per
        # engine prefill tick over the whole cross-request chunk batch.
        self._fwd_prefill = jax.jit(
            self._forward_prefill_paged, donate_argnums=(6, 7)
        )
        self._fwd_prefill_q = jax.jit(
            self._forward_prefill_paged_q, donate_argnums=(6, 7, 8, 9)
        )

    # ---- constructors ---------------------------------------------------

    @classmethod
    def from_model(cls, model, params, **kw) -> "CachedDecoder":
        return cls(
            cfg=model.cfg,
            embed=params["embed"],
            final_norm=params["final_norm"],
            blocks=_fp_blocks(params, model.cfg),
            **kw,
        )

    @classmethod
    def from_quantized(cls, qm, **kw) -> "CachedDecoder":
        # QuantizedModel.blocks already has the expected structure, with
        # QuantizedLinear instances as the projection callables.
        return cls(
            cfg=qm.cfg, embed=qm.embed, final_norm=qm.final_norm,
            blocks=qm.blocks, **kw,
        )

    # ---- engine hooks ----------------------------------------------------

    def make_pool(self, **kw) -> PagedKVPool:
        """Build the engine's KV pool.  Distributed adapters override this
        to place the physical pages sharded over their mesh."""
        return PagedKVPool(self.cfg, **kw)

    def _place(self, x, dtype=None):
        """Device placement for small per-step host arrays (tokens, block
        tables, context lengths, page addresses).  Distributed adapters
        override to commit them replicated on the mesh."""
        return jnp.asarray(x, dtype)

    # ---- gather-dense reference path ------------------------------------

    def __call__(self, tokens, positions, ctx_k, ctx_v, ctx_len):
        """Cached forward (gather-dense reference).

        tokens    (B, T) int32 — new tokens (decode: T=1; prefill: B=1);
        positions (B, T) int32 — absolute position of each new token;
        ctx_k/v   (L, B, S, KV, hd) — gathered context pages (post-RoPE K);
        ctx_len   (B,) int32 — valid context tokens per lane.

        Returns (logits (B, T, V), k_new (L, B, T, KV, hd), v_new (same)).
        """
        return self._fwd(tokens, positions, ctx_k, ctx_v, ctx_len)

    def _forward(self, tokens, positions, ctx_k, ctx_v, ctx_len):
        cfg = self.cfg
        x = L.embed(self.embed, tokens)
        new_k, new_v = [], []
        for i, blk in enumerate(self.blocks):
            x, k, v = self._block(blk, x, positions, ctx_k[i], ctx_v[i], ctx_len)
            new_k.append(k)
            new_v.append(v)
        x = L.norm_apply(self.final_norm, x, cfg)
        logits = L.lm_logits(self.embed, x)
        return logits, jnp.stack(new_k), jnp.stack(new_v)

    def _block(self, blk, x, positions, ck, cv, ctx_len):
        cfg = self.cfg
        B, T, _ = x.shape
        S = ck.shape[1]
        h = L.norm_apply(blk["ln1"], x, cfg)
        q, k, v = self._qkv(blk, h, positions)
        k_all = jnp.concatenate([ck.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([cv.astype(v.dtype), v], axis=1)
        s = L._gqa_scores(q, k_all, cfg)  # (B, KV, G, T, S+T)
        # context keys: valid below each lane's ctx_len; new keys: causal
        # within the chunk (their absolute positions are >= every ctx pos).
        mask_ctx = jnp.arange(S)[None, None, :] < ctx_len[:, None, None]
        mask_ctx = jnp.broadcast_to(mask_ctx, (B, T, S))
        mask_new = jnp.broadcast_to(
            jnp.tril(jnp.ones((T, T), bool))[None], (B, T, T)
        )
        mask = jnp.concatenate([mask_ctx, mask_new], axis=-1)
        s = jnp.where(mask[:, None, None], s, jnp.finfo(s.dtype).min)
        probs = jax.nn.softmax(s, axis=-1)
        o = L._gqa_out(probs, v_all, cfg)
        o = o.astype(x.dtype).reshape(B, T, cfg.q_dim)
        x = x + blk["attn.wo"](o)
        return self._mlp(blk, x), k, v

    # ---- shared block pieces --------------------------------------------

    def _proj(self, blk, name, h):
        """Apply one projection; on the paged fast path QuantizedLinear
        goes through the Pallas quant_matmul kernel dispatch (batched
        decode matvec, affine dequant in the epilogue) instead of the XLA
        unpack fallback."""
        f = blk[name]
        if isinstance(f, QuantizedLinear):
            return f(h, use_kernel=True)
        return f(h)

    def _qkv(self, blk, h, positions, *, kernel_proj: bool = False):
        """(q, k, v) each (B, T, heads, hd), qk-normed + RoPE'd."""
        cfg = self.cfg
        B, T, _ = h.shape
        proj = (lambda n: self._proj(blk, n, h)) if kernel_proj else (
            lambda n: blk[n](h)
        )
        q = proj("attn.wq").reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = proj("attn.wk").reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = proj("attn.wv").reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = L.rms_norm(q, blk["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, blk["k_norm"], cfg.norm_eps)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _mlp(self, blk, x, *, kernel_proj: bool = False):
        cfg = self.cfg
        h = L.norm_apply(blk["ln2"], x, cfg)
        proj = (lambda n, z: self._proj(blk, n, z)) if kernel_proj else (
            lambda n, z: blk[n](z)
        )
        up = proj("mlp.wi", h)
        if cfg.mlp == "swiglu":
            up = jax.nn.silu(up) * proj("mlp.wg", h)
        else:
            up = jax.nn.gelu(up)
        return x + proj("mlp.wo", up)

    # ---- paged fast path -------------------------------------------------

    def decode_paged(self, tokens, positions, block_tables, ctx_len,
                     pages, offs, pool):
        """Fused decode step against ``pool`` (PagedKVPool), in place.

        tokens/positions (B, 1) int32; block_tables (B, Pa) int32 bucketed
        to the attended prefix; ctx_len (B,) int32; pages/offs (B,) int32
        physical address of each lane's new token (scratch for pad lanes).

        Mutates ``pool.k``/``pool.v`` (+ scales for int8 pools) via donated
        buffers and returns logits (B, 1, V).  The caller still owns the
        host-side length accounting (``pool.note_written``).
        """
        args = (
            self._place(tokens), self._place(positions),
            self._place(block_tables), self._place(ctx_len),
            self._place(pages), self._place(offs),
        )
        if pool.is_int8:
            logits, pool.k, pool.v, pool.k_scale, pool.v_scale = (
                self._fwd_paged_q(
                    *args, pool.k, pool.v, pool.k_scale, pool.v_scale
                )
            )
        else:
            logits, pool.k, pool.v = self._fwd_paged(*args, pool.k, pool.v)
        return logits

    def _paged_trunk(self, tokens, positions, block_tables, ctx_len,
                     pool_k, pool_v, k_scale, v_scale):
        """Embed -> blocks (paged attention) -> logits; returns the new
        per-layer K/V stacked (L, B, KV, hd) for the trailing scatter."""
        cfg = self.cfg
        x = L.embed(self.embed, tokens)  # (B, 1, D)
        new_k, new_v = [], []
        for i, blk in enumerate(self.blocks):
            x, k, v = self._block_paged(
                blk, x, positions, i, pool_k, pool_v, k_scale, v_scale,
                block_tables, ctx_len,
            )
            new_k.append(k)
            new_v.append(v)
        x = L.norm_apply(self.final_norm, x, cfg)
        logits = L.lm_logits(self.embed, x)
        return logits, jnp.stack(new_k), jnp.stack(new_v)

    def _forward_paged(self, tokens, positions, block_tables, ctx_len,
                       pages, offs, pool_k, pool_v):
        logits, kn, vn = self._paged_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            None, None,
        )
        pool_k = pool_k.at[:, pages, offs].set(kn.astype(pool_k.dtype))
        pool_v = pool_v.at[:, pages, offs].set(vn.astype(pool_v.dtype))
        return logits, pool_k, pool_v

    def _forward_paged_q(self, tokens, positions, block_tables, ctx_len,
                         pages, offs, pool_k, pool_v, k_scale, v_scale):
        logits, kn, vn = self._paged_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            k_scale, v_scale,
        )
        kq, ks = quantize_kv_int8(kn)
        vq, vs = quantize_kv_int8(vn)
        pool_k = pool_k.at[:, pages, offs].set(kq)
        pool_v = pool_v.at[:, pages, offs].set(vq)
        k_scale = k_scale.at[:, pages, offs].set(ks)
        v_scale = v_scale.at[:, pages, offs].set(vs)
        return logits, pool_k, pool_v, k_scale, v_scale

    def _block_paged(self, blk, x, positions, layer, pool_k, pool_v,
                     k_scale, v_scale, block_tables, ctx_len):
        cfg = self.cfg
        B = x.shape[0]
        h = L.norm_apply(blk["ln1"], x, cfg)
        q, k, v = self._qkv(blk, h, positions, kernel_proj=True)
        o = self._paged_attention(
            q[:, 0], k[:, 0], v[:, 0], pool_k, pool_v, k_scale, v_scale,
            block_tables, ctx_len, layer=layer,
        )
        o = o.astype(x.dtype).reshape(B, 1, cfg.q_dim)
        x = x + self._proj(blk, "attn.wo", o)
        return self._mlp(blk, x, kernel_proj=True), k[:, 0], v[:, 0]

    def _paged_attention(self, q, k_new, v_new, pool_k, pool_v, k_scale,
                         v_scale, block_tables, ctx_len, *, layer):
        """One layer of decode attention against the pool.  Distributed
        adapters override this with a ``shard_map`` over the model axis so
        each device attends only its local KV-head page slice."""
        return paged_gqa_decode(
            q, k_new, v_new, pool_k, pool_v, block_tables, ctx_len,
            layer=layer, k_scale=k_scale, v_scale=v_scale,
            interpret=self.paged_interpret,
        )

    # ---- paged batched prefill -------------------------------------------

    def prefill_paged(self, tokens, positions, block_tables, ctx_len,
                      pages, offs, pool):
        """Fused cross-request prefill chunk batch against ``pool``.

        tokens/positions (B, C) int32 — lane b carries one request's chunk
        (front-aligned, zero-padded tail); block_tables (B, Pa) int32
        bucketed to the longest PRIOR context; ctx_len (B,) int32 prior
        context per lane (the chunk start); pages/offs (B, C) int32
        physical address of every chunk token (scratch for padding).

        Mutates ``pool.k``/``pool.v`` (+ scales for int8 pools) via donated
        buffers and returns logits (B, C, V).  The caller owns the host-
        side length accounting (``pool.note_span_written``).
        """
        args = (
            self._place(tokens), self._place(positions),
            self._place(block_tables), self._place(ctx_len),
            self._place(pages), self._place(offs),
        )
        if pool.is_int8:
            logits, pool.k, pool.v, pool.k_scale, pool.v_scale = (
                self._fwd_prefill_q(
                    *args, pool.k, pool.v, pool.k_scale, pool.v_scale
                )
            )
        else:
            logits, pool.k, pool.v = self._fwd_prefill(*args, pool.k, pool.v)
        return logits

    def _prefill_trunk(self, tokens, positions, block_tables, ctx_len,
                       pool_k, pool_v, k_scale, v_scale):
        """Embed -> blocks (paged chunk attention) -> logits; returns the
        chunk's per-layer K/V stacked (L, B, C, KV, hd) for the scatter."""
        cfg = self.cfg
        x = L.embed(self.embed, tokens)  # (B, C, D)
        new_k, new_v = [], []
        for i, blk in enumerate(self.blocks):
            x, k, v = self._block_prefill_paged(
                blk, x, positions, i, pool_k, pool_v, k_scale, v_scale,
                block_tables, ctx_len,
            )
            new_k.append(k)
            new_v.append(v)
        x = L.norm_apply(self.final_norm, x, cfg)
        logits = L.lm_logits(self.embed, x)
        return logits, jnp.stack(new_k), jnp.stack(new_v)

    def _forward_prefill_paged(self, tokens, positions, block_tables,
                               ctx_len, pages, offs, pool_k, pool_v):
        logits, kn, vn = self._prefill_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            None, None,
        )
        # kn/vn (L, B, C, KV, hd); pages/offs (B, C) broadcast together
        pool_k = pool_k.at[:, pages, offs].set(kn.astype(pool_k.dtype))
        pool_v = pool_v.at[:, pages, offs].set(vn.astype(pool_v.dtype))
        return logits, pool_k, pool_v

    def _forward_prefill_paged_q(self, tokens, positions, block_tables,
                                 ctx_len, pages, offs, pool_k, pool_v,
                                 k_scale, v_scale):
        logits, kn, vn = self._prefill_trunk(
            tokens, positions, block_tables, ctx_len, pool_k, pool_v,
            k_scale, v_scale,
        )
        kq, ks = quantize_kv_int8(kn)
        vq, vs = quantize_kv_int8(vn)
        pool_k = pool_k.at[:, pages, offs].set(kq)
        pool_v = pool_v.at[:, pages, offs].set(vq)
        k_scale = k_scale.at[:, pages, offs].set(ks)
        v_scale = v_scale.at[:, pages, offs].set(vs)
        return logits, pool_k, pool_v, k_scale, v_scale

    def _block_prefill_paged(self, blk, x, positions, layer, pool_k, pool_v,
                             k_scale, v_scale, block_tables, ctx_len):
        cfg = self.cfg
        B, C, _ = x.shape
        h = L.norm_apply(blk["ln1"], x, cfg)
        q, k, v = self._qkv(blk, h, positions, kernel_proj=True)
        o = self._paged_prefill_attention(
            q, k, v, pool_k, pool_v, k_scale, v_scale, block_tables,
            ctx_len, layer=layer,
        )
        o = o.astype(x.dtype).reshape(B, C, cfg.q_dim)
        x = x + self._proj(blk, "attn.wo", o)
        return self._mlp(blk, x, kernel_proj=True), k, v

    def _paged_prefill_attention(self, q, k_new, v_new, pool_k, pool_v,
                                 k_scale, v_scale, block_tables, ctx_len,
                                 *, layer):
        """One layer of chunk-batch prefill attention against the pool.
        Distributed adapters override this with a ``shard_map`` over the
        model axis, mirroring :meth:`_paged_attention`."""
        return paged_gqa_prefill(
            q, k_new, v_new, pool_k, pool_v, block_tables, ctx_len,
            layer=layer, k_scale=k_scale, v_scale=v_scale,
            interpret=self.paged_interpret,
        )
